"""Batched multi-graph PIVOT engine — shape-bucketed ELL clustering.

The per-graph engine (``correlation_cluster``) retraces and recompiles for
every new ``(n, m)`` shape, which is hopeless for serving millions of small
clustering queries (near-dup buckets, LSH bands, per-shard similarity
graphs). This module packs many small graphs into **shape buckets** and runs
the whole bucket through one fused device program.

Bucketing scheme
  Each graph is assigned a bucket key ``(R, W)`` where ``R`` is the vertex
  count rounded up to a power of two (min 8) and ``W`` is the max degree of
  the *eligible-induced* subgraph rounded up to a power of two (min 4). The
  Theorem 26 degree cap is what makes ``W`` small: clustered vertices have
  degree ≤ 12λ at ε=2, so ELL padding waste is bounded by the cap, exactly
  the property the paper's TPU adaptation exploits for single graphs. A
  bucket of ``G`` graphs × ``k`` best-of-k samples is packed into

    ell      (B, R, W) int32  — per-entry ELL adjacency, pad entries = R
    ranks    (B, R+1)  int32  — per-entry permutation ranks, slot R = INF
    eligible (B, R+1)  bool   — degree-cap mask, slot R inactive
    m_edges  (B,)      int32  — undirected |E⁺| of the full (uncapped) graph

  with ``B = next_pow2(G) · k`` — the group axis is padded to a power of two
  so the jit cache key is the bucket shape: **compile count is
  O(#buckets · log B)**, not O(#graphs), including deadline-driven
  partial-bucket flushes (each pads to the next power-of-two sub-batch).

Fused device pipeline (one program per bucket shape)
  1. *Round loop* — one ``lax.while_loop`` drives the entire bucket: every
     round does a batched neighbour-min (pure-jnp gather or the Pallas
     ``(batch, row_block)`` grid kernel), local minima join the MIS, their
     neighbours drop out, and per-entry ``done`` masks freeze finished
     entries while the rest keep iterating.
  2. *Capture pass* — the PIVOT assignment (min-rank MIS neighbour) as one
     more batched gather.
  3. *Cost pass* — disagreement cost per entry, on device: same-label
     neighbour counting through the same ELL tensor (jnp gather or the
     Pallas ``label_agree_ell_batch`` kernel) plus a batched cluster-size
     scatter. Edges dropped by the degree cap are always cut (their
     ineligible endpoint is a singleton), so ``cost = m − 2·intra_pos +
     intra_pairs`` needs only the eligible-induced ELL and the scalar ``m``.
  4. *Best-of-k argmin* — per-graph ``argmin`` over the ``k`` sample
     replicas, computed on device so only the winning labels / costs /
     sample indices cross back to the host (the former ``_cost_host`` loop
     survives only as the oracle in tests).

Bit-exactness contract
  For the same per-graph PRNG key, ``correlation_cluster_batch`` returns
  labels and costs **bit-identical** to per-graph ``correlation_cluster``:
  ranks come from the same ``random_permutation_ranks(n_i, key_i)``, the
  round dynamics are the same deterministic integer min-propagation, the
  capture pass resolves the same min-rank pivots, and the integer cost /
  first-minimum argmin match the host loop exactly. Enforced in
  ``tests/test_batch.py`` and ``tests/test_engine.py`` across bucket
  boundaries (n = R−1/R/R+1), methods, sampling, and both kernel paths.

Buffer reuse
  :class:`BucketBufferPool` gives steady-state serving O(#buckets)
  persistent buffers: host staging arrays per bucket shape are reused
  across flushes, and the device program is jit'd with ``donate_argnums``
  so XLA recycles the input buffers for the outputs instead of holding
  both generations live.

Benchmarks
  ``PYTHONPATH=src python benchmarks/batch_bench.py`` — throughput and
  compile counts vs the per-graph loop; ``benchmarks/serve_bench.py`` —
  p50/p99 serving latency under full-bucket vs deadline flush policies.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.util import next_pow2

from .arboricity import arboricity_bounds
from .degree_cap import degree_threshold
from .graph import Graph
from .mis import INF_RANK, random_permutation_ranks

UNDECIDED = 0
IN_MIS = 1
REMOVED = 2

MIN_ROWS = 8     # smallest R bucket
MIN_WIDTH = 4    # smallest W bucket

# Largest supported bucket shapes. R is bounded so the int32 pair count
# R·(R−1)/2 of the device cost pass cannot overflow (jax x64 is disabled in
# this deployment); W is bounded because an eligible-induced degree that
# large means the degree cap is effectively off for a dense graph — the
# per-graph engine is the right tool there.
MAX_ROWS = 1 << 15
MAX_WIDTH = 1 << 12

_INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Host-side packing.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphPlan:
    """Per-graph packing plan: bucket key + degree-cap metadata."""

    g: Graph
    n: int
    lam: Optional[int]          # resolved arboricity bound (None for raw)
    threshold: Optional[float]  # degree-cap threshold (None for raw)
    eligible: np.ndarray        # (n,) bool — vertices the inner PIVOT sees
    wreq: int                   # max eligible-induced degree
    R: int                      # row bucket (pow2)
    W: int                      # width bucket (pow2)

    @property
    def bucket(self) -> Tuple[int, int]:
        return (self.R, self.W)


def plan_graph(g: Graph, method: str = "pivot", eps: float = 2.0,
               lam: Optional[int] = None) -> GraphPlan:
    """Resolve the degree cap and the (R, W) shape bucket for one graph.

    Mirrors the per-graph api exactly: ``lam`` defaults to the degeneracy
    upper bound, eligibility is ``deg <= 8(1+ε)/ε·λ`` (Theorem 26), and for
    ``method='pivot_raw'`` every vertex is eligible.

    Raises ``ValueError`` when the graph exceeds the largest supported
    bucket (``MAX_ROWS`` vertices / eligible-induced degree ``MAX_WIDTH``).
    """
    n = g.n
    if method == "pivot":
        if lam is None:
            _, lam = arboricity_bounds(g, exact=n <= 200_000)
        threshold = degree_threshold(lam, eps)
        eligible = ~(np.asarray(g.deg) > threshold)
    elif method == "pivot_raw":
        lam, threshold = None, None
        eligible = np.ones(n, dtype=bool)
    else:
        raise ValueError(f"batch engine supports 'pivot'/'pivot_raw', "
                         f"got {method!r}")

    und = g.undirected_edges()
    if len(und):
        keep = eligible[und[:, 0]] & eligible[und[:, 1]]
        kept = und[keep]
        deg_ind = np.bincount(kept.ravel(), minlength=n) if len(kept) else \
            np.zeros(n, np.int64)
        wreq = int(deg_ind.max()) if len(kept) else 0
    else:
        wreq = 0

    R = max(MIN_ROWS, next_pow2(max(1, n)))
    W = max(MIN_WIDTH, next_pow2(max(1, wreq)))
    if R > MAX_ROWS:
        raise ValueError(
            f"graph with n={n} needs row bucket R={R} > MAX_ROWS={MAX_ROWS}; "
            "the batch engine targets many small graphs — cluster this one "
            "through correlation_cluster (per-graph engine) instead")
    if W > MAX_WIDTH:
        raise ValueError(
            f"graph needs ELL width W={W} > MAX_WIDTH={MAX_WIDTH} (max "
            f"eligible-induced degree {wreq}); with method='pivot' the "
            "Theorem 26 degree cap bounds this by 12λ — a width this large "
            "means the graph is too dense for the bucketed ELL layout; use "
            "the per-graph engine")
    return GraphPlan(g=g, n=n, lam=lam, threshold=threshold,
                     eligible=eligible, wreq=wreq, R=R, W=W)


@dataclasses.dataclass
class PackStats:
    """Packing/padding accounting for one ``correlation_cluster_batch`` call.

    Returned by the packer itself (``with_stats=True``) so serving-layer
    stats can never drift from what was actually padded onto the device.
    """

    n_graphs: int = 0
    n_entries: int = 0        # real device entries = graphs × num_samples
    padded_entries: int = 0   # empty entries added for pow2 group padding
    pad_vertex_waste: int = 0  # Σ (R − n) over real graphs
    bucket_shapes: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)  # (R, W, B) per bucket actually run


def _pack_bucket(plans: Sequence[GraphPlan],
                 group_keys: Sequence[Sequence[jax.Array]],
                 k: int,
                 staging: Optional[dict] = None):
    """Pack one bucket's graphs (× k samples each) into device tensors.

    Returns ``(ell, ranks, elig, m_edges, pad_groups)`` with batch axis
    ``B = next_pow2(len(plans)) · k``: the ``k`` sample replicas of a graph
    occupy contiguous entries so the device argmin can reduce over a simple
    ``(G, k)`` reshape. ``staging`` (from :class:`BucketBufferPool`) reuses
    host arrays across flushes instead of reallocating.
    """
    R, W = plans[0].bucket
    g_pad = next_pow2(len(plans))
    b_pad = g_pad * k
    if staging is None:
        ell = np.full((b_pad, R, W), R, dtype=np.int32)
        ranks = np.full((b_pad, R + 1), _INT32_MAX, dtype=np.int32)
        elig = np.zeros((b_pad, R + 1), dtype=bool)
        m_edges = np.zeros((b_pad,), dtype=np.int32)
    else:
        ell, ranks, elig, m_edges = (staging["ell"], staging["ranks"],
                                     staging["elig"], staging["m_edges"])
        ell.fill(R)
        ranks.fill(_INT32_MAX)
        elig.fill(False)
        m_edges.fill(0)

    for gi, (plan, keys) in enumerate(zip(plans, group_keys)):
        n = plan.n
        base = gi * k
        und = plan.g.undirected_edges()
        if len(und):
            keep = plan.eligible[und[:, 0]] & plan.eligible[und[:, 1]]
            e = und[keep]
        else:
            e = np.zeros((0, 2), dtype=np.int64)
        if len(e):
            src = np.concatenate([e[:, 0], e[:, 1]])
            dst = np.concatenate([e[:, 1], e[:, 0]])
            order = np.argsort(src, kind="stable")
            src, dst = src[order], dst[order]
            deg = np.bincount(src, minlength=n)
            starts = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(deg, out=starts[1:])
            slot = np.arange(len(src)) - starts[src]
            ell[base, src, slot] = dst
        # The adjacency is identical across the k sample replicas; only the
        # permutation (hence ranks) differs per sample key.
        for si in range(1, k):
            ell[base + si] = ell[base]
        for si, key in enumerate(keys):
            if n:
                # Same per-graph permutation as the single-graph engine:
                # ranks are a function of (n, key) only ⇒ bit-exact per graph.
                ranks[base + si, :n] = np.asarray(
                    random_permutation_ranks(n, key))
                elig[base + si, :n] = plan.eligible
            m_edges[base + si] = plan.g.m
    return ell, ranks, elig, m_edges, g_pad - len(plans)


# ---------------------------------------------------------------------------
# Device program: fused MIS rounds + PIVOT capture + cost + best-of-k argmin.
# ---------------------------------------------------------------------------


def _gather_rows(table: jnp.ndarray, ell: jnp.ndarray) -> jnp.ndarray:
    """(B, R+1) per-graph state gathered through (B, R, W) neighbour ids."""
    return jax.vmap(lambda t, e: t[e])(table, ell)


def _batch_pivot_cost_impl(ell, ranks_p, elig_p, m_edges, k: int,
                           use_kernel: bool):
    """Cluster + cost + select every graph of one shape bucket on device.

    Args:
      ell: (B, R, W) int32 ELL adjacency, pad entries = R; B = G·k with the
        k sample replicas of each graph contiguous.
      ranks_p: (B, R+1) int32 ranks, slot R = INF.
      elig_p: (B, R+1) bool degree-cap eligibility, slot R False.
      m_edges: (B,) int32 full-graph undirected edge counts.
      k: best-of-k replica count (static).
    Returns per *group* (graph) arrays:
      (labels (G, R), costs (G,), picked (G,), rounds (G,)).
    """
    B, R, W = ell.shape
    ranks = ranks_p[:, :R]
    elig = elig_p[:, :R]
    # Rank gather is loop-invariant on the jnp path — hoisted out of the
    # while body; only the activity gather changes per round.
    nbr_ranks = None if use_kernel else _gather_rows(ranks_p, ell)

    def nbr_min(active: jnp.ndarray) -> jnp.ndarray:
        active_p = jnp.concatenate(
            [active, jnp.zeros((B, 1), active.dtype)], axis=1)
        if use_kernel:
            from repro.kernels import ops as _kops  # kernels stay optional

            return _kops.neighbor_min_ell_batch(ell, ranks_p, active_p)
        act = _gather_rows(active_p, ell)
        return jnp.min(jnp.where(act, nbr_ranks, INF_RANK), axis=2)

    def cond(carry):
        status, _ = carry
        return jnp.any(status == UNDECIDED)

    def body(carry):
        status, rounds = carry
        und = status == UNDECIDED            # UNDECIDED ⊆ eligible
        nmin = nbr_min(und)
        winners = und & (ranks < nmin)
        wmin = nbr_min(winners)
        hit = und & (~winners) & (wmin < INF_RANK)
        status = jnp.where(winners, IN_MIS, status)
        status = jnp.where(hit, REMOVED, status)
        # Per-entry done mask: finished entries stop accumulating rounds.
        rounds = rounds + jnp.any(und, axis=1).astype(jnp.int32)
        return status, rounds

    status0 = jnp.where(elig, UNDECIDED, REMOVED).astype(jnp.int32)
    status, rounds = jax.lax.while_loop(
        cond, body, (status0, jnp.zeros((B,), jnp.int32)))

    # PIVOT capture pass: min-rank MIS neighbour, one batched convergecast.
    in_mis = status == IN_MIS
    wmin = nbr_min(in_mis)
    arange_r = jnp.arange(R, dtype=jnp.int32)
    rank_to_v = jax.vmap(
        lambda rk: jnp.zeros((R + 1,), jnp.int32).at[
            jnp.clip(rk, 0, R)].set(arange_r)
    )(ranks)
    piv = jnp.take_along_axis(rank_to_v, jnp.minimum(wmin, R), axis=1)
    own = jnp.broadcast_to(arange_r[None, :], (B, R))
    labels = jnp.where(in_mis, own,
                       jnp.where(wmin < INF_RANK, piv, own))
    labels = jnp.where(elig, labels, own)

    # Disagreement-cost pass. Every kept (eligible-induced) undirected edge
    # appears twice in the ELL, so the same-label neighbour count sums to
    # 2·intra_pos; cap-dropped edges are always cut (their ineligible
    # endpoint is a singleton) so m_edges accounts for them exactly:
    #   cost = (m − intra_pos) + (intra_pairs − intra_pos).
    labels_p = jnp.concatenate(
        [labels, jnp.full((B, 1), -1, jnp.int32)], axis=1)
    if use_kernel:
        from repro.kernels import ops as _kops

        agree = _kops.label_agree_ell_batch(ell, labels_p)
        intra_pos2 = jnp.sum(agree, axis=1)
    else:
        nbr_lab = _gather_rows(labels_p, ell)
        intra_pos2 = jnp.sum(
            (nbr_lab == labels[:, :, None]).astype(jnp.int32), axis=(1, 2))
    sizes = jax.vmap(
        lambda lab: jnp.zeros((R,), jnp.int32).at[lab].add(1))(labels)
    intra_pairs = jnp.sum(sizes * (sizes - 1) // 2, axis=1)
    costs = m_edges - intra_pos2 + intra_pairs

    # Best-of-k selection: first minimum wins (jnp.argmin tie-break), the
    # same rule as the host loop's strict `<` — only winners cross to host.
    G = B // k
    cost_g = costs.reshape(G, k)
    picked = jnp.argmin(cost_g, axis=1).astype(jnp.int32)
    labels_win = jnp.take_along_axis(
        labels.reshape(G, k, R), picked[:, None, None], axis=1)[:, 0]
    costs_win = jnp.take_along_axis(cost_g, picked[:, None], axis=1)[:, 0]
    rounds_win = jnp.take_along_axis(
        rounds.reshape(G, k), picked[:, None], axis=1)[:, 0]
    return labels_win, costs_win, picked, rounds_win


_batch_program = partial(
    jax.jit, static_argnames=("k", "use_kernel"))(_batch_pivot_cost_impl)
# Donated variant for the serving path: XLA reuses the (B,R,W)/(B,R+1)
# input buffers for outputs/temporaries, so a steady flush stream holds
# O(#buckets) device buffers instead of two generations per flush.
_batch_program_donated = partial(
    jax.jit, static_argnames=("k", "use_kernel"),
    donate_argnums=(0, 1, 2, 3))(_batch_pivot_cost_impl)


def program_cache_size() -> int:
    """Number of compiled bucket programs (benchmark: O(#buckets))."""
    return int(_batch_program._cache_size()
               + _batch_program_donated._cache_size())


def run_bucket_program(ell, ranks_p, elig_p, m_edges, k: int,
                       use_kernel: bool = False, donate: bool = False):
    """Invoke the fused bucket program (optionally with donated inputs).

    The single entry point for both the batch API and serving-layer warmup,
    so the donation policy and its warning handling live in one place: the
    selection outputs are group-shaped, so XLA cannot alias the
    entry-shaped inputs into them on every backend — donation still
    releases the inputs eagerly instead of holding two generations live,
    and the "not usable" warning is expected, not actionable.
    """
    if donate:
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return _batch_program_donated(ell, ranks_p, elig_p, m_edges,
                                          k=k, use_kernel=use_kernel)
    return _batch_program(ell, ranks_p, elig_p, m_edges,
                          k=k, use_kernel=use_kernel)


class BucketBufferPool:
    """Persistent per-bucket-shape buffers for steady-state serving.

    Two halves, both keyed by the packed shape ``(B, R, W)``:

    * **Host staging** — the numpy ``ell``/``ranks``/``eligible``/``m``
      arrays a flush packs into are allocated once per shape and refilled
      in place on every subsequent flush.
    * **Device donation** — flushes routed through a pool run the
      ``donate_argnums`` jit variant, so the device input buffers are
      recycled into the outputs instead of surviving alongside them.

    Results are bit-identical with or without the pool (asserted in
    ``tests/test_engine.py``); the pool only changes allocation behaviour.
    """

    def __init__(self, donate: bool = True):
        self.donate = donate
        self._staging: Dict[Tuple[int, int, int], dict] = {}

    def staging(self, b: int, r: int, w: int) -> dict:
        key = (b, r, w)
        buf = self._staging.get(key)
        if buf is None:
            buf = {
                "ell": np.empty((b, r, w), dtype=np.int32),
                "ranks": np.empty((b, r + 1), dtype=np.int32),
                "elig": np.empty((b, r + 1), dtype=bool),
                "m_edges": np.empty((b,), dtype=np.int32),
            }
            self._staging[key] = buf
        return buf

    @property
    def n_buffers(self) -> int:
        return len(self._staging)


# ---------------------------------------------------------------------------
# Host-side cost (numpy) — the test oracle for the device cost pass.
# ---------------------------------------------------------------------------


def _cost_host(g: Graph, labels: np.ndarray) -> int:
    """Disagreement cost, same convention as ``core.cost.clustering_cost``.

    The serving path computes cost on device (see the fused program); this
    integer-exact numpy version is kept as the oracle the tests compare
    against.
    """
    und = g.undirected_edges()
    intra_pos = int((labels[und[:, 0]] == labels[und[:, 1]]).sum()) \
        if len(und) else 0
    pos_disagree = g.m - intra_pos
    sizes = np.bincount(labels, minlength=g.n)
    intra_pairs = int((sizes.astype(np.int64) * (sizes - 1) // 2).sum())
    return pos_disagree + (intra_pairs - intra_pos)


# ---------------------------------------------------------------------------
# Public entry point.
# ---------------------------------------------------------------------------


def correlation_cluster_batch(
    graphs: Sequence[Graph],
    keys: Optional[Sequence[jax.Array] | jax.Array] = None,
    method: str = "pivot",
    eps: float = 2.0,
    lams: Optional[Sequence[Optional[int]]] = None,
    num_samples: int = 1,
    use_kernel: bool = False,
    pool: Optional[BucketBufferPool] = None,
    with_stats: bool = False,
):
    """Cluster many graphs through the shape-bucketed batch engine.

    Args:
      graphs: the positive-edge graphs (``Graph`` instances).
      keys: per-graph PRNG keys (one key broadcast to all if a single key is
        given; defaults to ``PRNGKey(0)`` like the per-graph api).
      method: ``'pivot'`` (Theorem 26 degree cap + PIVOT, Corollary 28) or
        ``'pivot_raw'`` (no cap).
      lams: optional per-graph arboricity bounds (estimated when omitted).
      num_samples: best-of-k PIVOT — each graph is clustered under ``k``
        folded keys *within the same bucket* and the lowest-cost replica is
        selected by an on-device argmin, matching
        ``correlation_cluster(num_samples=k)`` bit-exactly (including the
        picked sample index). Must be >= 1.
      use_kernel: route neighbour-min and the cost reduction through the
        batched Pallas kernels.
      pool: optional :class:`BucketBufferPool` — reuse host staging buffers
        and run the donated device program (the serving path).
      with_stats: also return the packer's :class:`PackStats` as
        ``(results, stats)`` so callers track padding without re-deriving it.

    Returns one :class:`repro.core.api.ClusterResult` per input graph with
    labels/costs bit-identical to per-graph ``correlation_cluster`` calls
    under the same keys (plus ``PackStats`` when ``with_stats``).
    """
    from .api import ClusterResult, sample_keys  # deferred: api imports us

    if num_samples < 1:
        raise ValueError(
            f"num_samples must be >= 1, got {num_samples} (use 1 for a "
            "single PIVOT draw)")

    graphs = list(graphs)
    n_graphs = len(graphs)
    stats = PackStats()
    if n_graphs == 0:
        return ([], stats) if with_stats else []
    if keys is None:
        keys = [jax.random.PRNGKey(0)] * n_graphs
    elif isinstance(keys, jax.Array) and keys.ndim <= 1:
        # One key (legacy uint32 (2,) or typed 0-d) broadcast to all graphs.
        keys = [keys] * n_graphs
    else:
        keys = list(keys)
    if len(keys) != n_graphs:
        raise ValueError(f"{len(keys)} keys for {n_graphs} graphs")
    if lams is None:
        lams = [None] * n_graphs

    k = num_samples
    plans = [plan_graph(g, method=method, eps=eps, lam=lam)
             for g, lam in zip(graphs, lams)]

    buckets: Dict[Tuple[int, int], List[int]] = {}
    for gi, plan in enumerate(plans):
        buckets.setdefault(plan.bucket, []).append(gi)

    labels_by_graph: Dict[int, np.ndarray] = {}
    cost_by_graph: Dict[int, int] = {}
    picked_by_graph: Dict[int, int] = {}
    rounds_by_graph: Dict[int, int] = {}
    for (R, W), members in buckets.items():
        bplans = [plans[gi] for gi in members]
        bkeys = [sample_keys(keys[gi], k) for gi in members]
        b_pad = next_pow2(len(members)) * k
        staging = pool.staging(b_pad, R, W) if pool is not None else None
        ell, ranks, elig, m_edges, pad_groups = _pack_bucket(
            bplans, bkeys, k=k, staging=staging)
        labels, costs, picked, rounds = run_bucket_program(
            jnp.asarray(ell), jnp.asarray(ranks), jnp.asarray(elig),
            jnp.asarray(m_edges), k=k, use_kernel=use_kernel,
            donate=pool is not None and pool.donate)
        labels = np.asarray(labels)
        costs = np.asarray(costs)
        picked = np.asarray(picked)
        rounds = np.asarray(rounds)
        for slot, gi in enumerate(members):
            labels_by_graph[gi] = labels[slot, : plans[gi].n].astype(np.int32)
            cost_by_graph[gi] = int(costs[slot])
            picked_by_graph[gi] = int(picked[slot])
            rounds_by_graph[gi] = int(rounds[slot])
        stats.n_graphs += len(members)
        stats.n_entries += len(members) * k
        stats.padded_entries += pad_groups * k
        stats.pad_vertex_waste += sum(R - p.n for p in bplans)
        stats.bucket_shapes.append((R, W, b_pad))

    results: List[ClusterResult] = []
    for gi, plan in enumerate(plans):
        info = {
            "bucket": plan.bucket,
            "depth": rounds_by_graph[gi],
            "engine": "batch",
        }
        if plan.threshold is not None:
            info.update(threshold=plan.threshold,
                        high_degree=int((~plan.eligible).sum()),
                        lambda_bound=plan.lam)
        if k > 1:
            info.update(num_samples=k, picked_sample=picked_by_graph[gi])
        results.append(ClusterResult(
            labels=labels_by_graph[gi], cost=cost_by_graph[gi],
            method=method, info=info))
    return (results, stats) if with_stats else results


__all__ = [
    "GraphPlan",
    "PackStats",
    "BucketBufferPool",
    "plan_graph",
    "correlation_cluster_batch",
    "program_cache_size",
    "run_bucket_program",
    "MIN_ROWS",
    "MIN_WIDTH",
    "MAX_ROWS",
    "MAX_WIDTH",
]
