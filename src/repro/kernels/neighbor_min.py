"""Pallas TPU kernel: masked neighbour-min propagation (the MIS round loop).

Contract: given an ELL adjacency (each vertex's neighbour list padded to a
fixed width ``W``), per-vertex ``ranks`` and an ``active`` mask, compute for
every vertex the minimum rank over its *active* neighbours (INF if none).
This is the per-round hot loop of the paper's greedy-MIS engine — executed
O(log n) times per PIVOT call on the full edge set.

TPU adaptation (see DESIGN.md §2): the paper's own Theorem 26 bounds the
degree of the clustered subgraph by ``O(λ/ε)`` (12λ at ε=2), which makes the
ELL layout efficient — padding waste is bounded by the degree cap, and the
row-blocked kernel is a dense (R × W) tile pipeline through VMEM instead of
a data-dependent CSR walk. The full rank/active vectors are staged in VMEM
once per row-block (vertex state is O(n) and edge-sharded shards keep
n ≤ ~1M per device ⇒ ≤ 4 MB, well inside the 16 MB VMEM budget claimed by
the BlockSpec below).

Grid: 1-D over row blocks of ``R`` vertices.
  ell_ref:    (R, W) int32  — neighbour ids (pad = n)
  ranks_ref:  (n_pad,)      — full vector, replicated per block
  active_ref: (n_pad,)      — full vector (int32 0/1), replicated per block
  out_ref:    (R,) int32    — per-vertex min
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INF_VAL = 2**31 - 1  # int32 max; Python int so pallas kernels don't capture arrays
INF = jnp.int32(INF_VAL)


def _kernel(ell_ref, ranks_ref, active_ref, out_ref):
    cols = ell_ref[...]                       # (R, W) int32
    ranks = ranks_ref[...]                    # (n_pad,)
    active = active_ref[...]                  # (n_pad,) int32 0/1
    vals = jnp.take(ranks, cols, axis=0, fill_value=2**31 - 1)  # gather
    act = jnp.take(active, cols, axis=0, fill_value=0)
    vals = jnp.where(act > 0, vals, INF_VAL)
    out_ref[...] = jnp.min(vals, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def neighbor_min_ell(ell: jnp.ndarray, ranks: jnp.ndarray, active: jnp.ndarray,
                     block_rows: int = 256, interpret: bool = True
                     ) -> jnp.ndarray:
    """Blocked Pallas neighbour-min over an ELL adjacency.

    Args:
      ell: (n_rows, W) int32 neighbour ids; entries == len(ranks)-1 slot map
        to a padded rank slot holding INF (see :func:`pad_state`).
      ranks: (n_pad,) int32 — last slot is the INF pad slot.
      active: (n_pad,) bool/int32 — last slot False.
    Returns (n_rows,) int32 mins.
    """
    n_rows, w = ell.shape
    rb = min(block_rows, n_rows)
    n_blocks = pl.cdiv(n_rows, rb)
    active_i = active.astype(jnp.int32)

    out = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((rb, w), lambda i: (i, 0)),
            pl.BlockSpec(ranks.shape, lambda i: (0,)),
            pl.BlockSpec(ranks.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        interpret=interpret,
    )(ell, ranks, active_i)
    return out


def _kernel_batch(ell_ref, ranks_ref, active_ref, out_ref):
    """Per-(graph, row-block) program of the batched grid.

    Identical math to :func:`_kernel`; the leading length-1 axis is the
    batch block (one graph's row-block plus that graph's replicated state).
    """
    cols = ell_ref[0]                         # (RB, W) int32
    ranks = ranks_ref[0]                      # (R+1,)
    active = active_ref[0]                    # (R+1,) int32 0/1
    vals = jnp.take(ranks, cols, axis=0, fill_value=INF_VAL)
    act = jnp.take(active, cols, axis=0, fill_value=0)
    vals = jnp.where(act > 0, vals, INF_VAL)
    out_ref[0] = jnp.min(vals, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def neighbor_min_ell_batch(ell: jnp.ndarray, ranks: jnp.ndarray,
                           active: jnp.ndarray, block_rows: int = 256,
                           interpret: bool = True) -> jnp.ndarray:
    """Batched neighbour-min over shape-bucketed ELL adjacencies.

    The multi-graph PIVOT engine (``core.batch``) packs ``B`` graphs of one
    shape bucket into a single ``(B, R, W)`` ELL tensor; this kernel runs the
    per-round hot loop for the whole bucket with a 2-D ``(batch, row_block)``
    grid, so one Mosaic program serves every graph in the bucket and the
    round loop stays on device end to end.

    Args:
      ell: (B, R, W) int32 neighbour ids; pad entries == R (per-graph pad
        slot, see ``core.batch``).
      ranks: (B, R+1) int32 — slot R is the INF pad slot.
      active: (B, R+1) bool/int32 — slot R inactive.
    Returns (B, R) int32 per-vertex mins.
    """
    b, n_rows, w = ell.shape
    rb = min(block_rows, n_rows)
    n_blocks = pl.cdiv(n_rows, rb)
    state_w = ranks.shape[1]
    active_i = active.astype(jnp.int32)

    out = pl.pallas_call(
        _kernel_batch,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, rb, w), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, state_w), lambda bi, i: (bi, 0)),
            pl.BlockSpec((1, state_w), lambda bi, i: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, rb), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows), jnp.int32),
        interpret=interpret,
    )(ell, ranks, active_i)
    return out


def _kernel_agree_batch(ell_ref, labels_full_ref, labels_rows_ref, out_ref):
    """Per-(graph, row-block) program of the batched cost reduction.

    Counts, for every vertex of the row block, how many of its ELL
    neighbours carry the same cluster label. The eligible-induced ELL holds
    both directions of every kept undirected edge, so summing this output
    over rows yields ``2 · intra_pos`` — the quantity the fused batch
    program combines with cluster sizes into the disagreement cost. Pad
    entries point at slot R whose label is the -1 sentinel (never a real
    label), so they contribute nothing.
    """
    cols = ell_ref[0]                         # (RB, W) int32
    labels = labels_full_ref[0]               # (R+1,) int32, slot R = -1
    own = labels_rows_ref[0]                  # (RB,) int32
    nbr = jnp.take(labels, cols, axis=0, fill_value=-1)
    same = (nbr == own[:, None]).astype(jnp.int32)
    out_ref[0] = jnp.sum(same, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def label_agree_ell_batch(ell: jnp.ndarray, labels_p: jnp.ndarray,
                          block_rows: int = 256, interpret: bool = True
                          ) -> jnp.ndarray:
    """Batched same-label neighbour count over shape-bucketed ELL tensors.

    The device cost pass of ``core.batch``: one ``(batch, row_block)`` grid
    program computes per-vertex agreement counts for every graph of a
    bucket, mirroring :func:`neighbor_min_ell_batch`'s layout so the cost
    reduction rides the same VMEM staging as the round loop.

    Args:
      ell: (B, R, W) int32 neighbour ids; pad entries == R.
      labels_p: (B, R+1) int32 cluster labels; slot R holds the -1 sentinel.
    Returns (B, R) int32 per-vertex same-label neighbour counts.
    """
    b, n_rows, w = ell.shape
    rb = min(block_rows, n_rows)
    n_blocks = pl.cdiv(n_rows, rb)
    state_w = labels_p.shape[1]
    labels_rows = labels_p[:, :n_rows]

    out = pl.pallas_call(
        _kernel_agree_batch,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, rb, w), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, state_w), lambda bi, i: (bi, 0)),
            pl.BlockSpec((1, rb), lambda bi, i: (bi, i)),
        ],
        out_specs=pl.BlockSpec((1, rb), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows), jnp.int32),
        interpret=interpret,
    )(ell, labels_p, labels_rows)
    return out


def pad_state(ranks: jnp.ndarray, active: jnp.ndarray):
    """Append the INF/inactive pad slot (ELL pad entries point at it)."""
    ranks_p = jnp.concatenate([ranks, jnp.array([INF], jnp.int32)])
    active_p = jnp.concatenate([active.astype(jnp.int32), jnp.zeros((1,), jnp.int32)])
    return ranks_p, active_p


def ell_from_graph(g, width: int | None = None,
                   allow_truncate: bool = False) -> jnp.ndarray:
    """Build the (n, W) ELL neighbour table from a core Graph (jnp ops).

    Pad entries point at slot ``n`` (the pad slot added by pad_state).

    A ``width`` smaller than the graph's max degree silently dropped the
    overflow neighbours historically, which corrupts neighbour-min (and with
    it the greedy MIS): a vertex can win a round only because its true
    minimum-rank neighbour fell off the row. Now this raises unless the
    caller explicitly opts in with ``allow_truncate=True`` (legitimate only
    when the dropped columns are provably never active, e.g. rows the degree
    cap already singled out). Under tracing (``g.deg`` is abstract) the check
    is skipped — jit callers are expected to pass a concrete safe width, as
    ``core.mis`` does.
    """
    n = g.n
    max_deg = None
    if not isinstance(g.deg, jax.core.Tracer):
        max_deg = int(np.asarray(g.deg).max()) if n else 0
    if width is None:
        if max_deg is None:
            raise ValueError("ell_from_graph: pass an explicit width when "
                             "the graph degrees are traced")
        width = max(1, max_deg)
    elif max_deg is not None and width < max_deg and not allow_truncate:
        raise ValueError(
            f"ell_from_graph: width={width} < max degree {max_deg} would "
            "silently drop neighbours and corrupt neighbour-min / MIS "
            "results; pass width >= max degree or allow_truncate=True")
    slot = jnp.arange(g.src.shape[0], dtype=jnp.int32) - g.row_offsets[
        jnp.minimum(g.src, n)
    ]
    ell = jnp.full((n + 1, width), n, jnp.int32)
    valid = (g.src < n) & (slot < width)
    rows = jnp.where(valid, g.src, n)
    cols = jnp.where(valid, slot, 0)
    ell = ell.at[rows, cols].set(jnp.where(valid, g.dst, n))
    return ell[:n]


__all__ = ["neighbor_min_ell", "neighbor_min_ell_batch",
           "label_agree_ell_batch", "ell_from_graph", "pad_state", "INF"]
