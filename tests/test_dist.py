"""Distributed (shard_map) engine ≡ sequential; multi-device via subprocess."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (
    build_graph,
    correlation_cluster,
    distributed_pivot,
    pivot_sequential,
    random_permutation_ranks,
)
from repro.core.graph import random_arboric


def test_distributed_matches_sequential_one_device(rng):
    edges, _ = random_arboric(200, 3, rng)
    g = build_graph(200, edges)
    ranks = random_permutation_ranks(200, jax.random.PRNGKey(4))
    labels, in_mis, rounds = distributed_pivot(g, ranks)
    assert (labels == pivot_sequential(g, np.asarray(ranks))).all()
    assert rounds >= 1


def test_distributed_capped_api(rng):
    edges, lam = random_arboric(150, 2, rng)
    g = build_graph(150, edges)
    res_d = correlation_cluster(g, method="pivot", lam=lam,
                                key=jax.random.PRNGKey(9), distributed=True)
    res_s = correlation_cluster(g, method="pivot", lam=lam,
                                key=jax.random.PRNGKey(9), distributed=False)
    # same permutation (same key) ⇒ identical clustering
    assert (res_d.labels == res_s.labels).all()
    assert res_d.cost == res_s.cost


def test_distributed_packed_matches_unpacked(rng):
    """packed=True (int8 OR-convergecast hit detection) ≡ unpacked engine ≡
    sequential oracle — the previously untested _dist_mis_program path."""
    edges, _ = random_arboric(220, 3, rng)
    g = build_graph(220, edges)
    ranks = random_permutation_ranks(220, jax.random.PRNGKey(11))
    lab_p, mis_p, rounds_p = distributed_pivot(g, ranks, packed=True)
    lab_u, mis_u, rounds_u = distributed_pivot(g, ranks, packed=False)
    assert (lab_p == lab_u).all()
    assert (mis_p == mis_u).all()
    assert rounds_p == rounds_u
    assert (lab_p == pivot_sequential(g, np.asarray(ranks))).all()


@pytest.mark.slow
def test_distributed_packed_multidevice_subprocess(rng):
    """int8 OR-convergecast on a real 8-device CPU mesh: the packed
    collective must stay bit-exact when pmax actually crosses shards."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import (build_graph, distributed_pivot,
                                pivot_sequential, random_permutation_ranks,
                                edge_shard_mesh)
        from repro.core.graph import random_arboric
        rng = np.random.default_rng(3)
        edges, _ = random_arboric(400, 4, rng)
        g = build_graph(400, edges)
        ranks = random_permutation_ranks(400, jax.random.PRNGKey(6))
        mesh = edge_shard_mesh()
        assert mesh.devices.size == 8, mesh.devices.size
        lab_p, _, r_p = distributed_pivot(g, ranks, mesh=mesh, packed=True)
        lab_u, _, r_u = distributed_pivot(g, ranks, mesh=mesh, packed=False)
        ref = pivot_sequential(g, np.asarray(ranks))
        assert (lab_p == lab_u).all(), "packed != unpacked on 8 shards"
        assert (lab_p == ref).all(), "packed != sequential oracle"
        assert r_p == r_u
        print("OK rounds=", r_p)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


@pytest.mark.slow
def test_distributed_eight_devices_subprocess(rng, tmp_path):
    """Bit-equality of the edge-sharded engine across 8 host devices —
    proves the MPC mapping's collectives are semantics-preserving."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import (build_graph, distributed_pivot,
                                pivot_sequential, random_permutation_ranks,
                                edge_shard_mesh)
        from repro.core.graph import random_arboric
        rng = np.random.default_rng(0)
        edges, _ = random_arboric(500, 3, rng)
        g = build_graph(500, edges)
        ranks = random_permutation_ranks(500, jax.random.PRNGKey(1))
        mesh = edge_shard_mesh()
        assert mesh.devices.size == 8, mesh.devices.size
        labels, _, rounds = distributed_pivot(g, ranks, mesh=mesh)
        ref = pivot_sequential(g, np.asarray(ranks))
        assert (labels == ref).all(), "8-shard mismatch"
        print("OK rounds=", rounds)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


@pytest.mark.slow
def test_ep_local_moe_matches_sort_subprocess():
    """ep_local (shard_map EP, §Perf H1 iter 4-5) ≡ sort dispatch, incl.
    gradients, on a 2×4 device mesh."""
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.models.common import KeyGen, split_params
        from repro.models.mlp import init_moe, moe_sort, moe_ep_local
        from repro.models.sharding import ShardingPlan
        cfg = get_smoke("olmoe-1b-7b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = ShardingPlan(axes={"experts": "model", "batch": "data",
                                  "embed": None, "ff": None,
                                  "expert_ff": None, "expert_embed": None})
        p_pm = init_moe(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32, plan)
        p, _ = split_params(p_pm)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
        y_ref = moe_sort(p, x, cfg, capacity_factor=100.0)
        with mesh:
            y_ep = moe_ep_local(p, x, cfg, 100.0, plan, mesh)
        assert float(jnp.max(jnp.abs(y_ref - y_ep))) < 1e-4

        def loss(pp):
            with mesh:
                return jnp.sum(moe_ep_local(pp, x, cfg, 100.0, plan, mesh)**2)
        g = jax.grad(loss)(p)
        assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
