"""Method registry of fused bucket programs + pluggable cost objectives.

The device half of the batch engine used to be a single hardcoded
MIS+PIVOT pipeline. This module is the seam that replaced it: every
clustering *method* the batch engine can run is a :class:`BucketProgramSpec`
registered here, and every *objective* it can optimise is an
:class:`ObjectiveSpec`. The executor (:mod:`repro.core.executor`) composes
``rounds_body × cost_pass`` into one jit program per
``(shape, k, kernel, donation, mesh, block_rows, program, objective)`` key
and never needs to know what the method computes.

**The BucketProgramSpec contract** — what a new method must provide, and
what it inherits for free:

A method provides exactly one traced function, ``rounds_body``::

    rounds_body(ell, ranks_p, elig_p, *, use_kernel, nm_rows)
        -> (labels (B, R) int32, rounds (B,) int32)

over the shared packed tensors: ``ell`` the (B, R, W) int32 ELL adjacency
(pad id ``R``), ``ranks_p`` the (B, R+1) int32 rank rows (slot R = INF),
``elig_p`` the (B, R+1) bool eligibility rows (slot R False). It must
label ineligible and padded vertices with their own index (singletons) so
the cost identity and result slicing hold, and report a per-entry
``rounds`` counter (its notion of parallel depth). Everything else is
inherited: the host-side ELL pack and bucketing, admission-time row
prebuilds, best-of-k replica plumbing and the on-device argmin harvest,
both kernel paths (``nm_rows`` is the tuned ``neighbor_min`` row tile —
both registered methods reduce over neighbourhoods with the same
:func:`repro.kernels.ops.neighbor_min_ell_batch` kernel, so autotuned
winners apply to every method at that bucket shape), the compiled-program
LRU, staging leases, donation, sharding, and the whole serving layer.

An objective provides one traced function, ``cost_pass``::

    cost_pass(ell, labels, m_edges, *, use_kernel, la_rows)
        -> costs (B,) int32

scored per batch entry *before* best-of-k selection, so the argmin picks
the best sample under the configured objective. ``la_rows`` is the tuned
``label_agree`` row tile (again shared across objectives — both registered
cost passes consume the same per-vertex same-label neighbour counts).

Registered methods:

* ``'pivot'`` / ``'pivot_raw'`` — the paper's MIS+PIVOT rounds loop
  (``lax.while_loop`` until no vertex is undecided). The two share one
  *program family* (``program='pivot'``): they differ only in host-side
  eligibility planning, so they must keep sharing compiled programs.
* ``'precluster'`` — constant-round pre-clustering by neighbourhood
  agreement (Cohen-Addad et al., arXiv 2106.08448): vertices whose closed
  neighbourhoods differ by less than a constant fraction agree; labels are
  the minimum rank reached over :data:`PRECLUSTER_ROUNDS` static hops of
  the agreement graph. One straight-line device program — O(1)
  rounds-loop trips instead of the MIS while-loop.

Registered objectives:

* ``'disagree'`` — total disagreement count (the paper's objective).
* ``'minmax'`` — worst-vertex disagreement (min-max correlation
  clustering, arXiv 2502.12519), computed over the same packed tensors.
  Caveat (stated honestly): it is evaluated on the *eligible-induced*
  (degree-capped) subgraph — a cap-dropped edge's disagreement is not
  attributed to its endpoints, so under ``method='pivot'`` with capping
  active the device value is exact only for graphs where nothing is
  dropped. The host oracle :func:`minmax_cost_host` scores the full graph.

Numpy host oracles (:func:`precluster_host`, :func:`minmax_cost_host`)
replicate the device semantics exactly — integer-only agreement math, no
float thresholds — and back the per-graph reference path plus the
bit-exactness suites.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .mis import INF_RANK

UNDECIDED = 0
IN_MIS = 1
REMOVED = 2

# Constant round budget of the precluster min-rank propagation (static:
# baked into the straight-line device program).
PRECLUSTER_ROUNDS = 3

# Agreement threshold β = BETA_NUM/BETA_DEN: neighbours u, v agree when
# |N[u] Δ N[v]| < β·max(|N[u]|, |N[v]|) over closed neighbourhoods. Kept
# rational so the device (int32) and host (int64) comparisons are the same
# integer predicate — no float32-vs-float64 drift can break bit-exactness.
BETA_NUM = 2
BETA_DEN = 5


def _gather_rows(table: jnp.ndarray, ell: jnp.ndarray) -> jnp.ndarray:
    """(B, R+1) per-graph state gathered through (B, R, W) neighbour ids."""
    return jax.vmap(lambda t, e: t[e])(table, ell)


# ---------------------------------------------------------------------------
# Rounds bodies.
# ---------------------------------------------------------------------------


def _pivot_rounds_body(ell, ranks_p, elig_p, *, use_kernel: bool,
                       nm_rows: Optional[int]):
    """MIS rounds (``lax.while_loop``) + PIVOT capture — the paper's method.

    Verbatim the pre-registry fused pipeline, so the 'pivot' program family
    stays bit- and trace-identical to every earlier release.
    """
    B, R, W = ell.shape
    ranks = ranks_p[:, :R]
    elig = elig_p[:, :R]
    # Rank gather is loop-invariant on the jnp path — hoisted out of the
    # while body; only the activity gather changes per round.
    nbr_ranks = None if use_kernel else _gather_rows(ranks_p, ell)

    def nbr_min(active: jnp.ndarray) -> jnp.ndarray:
        active_p = jnp.concatenate(
            [active, jnp.zeros((B, 1), active.dtype)], axis=1)
        if use_kernel:
            from repro.kernels import ops as _kops  # kernels stay optional

            if nm_rows is not None:
                return _kops.neighbor_min_ell_batch(ell, ranks_p, active_p,
                                                    block_rows=nm_rows)
            return _kops.neighbor_min_ell_batch(ell, ranks_p, active_p)
        act = _gather_rows(active_p, ell)
        return jnp.min(jnp.where(act, nbr_ranks, INF_RANK), axis=2)

    def cond(carry):
        status, _ = carry
        return jnp.any(status == UNDECIDED)

    def body(carry):
        status, rounds = carry
        und = status == UNDECIDED            # UNDECIDED ⊆ eligible
        nmin = nbr_min(und)
        winners = und & (ranks < nmin)
        wmin = nbr_min(winners)
        hit = und & (~winners) & (wmin < INF_RANK)
        status = jnp.where(winners, IN_MIS, status)
        status = jnp.where(hit, REMOVED, status)
        # Per-entry done mask: finished entries stop accumulating rounds.
        rounds = rounds + jnp.any(und, axis=1).astype(jnp.int32)
        return status, rounds

    status0 = jnp.where(elig, UNDECIDED, REMOVED).astype(jnp.int32)
    status, rounds = jax.lax.while_loop(
        cond, body, (status0, jnp.zeros((B,), jnp.int32)))

    # PIVOT capture pass: min-rank MIS neighbour, one batched convergecast.
    in_mis = status == IN_MIS
    wmin = nbr_min(in_mis)
    arange_r = jnp.arange(R, dtype=jnp.int32)
    rank_to_v = jax.vmap(
        lambda rk: jnp.zeros((R + 1,), jnp.int32).at[
            jnp.clip(rk, 0, R)].set(arange_r)
    )(ranks)
    piv = jnp.take_along_axis(rank_to_v, jnp.minimum(wmin, R), axis=1)
    own = jnp.broadcast_to(arange_r[None, :], (B, R))
    labels = jnp.where(in_mis, own,
                       jnp.where(wmin < INF_RANK, piv, own))
    labels = jnp.where(elig, labels, own)
    return labels, rounds


def _precluster_rounds_body(ell, ranks_p, elig_p, *, use_kernel: bool,
                            nm_rows: Optional[int]):
    """Constant-round pre-clustering by neighbourhood agreement.

    Three straight-line stages, no data-dependent loop:

    1. **Agreement pass** — for every kept edge (u, v), count the common
       neighbours |N(u) ∩ N(v)| by looking each of v's ELL entries up in
       u's sorted ELL row (O(B·R·W²·log W) compare work, O(B·R·W²)
       intermediate memory — bounded because the Theorem 26 cap keeps
       W ≤ 12λ). The edge *agrees* when the closed neighbourhoods differ
       by less than β = BETA_NUM/BETA_DEN of the larger one:
       ``BETA_DEN·(deg(u)+deg(v)−2·common−2) < BETA_NUM·max(deg(u)+1,
       deg(v)+1)`` — symmetric in (u, v) and integer-only, so the filtered
       agreement graph is undirected by construction.
    2. **Min-rank propagation** — :data:`PRECLUSTER_ROUNDS` static hops of
       per-vertex min over the agreement neighbourhood, seeded with each
       vertex's own rank. This is where the key (hence best-of-k)
       enters: different permutations elect different cluster centres.
    3. **Label capture** — the reached minimum rank maps back to its
       vertex through the same rank→vertex table PIVOT capture uses.

    The per-entry ``rounds`` counter reports how many of the static hops
    still changed some vertex (realized propagation depth ≤ constant).
    """
    B, R, W = ell.shape
    ranks = ranks_p[:, :R]
    elig = elig_p[:, :R]
    real = ell != R                                     # (B, R, W)
    deg = jnp.sum(real, axis=2).astype(jnp.int32)       # (B, R)

    # Common-neighbour counts via sorted-row membership tests. Pad ids (R)
    # sort to the end of each row and are excluded from matching.
    ell_sorted = jnp.sort(ell, axis=2)
    ell_rows_p = jnp.concatenate(
        [ell, jnp.full((B, 1, W), R, jnp.int32)], axis=1)   # (B, R+1, W)
    nbr_lists = jax.vmap(lambda rows, e: rows[e])(ell_rows_p, ell)

    def row_common(sorted_row, cand):
        # sorted_row (W,), cand (W, W): cand[w] = ELL row of neighbour w.
        idx = jnp.searchsorted(sorted_row, cand)
        got = sorted_row[jnp.minimum(idx, W - 1)]
        member = (got == cand) & (cand != R)
        return jnp.sum(member, axis=1).astype(jnp.int32)

    common = jax.vmap(jax.vmap(row_common))(ell_sorted, nbr_lists)

    deg_p = jnp.concatenate(
        [deg, jnp.zeros((B, 1), jnp.int32)], axis=1)
    nbr_deg = _gather_rows(deg_p, ell)                  # (B, R, W)
    du = deg[:, :, None]
    dv = nbr_deg
    sym_diff = du + dv - 2 * common - 2     # closed nbhds: u∈N[v], v∈N[u]
    agree = real & (BETA_DEN * sym_diff
                    < BETA_NUM * (jnp.maximum(du, dv) + 1))
    agree_ell = jnp.where(agree, ell, R)

    def agree_min(state: jnp.ndarray) -> jnp.ndarray:
        state_p = jnp.concatenate(
            [state, jnp.full((B, 1), INF_RANK, jnp.int32)], axis=1)
        if use_kernel:
            from repro.kernels import ops as _kops  # kernels stay optional

            if nm_rows is not None:
                return _kops.neighbor_min_ell_batch(agree_ell, state_p,
                                                    elig_p,
                                                    block_rows=nm_rows)
            return _kops.neighbor_min_ell_batch(agree_ell, state_p, elig_p)
        act = _gather_rows(elig_p, agree_ell)
        vals = _gather_rows(state_p, agree_ell)
        return jnp.min(jnp.where(act, vals, INF_RANK), axis=2)

    state = jnp.where(elig, ranks, INF_RANK)
    rounds = jnp.zeros((B,), jnp.int32)
    for _ in range(PRECLUSTER_ROUNDS):
        nxt = jnp.minimum(state, agree_min(state))
        rounds = rounds + jnp.any(nxt != state, axis=1).astype(jnp.int32)
        state = nxt

    arange_r = jnp.arange(R, dtype=jnp.int32)
    rank_to_v = jax.vmap(
        lambda rk: jnp.zeros((R + 1,), jnp.int32).at[
            jnp.clip(rk, 0, R)].set(arange_r)
    )(ranks)
    lab = jnp.take_along_axis(rank_to_v, jnp.minimum(state, R), axis=1)
    own = jnp.broadcast_to(arange_r[None, :], (B, R))
    labels = jnp.where(state < INF_RANK, lab, own)
    labels = jnp.where(elig, labels, own)
    return labels, rounds


# ---------------------------------------------------------------------------
# Cost passes.
# ---------------------------------------------------------------------------


def _label_agree_counts(ell, labels, *, use_kernel: bool,
                        la_rows: Optional[int]) -> jnp.ndarray:
    """(B, R) per-vertex same-label neighbour counts over the packed ELL."""
    B, R, W = ell.shape
    labels_p = jnp.concatenate(
        [labels, jnp.full((B, 1), -1, jnp.int32)], axis=1)
    if use_kernel:
        from repro.kernels import ops as _kops

        if la_rows is not None:
            return _kops.label_agree_ell_batch(ell, labels_p,
                                               block_rows=la_rows)
        return _kops.label_agree_ell_batch(ell, labels_p)
    nbr_lab = _gather_rows(labels_p, ell)
    return jnp.sum((nbr_lab == labels[:, :, None]).astype(jnp.int32), axis=2)


def _cluster_sizes(labels: jnp.ndarray) -> jnp.ndarray:
    B, R = labels.shape
    return jax.vmap(
        lambda lab: jnp.zeros((R,), jnp.int32).at[lab].add(1))(labels)


def _disagree_cost_pass(ell, labels, m_edges, *, use_kernel: bool,
                        la_rows: Optional[int]) -> jnp.ndarray:
    """Total disagreement count — the paper's objective.

    Every kept (eligible-induced) undirected edge appears twice in the
    ELL, so the same-label neighbour count sums to 2·intra_pos;
    cap-dropped edges are always cut (their ineligible endpoint is a
    singleton) so m_edges accounts for them exactly:
      cost = (m − intra_pos) + (intra_pairs − intra_pos).
    """
    agree = _label_agree_counts(ell, labels, use_kernel=use_kernel,
                                la_rows=la_rows)
    intra_pos2 = jnp.sum(agree, axis=1)
    sizes = _cluster_sizes(labels)
    intra_pairs = jnp.sum(sizes * (sizes - 1) // 2, axis=1)
    return m_edges - intra_pos2 + intra_pairs


def _minmax_cost_pass(ell, labels, m_edges, *, use_kernel: bool,
                      la_rows: Optional[int]) -> jnp.ndarray:
    """Worst-vertex disagreement (min-max objective, arXiv 2502.12519).

    Per vertex v: cut positive edges (deg(v) − samelabel(v)) plus missing
    intra-cluster edges (|C(v)| − 1 − samelabel(v)); the entry's cost is
    the maximum over its vertices. Evaluated on the eligible-induced
    (degree-capped) subgraph the packed tensors carry — cap-dropped edges
    are not attributed to their endpoints (see the module caveat);
    :func:`minmax_cost_host` is the full-graph oracle.
    """
    B, R, W = ell.shape
    agree = _label_agree_counts(ell, labels, use_kernel=use_kernel,
                                la_rows=la_rows)
    deg = jnp.sum(ell != R, axis=2).astype(jnp.int32)
    sizes = _cluster_sizes(labels)
    size_of = jnp.take_along_axis(sizes, labels, axis=1)
    per_vertex = (deg - agree) + (size_of - 1 - agree)
    return jnp.max(per_vertex, axis=1)


# ---------------------------------------------------------------------------
# Registries.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketProgramSpec:
    """One registered clustering method of the batch engine.

    ``program`` is the *program family* — the compiled-program cache
    identity. Methods that run the same device computation and differ only
    in host-side planning (``'pivot'`` vs ``'pivot_raw'``) share one
    family, so the resident program cache never fragments across them.
    ``degree_cap`` drives planning: whether :func:`repro.core.plan.
    plan_graph` resolves the Theorem 26 threshold (capped eligibility) or
    marks every vertex eligible. ``constant_rounds`` is advisory metadata:
    True for straight-line programs with a static round budget.
    """

    method: str
    program: str
    rounds_body: Callable
    degree_cap: bool
    constant_rounds: bool
    description: str


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """One registered cost objective, selectable orthogonally to method."""

    objective: str
    cost_pass: Callable
    description: str


_METHODS: Dict[str, BucketProgramSpec] = {}
_OBJECTIVES: Dict[str, ObjectiveSpec] = {}


def register_method(spec: BucketProgramSpec) -> BucketProgramSpec:
    if spec.method in _METHODS:
        raise ValueError(f"method {spec.method!r} already registered")
    _METHODS[spec.method] = spec
    return spec


def register_objective(spec: ObjectiveSpec) -> ObjectiveSpec:
    if spec.objective in _OBJECTIVES:
        raise ValueError(f"objective {spec.objective!r} already registered")
    _OBJECTIVES[spec.objective] = spec
    return spec


def registered_methods() -> Tuple[str, ...]:
    """Batch-engine method names, sorted — the single source user-facing
    docs and error messages list methods from."""
    return tuple(sorted(_METHODS))


def registered_objectives() -> Tuple[str, ...]:
    return tuple(sorted(_OBJECTIVES))


def method_spec(method: str) -> BucketProgramSpec:
    try:
        return _METHODS[method]
    except KeyError:
        raise ValueError(
            f"batch engine supports methods {registered_methods()}, "
            f"got {method!r}") from None


def objective_spec(objective: str) -> ObjectiveSpec:
    try:
        return _OBJECTIVES[objective]
    except KeyError:
        raise ValueError(
            f"batch engine supports objectives {registered_objectives()}, "
            f"got {objective!r}") from None


register_method(BucketProgramSpec(
    method="pivot", program="pivot", rounds_body=_pivot_rounds_body,
    degree_cap=True, constant_rounds=False,
    description="degree-capped MIS+PIVOT (Corollary 28, the paper's "
                "headline algorithm)"))
register_method(BucketProgramSpec(
    method="pivot_raw", program="pivot", rounds_body=_pivot_rounds_body,
    degree_cap=False, constant_rounds=False,
    description="PIVOT without the degree cap (baseline comparator)"))
register_method(BucketProgramSpec(
    method="precluster", program="precluster",
    rounds_body=_precluster_rounds_body,
    degree_cap=True, constant_rounds=True,
    description="constant-round neighbourhood-agreement pre-clustering "
                "(arXiv 2106.08448)"))

register_objective(ObjectiveSpec(
    objective="disagree", cost_pass=_disagree_cost_pass,
    description="total disagreement count (the paper's objective)"))
register_objective(ObjectiveSpec(
    objective="minmax", cost_pass=_minmax_cost_pass,
    description="worst-vertex disagreement (min-max objective, arXiv "
                "2502.12519; scored on the eligible-induced subgraph)"))


# ---------------------------------------------------------------------------
# Composed bucket implementation (what the executor jit-compiles).
# ---------------------------------------------------------------------------


def bucket_impl(ell, ranks_p, elig_p, m_edges, k: int, use_kernel: bool,
                block_rows: Optional[Tuple[int, int]],
                program: str, objective: str):
    """Cluster + cost + select every graph of one shape bucket on device.

    ``rounds_body × cost_pass`` composed with the shared best-of-k argmin
    harvest: the first cost minimum wins (``jnp.argmin`` tie-break), the
    same rule as the host loop's strict ``<`` — only winners cross back to
    the host. ``program`` is a program *family* name; resolution through
    the method registry happens in the executor so two methods of one
    family compile (and cache) identical programs.
    """
    spec = _METHODS[program]
    obj = _OBJECTIVES[objective]
    B, R, W = ell.shape
    nm_rows, la_rows = block_rows if block_rows is not None else (None, None)
    labels, rounds = spec.rounds_body(ell, ranks_p, elig_p,
                                      use_kernel=use_kernel, nm_rows=nm_rows)
    costs = obj.cost_pass(ell, labels, m_edges, use_kernel=use_kernel,
                          la_rows=la_rows)
    G = B // k
    cost_g = costs.reshape(G, k)
    picked = jnp.argmin(cost_g, axis=1).astype(jnp.int32)
    labels_win = jnp.take_along_axis(
        labels.reshape(G, k, R), picked[:, None, None], axis=1)[:, 0]
    costs_win = jnp.take_along_axis(cost_g, picked[:, None], axis=1)[:, 0]
    rounds_win = jnp.take_along_axis(
        rounds.reshape(G, k), picked[:, None], axis=1)[:, 0]
    return labels_win, costs_win, picked, rounds_win


# ---------------------------------------------------------------------------
# Numpy host oracles.
# ---------------------------------------------------------------------------

_INT32_INF = np.int32(2**31 - 1)


def _host_adjacency(n: int, edges: np.ndarray):
    adj = [[] for _ in range(n)]
    for u, v in np.asarray(edges, dtype=np.int64):
        adj[int(u)].append(int(v))
        adj[int(v)].append(int(u))
    return [sorted(a) for a in adj]


def precluster_host(n: int, edges: np.ndarray, eligible: np.ndarray,
                    ranks: np.ndarray):
    """Numpy reference of the precluster device program for one graph.

    ``edges`` is the *eligible-induced* kept undirected edge list (what
    the ELL pack carries), ``ranks`` the full permutation ranks. Returns
    ``(labels (n,) int32, rounds int)`` bit-identical to the device
    program's per-entry outputs — same integer agreement predicate, same
    synchronous min-rank propagation over :data:`PRECLUSTER_ROUNDS` hops,
    same rank→vertex capture.
    """
    eligible = np.asarray(eligible, dtype=bool)
    ranks = np.asarray(ranks, dtype=np.int64)
    adj = _host_adjacency(n, edges)
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    sets = [set(a) for a in adj]

    agree_adj = [[] for _ in range(n)]
    for u, v in np.asarray(edges, dtype=np.int64):
        u, v = int(u), int(v)
        common = len(sets[u] & sets[v])
        sym_diff = deg[u] + deg[v] - 2 * common - 2
        if BETA_DEN * sym_diff < BETA_NUM * (max(deg[u], deg[v]) + 1):
            agree_adj[u].append(v)
            agree_adj[v].append(u)

    state = np.where(eligible, ranks, np.int64(_INT32_INF))
    rounds = 0
    for _ in range(PRECLUSTER_ROUNDS):
        nxt = state.copy()
        for u in range(n):
            for v in agree_adj[u]:
                if state[v] < nxt[u]:
                    nxt[u] = state[v]
        if np.any(nxt != state):
            rounds += 1
        state = nxt

    v_of_rank = np.empty(n, dtype=np.int64)
    v_of_rank[ranks] = np.arange(n)
    own = np.arange(n, dtype=np.int64)
    labels = np.where(state < _INT32_INF, v_of_rank[np.minimum(state, n - 1)],
                      own)
    labels = np.where(eligible, labels, own)
    return labels.astype(np.int32), rounds


def minmax_cost_host(n: int, edges: np.ndarray,
                     labels: np.ndarray) -> int:
    """Numpy min-max oracle: worst-vertex disagreement over ``edges``.

    Pass the full undirected positive edge list for the true objective, or
    the eligible-induced kept list to mirror the device cost pass exactly.
    """
    labels = np.asarray(labels, dtype=np.int64)
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    deg = np.zeros(n, dtype=np.int64)
    same = np.zeros(n, dtype=np.int64)
    if len(edges):
        np.add.at(deg, edges[:, 0], 1)
        np.add.at(deg, edges[:, 1], 1)
        agree = labels[edges[:, 0]] == labels[edges[:, 1]]
        np.add.at(same, edges[agree][:, 0], 1)
        np.add.at(same, edges[agree][:, 1], 1)
    sizes = np.bincount(labels, minlength=n if n else 1)
    size_of = sizes[labels] if n else np.zeros(0, dtype=np.int64)
    per_vertex = (deg - same) + (size_of - 1 - same)
    return int(per_vertex.max(initial=0))


__all__ = [
    "UNDECIDED",
    "IN_MIS",
    "REMOVED",
    "PRECLUSTER_ROUNDS",
    "BETA_NUM",
    "BETA_DEN",
    "BucketProgramSpec",
    "ObjectiveSpec",
    "register_method",
    "register_objective",
    "registered_methods",
    "registered_objectives",
    "method_spec",
    "objective_spec",
    "bucket_impl",
    "precluster_host",
    "minmax_cost_host",
    "_gather_rows",
]
