"""Sharding plans: logical axes → mesh axes, resolved per (arch × shape).

Production mesh: ``(data=16, model=16)`` single pod, ``(pod=2, data=16,
model=16)`` multi-pod. The plan maps *logical* tensor axes to mesh axes with
per-architecture divisibility checks (e.g. smollm's 9 heads cannot shard
16-way — attention weights replicate over 'model' while the MLP still
tensor-parallelizes; grok's 8 experts go tensor-parallel *inside* experts
since 8 % 16 != 0, olmoe's 64 experts use expert parallelism).

Logical axes used by the model code:
  batch     — activation batch dim                (pod, data)
  embed     — d_model rows of weight matrices     (FSDP/ZeRO shard: data)
  ff        — MLP hidden                          (model)
  heads     — q-head dim of attention weights     (model if divisible)
  kv        — kv-head dim                         (model if divisible)
  vocab     — vocabulary dim                      (model)
  experts   — expert dim of stacked MoE weights   (model if divisible)
  expert_ff — per-expert hidden                   (model if experts aren't)
  seq_kv    — sequence dim of decode KV caches    (model [+ data if B small])
  stack     — scan-stacked layer dim              (never sharded)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    axes: Dict[str, object]          # logical name -> mesh axis (str/tuple/None)
    active: bool = True              # False = single-device smoke mode

    def P(self, *logical) -> P:
        return P(*[self.axes.get(name) for name in logical])

    @staticmethod
    def null() -> "ShardingPlan":
        return ShardingPlan(axes={}, active=False)


def _divides(a: int, b: int) -> bool:
    return b > 0 and a % b == 0


def resolve_plan(cfg: ModelConfig, shape: Optional[ShapeConfig],
                 mesh_axes: Dict[str, int],
                 expert_mode: str = "auto") -> ShardingPlan:
    """Build the plan for a config on a mesh given as {axis_name: size}.

    ``expert_mode``: 'auto' (EP when E divides the model axis, else TP
    inside experts), or force 'ep'/'tp' — the H1 hillclimb lever (see
    EXPERIMENTS.md §Perf: EP's dispatch scatter traffic vs TP's activation
    all-reduces).
    """
    tp = "model" if "model" in mesh_axes else None
    tp_size = mesh_axes.get("model", 1)
    data_axes: Tuple[str, ...] = tuple(
        a for a in ("pod", "data") if a in mesh_axes)
    data_size = 1
    for a in data_axes:
        data_size *= mesh_axes[a]

    axes: Dict[str, object] = {}
    axes["stack"] = None
    axes["batch"] = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None)

    # FSDP/ZeRO axes for the d_model rows of weights (and optimizer states).
    # Spans the pod axis too: capacity beats cross-pod gather bandwidth for
    # the ≥300B models (grok-1 only fits the 2-pod mesh; the roofline's
    # collective term prices the cross-pod gathers).
    span = 1
    for a in data_axes:
        span *= mesh_axes[a]
    if data_axes and _divides(cfg.d_model, span):
        axes["embed"] = data_axes if len(data_axes) > 1 else data_axes[0]
    elif "data" in mesh_axes and _divides(cfg.d_model, mesh_axes["data"]):
        axes["embed"] = "data"
    else:
        axes["embed"] = None

    axes["ff"] = tp if _divides(cfg.d_ff, tp_size) else None
    axes["vocab"] = tp if _divides(cfg.padded_vocab, tp_size) else None
    axes["heads"] = tp if _divides(cfg.num_heads, tp_size) else None
    axes["kv"] = tp if _divides(cfg.num_kv_heads, tp_size) else None

    if cfg.num_experts:
        use_ep = _divides(cfg.num_experts, tp_size)
        if expert_mode == "tp":
            use_ep = False
        elif expert_mode == "ep" and not use_ep:
            raise ValueError(f"E={cfg.num_experts} not divisible by tp")
        if use_ep:
            axes["experts"] = tp
            axes["expert_ff"] = None
            # EP expert weights are E/|model| small — skip FSDP on d so the
            # ep_local shard_map doesn't re-gather them (measured regression).
            axes["expert_embed"] = None
        else:
            axes["experts"] = None
            ff = cfg.moe_d_ff or cfg.d_ff
            axes["expert_ff"] = tp if _divides(ff, tp_size) else None
            axes["expert_embed"] = axes["embed"]
    else:
        axes["experts"] = None
        axes["expert_ff"] = None
        axes["expert_embed"] = axes["embed"]

    # Capacity dim of MoE expert batches: None (constraint measured worse —
    # §Perf H2 iter 3); kept as an opt-in lever.
    axes["moe_c"] = None

    # Decode KV-cache sequence sharding: primary over model; if the batch is
    # too small to occupy the data axes (long_500k B=1), fold them into the
    # sequence shard too.
    seq_axes = []
    if tp:
        seq_axes.append(tp)
    if shape is not None and shape.kind == "decode":
        batch_axes = axes["batch"]
        if batch_axes is not None:
            b_axes = (batch_axes,) if isinstance(batch_axes, str) else batch_axes
            span = 1
            for a in b_axes:
                span *= mesh_axes[a]
            if shape.global_batch < span:
                # Free the data axes for sequence sharding.
                axes["batch"] = None
                seq_axes = [a for a in ("data", "model", "pod")
                            if a in mesh_axes]
    axes["seq_kv"] = tuple(seq_axes) if len(seq_axes) > 1 else (
        seq_axes[0] if seq_axes else None)

    return ShardingPlan(axes=axes, active=bool(mesh_axes))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


__all__ = ["ShardingPlan", "resolve_plan", "mesh_axis_sizes"]
