"""Benchmark harness: one function per paper claim + system benchmarks.

Prints ``name,us_per_call,derived`` CSV. The ``derived`` column carries the
quantity each theorem bounds (approximation ratio, round count, component
size / log n, ...) — see benchmarks/paper_claims.py docstrings.

    PYTHONPATH=src python -m benchmarks.run [--only substring]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this substring")
    args = ap.parse_args()

    from . import paper_claims, system_bench

    benches = list(paper_claims.ALL) + list(system_bench.ALL)
    print("name,us_per_call,derived")
    failed = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived:.4f}", flush=True)
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{bench.__name__},ERROR,{type(e).__name__}",
                  file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
