"""Randomized greedy MIS — sequential oracle and round-parallel simulation.

Greedy MIS w.r.t. a permutation π (paper footnote 2): iterate vertices in
π-order; add a vertex iff no earlier neighbour was added. The parallel
simulation repeatedly selects *local minima* of the permutation rank among
undecided vertices — by Fischer–Noever (Theorem 5) the number of parallel
rounds equals the longest dependency path, which is ``O(log n)`` w.h.p., and
the resulting set is **identical** to the sequential greedy MIS for the same
π (tested bit-exactly).

PIVOT's cluster assignment (each removed vertex joins the *first* pivot in
π-order among its neighbours) equals "min-rank MIS neighbour" and is computed
in a single post-pass (:func:`assign_to_min_rank_mis_neighbor`) — assigning
during the rounds would be wrong, since a smaller-rank MIS neighbour of a
vertex can become a winner in a *later* round than a larger-rank one.

The per-round hot loop — every undecided vertex computing the min rank over
its undecided neighbours — is exposed as :func:`neighbor_min_ranks`; the
Pallas TPU kernel ``repro.kernels.neighbor_min`` implements the same contract
with CSR tiles staged through VMEM and can be swapped in via ``use_kernel``.
"""

from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph

# Vertex status codes.
UNDECIDED = jnp.int32(0)
IN_MIS = jnp.int32(1)
REMOVED = jnp.int32(2)

INF_RANK = jnp.int32(2**31 - 1)


def random_permutation_ranks(n: int, key: jax.Array) -> jnp.ndarray:
    """rank[v] = position of v in a uniform-at-random permutation π."""
    perm = jax.random.permutation(key, n)
    ranks = jnp.zeros((n,), jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))
    return ranks


@lru_cache(maxsize=1024)
def _perm_ranks_batch_for(n: int):
    # One jitted vmap per vertex count, held in a bounded LRU: a long-lived
    # server seeing arbitrarily many distinct n must not accumulate one
    # resident executable per size forever (evicted sizes just recompile).
    return jax.jit(jax.vmap(lambda k: random_permutation_ranks(n, k)))


@lru_cache(maxsize=1024)
def _perm_ranks_single_for(n: int):
    # k=1 fastpath: the broadcast to a (1, n) batch happens inside the
    # trace, so a single-sample caller pays one dispatch instead of a host
    # jnp.stack plus the vmapped call. Bit-identical to the batch of one.
    return jax.jit(lambda k: random_permutation_ranks(n, k)[None])


def random_permutation_ranks_batch(n: int, keys) -> jax.Array:
    """Ranks for several keys of one graph in a single fused dispatch.

    Row ``i`` is bit-identical to ``random_permutation_ranks(n, keys[i])``
    (``jax.random.permutation`` is deterministic per key under ``vmap``;
    asserted in ``tests/test_mis.py``). The batch-engine packer uses this
    for the best-of-k sample keys of each graph: one async dispatch per
    graph instead of ``k`` eager permutation calls, which keeps host-side
    packing off the device's critical path. A single-key list (best-of-1,
    the serving default) skips the host-side key stack entirely — that
    stack is pure dispatch overhead when admission-time row builds issue
    one rank op per request.
    """
    if not isinstance(keys, jax.Array):
        keys = list(keys)
        if len(keys) == 1:
            return _perm_ranks_single_for(n)(keys[0])
        keys = jnp.stack(keys)
    return _perm_ranks_batch_for(n)(keys)


# ---------------------------------------------------------------------------
# Sequential oracle (numpy) — ground truth for tests.
# ---------------------------------------------------------------------------


def greedy_mis_sequential(g: Graph, ranks: np.ndarray) -> np.ndarray:
    """Sequential greedy MIS; returns bool mask of MIS membership."""
    n = g.n
    ranks = np.asarray(ranks)
    order = np.argsort(ranks, kind="stable")
    dst = np.asarray(g.dst)
    row = np.asarray(g.row_offsets)
    in_mis = np.zeros(n, dtype=bool)
    blocked = np.zeros(n, dtype=bool)
    for v in order:
        if blocked[v]:
            continue
        in_mis[v] = True
        for e in range(row[v], row[v + 1]):
            blocked[dst[e]] = True
    return in_mis


def pivot_sequential(g: Graph, ranks: np.ndarray) -> np.ndarray:
    """Sequential PIVOT (Ailon–Charikar–Newman): cluster labels per vertex."""
    n = g.n
    order = np.argsort(np.asarray(ranks), kind="stable")
    dst = np.asarray(g.dst)
    row = np.asarray(g.row_offsets)
    labels = np.full(n, -1, dtype=np.int32)
    for v in order:
        if labels[v] >= 0:
            continue
        labels[v] = v
        for e in range(row[v], row[v + 1]):
            u = dst[e]
            if u < n and labels[u] < 0:
                labels[u] = v
    return labels


# ---------------------------------------------------------------------------
# Round-parallel simulation (JAX).
# ---------------------------------------------------------------------------


def _masked_segment_min(g: Graph, vals_at_dst: jnp.ndarray,
                        mask_at_dst: jnp.ndarray) -> jnp.ndarray:
    """segment-min over COO edges: per src vertex, min of vals[dst] | mask[dst]."""
    n = g.n
    dst_ok = g.dst < n
    dst_idx = jnp.minimum(g.dst, n - 1)
    vals = jnp.where(dst_ok & mask_at_dst[dst_idx], vals_at_dst[dst_idx], INF_RANK)
    seg = jax.ops.segment_min(
        vals, jnp.minimum(g.src, n), num_segments=n + 1, indices_are_sorted=True
    )
    return seg[:n]


def neighbor_min_ranks(g: Graph, ranks: jnp.ndarray, active: jnp.ndarray,
                       use_kernel: bool = False,
                       ell: jnp.ndarray | None = None) -> jnp.ndarray:
    """For every vertex: min rank over *active* neighbours (INF if none).

    ``ell`` is the precomputed ELL adjacency for the Pallas kernel path
    (built once per MIS run, outside the round loop).
    """
    if use_kernel:
        from repro.kernels import ops as _kops  # local import: kernels optional
        from repro.kernels.neighbor_min import ell_from_graph, pad_state

        if ell is None:
            ell = ell_from_graph(g)
        rp, ap = pad_state(jnp.asarray(ranks, jnp.int32), active)
        return _kops.neighbor_min_ell(ell, rp, ap)
    return _masked_segment_min(g, ranks, active)


class MISState(NamedTuple):
    status: jnp.ndarray      # (n,) int32 in {UNDECIDED, IN_MIS, REMOVED}
    rounds: jnp.ndarray      # scalar int32 — parallel rounds executed


def _mis_round(g: Graph, ranks: jnp.ndarray, state: MISState,
               eligible: jnp.ndarray, use_kernel: bool = False,
               ell: jnp.ndarray | None = None) -> MISState:
    """One parallel round restricted to ``eligible`` vertices.

    Local minima among undecided∩eligible join the MIS; their undecided
    neighbours (eligible or not) are removed.
    """
    und = (state.status == UNDECIDED) & eligible
    nmin = neighbor_min_ranks(g, ranks, und, use_kernel=use_kernel, ell=ell)
    winners = und & (ranks < nmin)

    # Any undecided vertex adjacent to a winner is removed.
    wmin = _masked_segment_min(g, ranks, winners)
    hit = (state.status == UNDECIDED) & (~winners) & (wmin < INF_RANK)

    status = jnp.where(winners, IN_MIS, state.status)
    status = jnp.where(hit, REMOVED, status)
    return MISState(status=status, rounds=state.rounds + 1)


@partial(jax.jit, static_argnames=("use_kernel", "ell_width"))
def _greedy_mis_parallel_impl(g: Graph, ranks: jnp.ndarray,
                              eligible: jnp.ndarray | None,
                              use_kernel: bool, ell_width: int) -> MISState:
    n = g.n
    if eligible is None:
        eligible = jnp.ones((n,), bool)
    status0 = jnp.where(eligible, UNDECIDED, REMOVED)
    init = MISState(status=status0, rounds=jnp.int32(0))

    ell = None
    if use_kernel:
        from repro.kernels.neighbor_min import ell_from_graph

        # Built once, loop-invariant (lives outside the while body).
        ell = ell_from_graph(g, width=ell_width)

    def cond(state: MISState):
        return jnp.any(state.status == UNDECIDED)

    def body(state: MISState):
        return _mis_round(g, ranks, state, eligible, use_kernel=use_kernel,
                          ell=ell)

    return jax.lax.while_loop(cond, body, init)


def greedy_mis_parallel(g: Graph, ranks: jnp.ndarray,
                        eligible: jnp.ndarray | None = None,
                        use_kernel: bool = False) -> MISState:
    """Full round-parallel greedy MIS via ``lax.while_loop``.

    ``eligible`` restricts the instance to an induced subgraph (used by the
    Theorem 26 degree cap); ineligible vertices start REMOVED and never
    participate. Returns final state; ``state.rounds`` is the dependency
    depth actually realized (Fischer–Noever: O(log n) w.h.p.).
    """
    ell_width = max(1, g.max_degree()) if use_kernel else 0
    return _greedy_mis_parallel_impl(g, ranks, eligible, use_kernel, ell_width)


def assign_to_min_rank_mis_neighbor(g: Graph, ranks: jnp.ndarray,
                                    in_mis: jnp.ndarray) -> jnp.ndarray:
    """PIVOT post-pass: label every vertex with its min-rank MIS neighbour.

    MIS vertices label themselves. Non-MIS vertices take the MIS neighbour of
    minimum rank (maximality guarantees one exists). One MPC round
    (convergecast) in the cost model.
    """
    n = g.n
    wmin = _masked_segment_min(g, ranks, in_mis)
    rank_to_v = jnp.zeros((n,), jnp.int32).at[ranks].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    pivot = rank_to_v[jnp.minimum(wmin, n - 1)]
    own = jnp.arange(n, dtype=jnp.int32)
    return jnp.where(in_mis, own, jnp.where(wmin < INF_RANK, pivot, own))


def greedy_mis_rounds_trace(g: Graph, ranks: jnp.ndarray,
                            max_rounds: int = 100_000) -> Tuple[MISState, list]:
    """Python-stepped variant that records per-round stats (for benchmarks)."""
    n = g.n
    state = MISState(status=jnp.zeros((n,), jnp.int32), rounds=jnp.int32(0))
    eligible = jnp.ones((n,), bool)
    step = jax.jit(lambda s: _mis_round(g, ranks, s, eligible))
    trace = []
    for _ in range(max_rounds):
        und = int(jnp.sum(state.status == UNDECIDED))
        if und == 0:
            break
        state = step(state)
        trace.append(
            {
                "round": int(state.rounds),
                "undecided_before": und,
                "mis_size": int(jnp.sum(state.status == IN_MIS)),
            }
        )
    return state, trace


def dependency_depth(g: Graph, ranks) -> int:
    """Longest dependency path realized by the parallel simulation (= rounds)."""
    state = greedy_mis_parallel(g, jnp.asarray(ranks, jnp.int32))
    return int(state.rounds)


__all__ = [
    "UNDECIDED",
    "IN_MIS",
    "REMOVED",
    "INF_RANK",
    "MISState",
    "random_permutation_ranks",
    "greedy_mis_sequential",
    "pivot_sequential",
    "greedy_mis_parallel",
    "greedy_mis_rounds_trace",
    "assign_to_min_rank_mis_neighbor",
    "neighbor_min_ranks",
    "dependency_depth",
    "_mis_round",
    "_masked_segment_min",
]
