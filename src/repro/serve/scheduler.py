"""Pluggable scheduling-policy layer: *which* bucket flushes, *when*, at
*what* sub-batch size.

The serving analogue of the paper's MPC resource question. Cohen-Addad et
al. get constant rounds by being deliberate about what each round does and
how machines are loaded — per-round compute is never the bottleneck, the
round/launch schedule is. In this repo the "round" is a bucket flush and
the "machines" are the in-flight device programs, so the scheduling
decisions (flush triggers, admission control, load balancing across bucket
queues) deserve their own layer instead of being hard-coded into
:class:`~repro.serve.cluster_batcher.ClusterBatcher`. The batcher keeps
the *mechanics* — queues, staging leases, packing, harvest — and delegates
every *decision* to a :class:`SchedulerPolicy`:

* :class:`FullBucketPolicy` — flush a bucket only when it holds
  ``max_batch`` requests. MPC analogue: run a round only with machines at
  full memory load, maximizing work amortized per round (the paper's
  O(n·λ) total-memory budget spent in as few rounds as possible).
* :class:`DeadlinePolicy` — full buckets, plus flush any bucket whose
  oldest request has waited ``max_wait`` (a partial, pow2-padded
  sub-batch). MPC analogue: the constant-*round* guarantee itself — no
  item's round count depends on what the rest of the stream does.
* :class:`AdaptivePolicy` — replaces the static ``max_in_flight`` knob
  with a dynamic admission window derived from executor telemetry: keep
  ``ceil(EWMA(flush service time) / EWMA(assemble time))`` flushes in
  flight —
  enough that the host never leaves the device idle, no more than that so
  queueing delay is not hidden inside the engine. MPC analogue: sizing
  the number of machines to the observed round time instead of fixing it
  up front.
* :class:`CoalescingPolicy` — work-stealing across bucket queues: when a
  bucket flushes, requests starving in a *compatible smaller* ``(R', W')``
  bucket (``R' ≤ R, W' ≤ W``, same bucket-program method — a flush runs
  exactly one registered method) are promoted into the flush via
  :func:`repro.core.plan.promote_plan`, so no queue waits unboundedly
  behind a hot one. MPC analogue: migrating a straggler machine's items
  into a busier machine's round — sound here because a graph that fits a
  small ``(R, W)`` memory budget trivially fits a larger one, and the
  clustering of each packed entry is independent of its neighbours in the
  batch (which is also why promotion is bit-exact).
* :class:`CostAwareCoalescingPolicy` — coalescing with the steal *priced*
  (:class:`~repro.serve.costmodel.FlushCostModel`): a steal is taken only
  when the deadline slack it saves covers the pow2 pad inflation, the
  promoted-row waste and any compile the inflated batch axis would pay —
  otherwise it is trimmed to the slots that ride existing padding for
  free. Its ``on_retire`` additionally feeds bucket-shape heat
  (:class:`~repro.serve.costmodel.ShapeHeat`) to the compiled-program
  LRU's ``touch``/``pin`` surface, so hot shapes outlive cold-shape
  churn. MPC analogue: the paper's per-machine O(n^δ) budget accounting —
  Cohen-Addad et al. and Behnezhad et al. get their constant round counts
  precisely by pricing what each round carries; migrating an item into a
  round is only sound when it does not blow the budget the round was
  priced at. Cost only ever decides *whether* a steal happens, never what
  a flush computes, so the bit-exactness contract is untouched.

Policies see three read-only inputs: the bucket queues (admission-ordered
request lists), the engine clock's ``now``, and a :class:`FlushTelemetry`
(per-bucket flush latency EWMAs/percentiles fed by the executor layer,
plus the current in-flight count). They return :class:`FlushDecision`
values — bucket key, sub-batch size, and optionally which queues to steal
from — and the batcher executes them without second-guessing.

The queues contain only *primary* requests — work that will actually pack
a device row. Admissions the batcher's result cache retires immediately,
and single-flight subscribers riding an identical queued/in-flight
request, never enter a queue (and skip the ``on_admit`` gate: they add no
device work to the window it protects). A policy can therefore trust
``len(queue)`` as the exact row count a flush of that queue packs, and
queue ages as the ages of real pending device work — subscribed
duplicates are never double-counted in depth or age.

Determinism: policies only ever read the injected engine clock (``now``)
and telemetry; they never touch wall-clock time themselves, so tests and
simulators drive them with virtual clocks and fabricated telemetry.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

import numpy as np

# Queue identity: (method, R, W) — the registered bucket program that will
# run the flush plus the padded ELL shape it packs into. (Telemetry and the
# policies also tolerate legacy bare (R, W) keys — the method prefix is
# whatever precedes the trailing shape pair — but the engine always keys by
# the full GraphPlan.queue_key.)
BucketKey = Tuple[str, int, int]


@dataclasses.dataclass(frozen=True)
class FlushDecision:
    """One flush the policy wants executed.

    ``bucket`` is the ``(method, R, W)`` queue the flush packs from (the
    registered bucket program plus the padded shape); ``count`` requests
    are taken (oldest first) from that bucket's own queue; ``steal`` names
    extra ``(source_bucket, count)`` groups to promote into the same flush
    (their plans are re-targeted at the decision's shape via
    :func:`repro.core.plan.promote_plan` — every source must satisfy
    ``R' ≤ R and W' ≤ W`` **and run the same method**: a bucket program
    runs exactly one registered method per flush, so the batcher refuses a
    cross-method steal with ``ValueError``). The batcher pops stolen
    requests from the *front* of each source queue, so a steal always
    names that queue's oldest unconsumed requests. ``deadline`` marks the
    flush as forced by a wait budget, for stats accounting only.
    """

    bucket: BucketKey
    count: int
    steal: Tuple[Tuple[BucketKey, int], ...] = ()
    deadline: bool = False


class FlushTelemetry:
    """Rolling flush-latency telemetry — the policies' stats surface.

    Host packing work is accounted as two separate streams since the
    admission-time packing split (PR 8):

    * **build** — the per-request :func:`~repro.core.plan.
      build_packed_rows` time, recorded by the batcher at admission via
      :meth:`record_build`. It is not part of any flush's wall.
    * **assemble** — the per-bucket staging assembly time stamped on each
      :class:`~repro.core.executor.InFlightBucket` (the only host packing
      cost left on the flush critical path), fed here on harvest together
      with the submit→fetch wall time.

    Policies read the EWMAs (adaptive in-flight control); benchmarks and
    ``ClusterStats`` read :meth:`summary` (per-bucket p50/p99). Bounded:
    at most ``window`` samples are retained per bucket shape.

    ``in_flight`` is refreshed by the batcher before every policy call —
    it is the number of submitted-but-unharvested flushes, the quantity
    admission control windows bound.
    """

    def __init__(self, window: int = 256, alpha: float = 0.2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.window = window
        self.alpha = alpha
        self.in_flight = 0
        self.total_flushes = 0
        self.total_builds = 0
        # Lifetime wall accumulators for the two host packing streams —
        # batch_bench emits these as fractions of the serve wall.
        self.total_build_s = 0.0
        self.total_assemble_s = 0.0
        self._ewma_wall: Optional[float] = None
        self._ewma_service: Optional[float] = None
        self._ewma_assemble: Optional[float] = None
        self._ewma_build: Optional[float] = None
        self._ewma_compile: Optional[float] = None
        self._per_bucket: Dict[BucketKey, dict] = {}

    def record(self, bucket: BucketKey, wall_s: float,
               assemble_s: float = 0.0, depth: int = 1,
               compile_s: Optional[float] = None) -> None:
        """Account one completed flush of shape ``bucket``.

        ``depth`` is how many flushes were in flight when this one was
        submitted (1 = it had the device to itself). Submit→fetch wall
        time includes queueing behind the ``depth − 1`` earlier flushes,
        so ``wall / depth`` estimates the per-flush *service* time — the
        quantity the adaptive window must use, or queue wait would feed
        back into a larger window which creates more queue wait.

        ``assemble_s`` is the flush's host bucket-assembly time (the
        pre-PR-8 ``pack_s``, minus the per-request row build that now
        happens at admission — see :meth:`record_build`).

        ``compile_s`` is the compile wall this flush paid (None on
        program-cache hits): subtracted to maintain a *compile-free* wall
        EWMA per bucket, the steady-state service estimate the cost
        model's own-flush steal credit reads — crediting a first flush's
        compile-inflated wall would overprice avoided flushes wildly.
        """
        a = self.alpha
        self.total_flushes += 1
        self.total_assemble_s += assemble_s
        self._ewma_wall = wall_s if self._ewma_wall is None \
            else a * wall_s + (1 - a) * self._ewma_wall
        service = wall_s / max(1, depth)
        self._ewma_service = service if self._ewma_service is None \
            else a * service + (1 - a) * self._ewma_service
        self._ewma_assemble = assemble_s if self._ewma_assemble is None \
            else a * assemble_s + (1 - a) * self._ewma_assemble
        rec = self._bucket_rec(bucket)
        rec["wall"].append(wall_s)
        rec["assemble"].append(assemble_s)
        rec["count"] += 1
        rec["ewma_wall"] = wall_s if rec["ewma_wall"] is None \
            else a * wall_s + (1 - a) * rec["ewma_wall"]
        wall_xc = max(0.0, wall_s - (compile_s or 0.0))
        rec["ewma_wall_xc"] = wall_xc if rec.get("ewma_wall_xc") is None \
            else a * wall_xc + (1 - a) * rec["ewma_wall_xc"]

    def record_build(self, bucket: BucketKey, build_s: float) -> None:
        """Account one request's admission-time row build for ``bucket``.

        Fed by the batcher right after :func:`~repro.core.plan.
        build_packed_rows`; per-request (not per-flush), so the stream's
        sample count is the prebuilt-admission count, not the flush count.
        """
        a = self.alpha
        self.total_builds += 1
        self.total_build_s += build_s
        self._ewma_build = build_s if self._ewma_build is None \
            else a * build_s + (1 - a) * self._ewma_build
        rec = self._bucket_rec(bucket)
        rec["build"].append(build_s)
        rec["builds"] += 1

    def _bucket_rec(self, bucket: BucketKey) -> dict:
        rec = self._per_bucket.get(bucket)
        if rec is None:
            rec = self._per_bucket[bucket] = {
                "wall": deque(maxlen=self.window),
                "assemble": deque(maxlen=self.window),
                "build": deque(maxlen=self.window),
                "compile": deque(maxlen=self.window),
                "count": 0,
                "builds": 0,
                "compiles": 0,
                "ewma_wall": None,
                "ewma_wall_xc": None,
                "ewma_compile": None,
            }
        return rec

    def record_compile(self, bucket: BucketKey, wall_s: float) -> None:
        """Account one observed compile wall for shape ``bucket``.

        The executor stamps ``compile_seconds`` on each in-flight handle
        that missed the program cache; the batcher feeds the samples here
        on harvest. Windowed like wall/assemble; the per-shape EWMA is the
        learned prior :meth:`~repro.serve.costmodel.FlushCostModel.
        compile_charge` prefers over its static ``compile_cost_s``.
        """
        a = self.alpha
        self._ewma_compile = wall_s if self._ewma_compile is None \
            else a * wall_s + (1 - a) * self._ewma_compile
        rec = self._bucket_rec(bucket)
        rec["compile"].append(wall_s)
        rec["compiles"] += 1
        rec["ewma_compile"] = wall_s if rec["ewma_compile"] is None \
            else a * wall_s + (1 - a) * rec["ewma_compile"]

    @property
    def ewma_wall(self) -> Optional[float]:
        """EWMA submit→fetch wall seconds across all buckets (None = no
        flush recorded yet)."""
        return self._ewma_wall

    @property
    def ewma_service(self) -> Optional[float]:
        """EWMA per-flush service seconds (wall normalized by the in-flight
        depth at submit) — the adaptive window's input."""
        return self._ewma_service

    @property
    def ewma_assemble(self) -> Optional[float]:
        """EWMA host bucket-assembly seconds per flush across all buckets
        (the pre-PR-8 ``ewma_pack``)."""
        return self._ewma_assemble

    @property
    def ewma_build(self) -> Optional[float]:
        """EWMA per-request admission-time row-build seconds (None until
        a prebuilt admission is recorded)."""
        return self._ewma_build

    @property
    def ewma_pack(self) -> Optional[float]:
        """Deprecated pre-PR-8 name of :attr:`ewma_assemble`."""
        return self._ewma_assemble

    def bucket_ewma_wall(self, bucket: BucketKey) -> Optional[float]:
        rec = self._per_bucket.get(bucket)
        return None if rec is None else rec["ewma_wall"]

    @property
    def ewma_compile(self) -> Optional[float]:
        """EWMA observed compile wall seconds across all buckets (None =
        no compile observed yet)."""
        return self._ewma_compile

    def bucket_ewma_compile(self, bucket: BucketKey) -> Optional[float]:
        rec = self._per_bucket.get(bucket)
        return None if rec is None else rec.get("ewma_compile")

    def bucket_ewma_wall_xc(self, bucket: BucketKey) -> Optional[float]:
        """Compile-free wall EWMA — the steady-state service estimate the
        cost model's own-flush steal credit is allowed to use (observed
        flushes only; no floor/global fallback)."""
        rec = self._per_bucket.get(bucket)
        return None if rec is None else rec.get("ewma_wall_xc")

    def samples(self, metric: str) -> list:
        """All retained samples of one metric, pooled across bucket shapes.

        ``metric`` is one of ``'wall'``, ``'assemble'``, ``'build'`` or
        ``'compile'`` (seconds, flush/record order within each bucket).
        Benchmarks use this for stream-wide percentiles that per-bucket
        :meth:`summary` entries cannot express. Bounded by the telemetry
        window: at most ``window`` samples per bucket shape survive.
        """
        out: list = []
        for rec in self._per_bucket.values():
            out.extend(rec.get(metric, ()))
        return out

    def summary(self) -> Dict[str, dict]:
        """Per-bucket-shape latency percentiles, JSON-ready (ms).

        Keys are ``"method:RxW"`` strings (bare ``"RxW"`` for legacy
        2-tuple keys); values carry flush counts, wall
        p50/p99, assemble p50/p99 and the wall EWMA — the fields the
        benchmarks emit so scheduling quality is tracked across PRs.
        Since the admission-time packing split (PR 8) the pre-PR-8
        ``pack_p50_ms``/``pack_p99_ms`` fields are renamed
        ``assemble_p50_ms``/``assemble_p99_ms`` (per-flush bucket
        assembly), and shapes with prebuilt admissions additionally carry
        ``builds_total``/``build_p50_ms``/``build_p99_ms`` (per-request
        admission-time row build). Counts are explicit about scope:
        ``flushes_total`` is the lifetime count for the bucket shape
        while ``window_samples`` is the number of retained samples the
        percentiles are computed over (at most ``window``) — a long-lived
        bucket's percentiles describe its recent flushes, not its
        lifetime.
        """
        out: Dict[str, dict] = {}
        for bucket, rec in sorted(self._per_bucket.items(),
                                  key=lambda kv: tuple(map(str, kv[0]))):
            *prefix, R, W = bucket
            label = f"{prefix[0]}:{R}x{W}" if prefix else f"{R}x{W}"
            wall = np.asarray(rec["wall"], dtype=np.float64)
            assemble = np.asarray(rec["assemble"], dtype=np.float64)
            entry = {
                "flushes_total": rec["count"],
                "window_samples": int(len(wall)),
            }
            if len(wall):       # a shape may have compile samples only
                entry.update(
                    wall_p50_ms=float(np.percentile(wall, 50)) * 1e3,
                    wall_p99_ms=float(np.percentile(wall, 99)) * 1e3,
                    assemble_p50_ms=float(np.percentile(assemble, 50)) * 1e3,
                    assemble_p99_ms=float(np.percentile(assemble, 99)) * 1e3,
                    wall_ewma_ms=rec["ewma_wall"] * 1e3,
                )
            if rec.get("builds"):
                build = np.asarray(rec["build"], dtype=np.float64)
                entry.update(
                    builds_total=rec["builds"],
                    build_p50_ms=float(np.percentile(build, 50)) * 1e3,
                    build_p99_ms=float(np.percentile(build, 99)) * 1e3,
                )
            if rec.get("compiles"):
                entry["compiles_total"] = rec["compiles"]
                entry["compile_wall_ewma_ms"] = rec["ewma_compile"] * 1e3
            out[label] = entry
        return out


@runtime_checkable
class SchedulerPolicy(Protocol):
    """Structural protocol the batcher's decision layer is swapped by.

    ``queues`` is always the batcher's live bucket → request-list mapping,
    admission-ordered (oldest first); policies must treat it as read-only.
    Requests expose at least ``admitted_at`` (engine-clock stamp).
    """

    name: str

    def on_admit(self, queues, now: float,
                 telemetry: FlushTelemetry) -> bool:
        """Admission gate, called *before* a request is queued. Returning
        False makes the engine raise ``AdmissionRejected`` (shed load)."""
        ...

    def select_flushes(self, queues, now: float,
                       telemetry: FlushTelemetry) -> List[FlushDecision]:
        """Decide which buckets flush now (called after every admit and on
        every poll)."""
        ...

    def on_retire(self, bucket: BucketKey,
                  telemetry: FlushTelemetry) -> None:
        """Notification that a flush of shape ``bucket`` was harvested
        (its latency is already recorded in ``telemetry``)."""
        ...


class FullBucketPolicy:
    """Today's throughput default, extracted: flush only full buckets.

    ``max_in_flight`` (optional) is the static admission window the
    pre-scheduler engine exposed: while that many flushes are in flight,
    ``on_admit`` refuses and the engine sheds load.
    """

    name = "full"

    def __init__(self, max_batch: int, max_in_flight: Optional[int] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.max_batch = max_batch
        self.max_in_flight = max_in_flight

    # -- admission ------------------------------------------------------

    def admission_window(self, telemetry: FlushTelemetry) -> Optional[int]:
        """Current in-flight bound (None = unbounded)."""
        return self.max_in_flight

    def on_admit(self, queues, now, telemetry) -> bool:
        window = self.admission_window(telemetry)
        return window is None or telemetry.in_flight < window

    # -- flush selection ------------------------------------------------

    def select_flushes(self, queues, now, telemetry) -> List[FlushDecision]:
        out: List[FlushDecision] = []
        for bucket, q in queues.items():
            avail = len(q)
            while avail >= self.max_batch:
                out.append(FlushDecision(bucket=bucket, count=self.max_batch))
                avail -= self.max_batch
        return out

    def on_retire(self, bucket, telemetry) -> None:
        pass


class DeadlinePolicy(FullBucketPolicy):
    """Full buckets plus ``max_wait``-bounded tail latency, extracted.

    Any bucket whose oldest *unconsumed* request has waited ``max_wait``
    engine-clock seconds flushes partially (the packer pads the sub-batch
    to a power of two, keeping compiles O(#buckets · log B)).
    """

    name = "deadline"

    def __init__(self, max_batch: int, max_wait: Optional[float] = None,
                 max_in_flight: Optional[int] = None):
        super().__init__(max_batch, max_in_flight=max_in_flight)
        if max_wait is not None and max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_wait = max_wait

    def select_flushes(self, queues, now, telemetry) -> List[FlushDecision]:
        out = super().select_flushes(queues, now, telemetry)
        if self.max_wait is None:
            return out
        consumed: Dict[BucketKey, int] = {}
        for d in out:
            consumed[d.bucket] = consumed.get(d.bucket, 0) + d.count
        for bucket, q in queues.items():
            used = consumed.get(bucket, 0)
            rest = len(q) - used
            if rest > 0 and now - q[used].admitted_at >= self.max_wait:
                out.append(FlushDecision(bucket=bucket, count=rest,
                                         deadline=True))
        return out


class AdaptivePolicy(DeadlinePolicy):
    """Dynamic in-flight window from observed flush latency.

    Replaces the static ``max_in_flight`` knob: the admission window is
    ``clamp(ceil(EWMA(service) / EWMA(assemble)), min_window,
    max_window)`` — the pipeline depth at which the host (assembling one
    flush in ``assemble`` seconds; the per-request row build happens at
    admission and is off this path) exactly keeps a device busy for
    ``service`` seconds per flush. Fewer in flight and the device idles
    between flushes; more and
    extra arrivals only queue *inside* the engine where the front-end
    cannot see or shed them. ``service`` is the submit→fetch wall time
    normalized by the in-flight depth at submit (queue-excluded) — raw
    wall time grows with the very depth this window sets, a positive
    feedback that would pin it at ``max_window``. Until telemetry exists
    (cold engine) the window is ``max_window``, so a cold start is never
    throttled by a guess.
    """

    name = "adaptive"

    def __init__(self, max_batch: int, max_wait: Optional[float] = None,
                 min_window: int = 1, max_window: int = 8):
        super().__init__(max_batch, max_wait=max_wait, max_in_flight=None)
        if not 1 <= min_window <= max_window:
            raise ValueError(
                f"need 1 <= min_window <= max_window, got "
                f"{min_window}..{max_window}")
        self.min_window = min_window
        self.max_window = max_window

    def admission_window(self, telemetry: FlushTelemetry) -> Optional[int]:
        service = telemetry.ewma_service
        assemble = telemetry.ewma_assemble
        if service is None or assemble is None or assemble <= 0.0:
            return self.max_window
        depth = math.ceil(service / assemble)
        return max(self.min_window, min(self.max_window, depth))


class CoalescingPolicy(DeadlinePolicy):
    """Work-stealing across bucket queues via shape promotion.

    Every flush decision (full or deadline) additionally *steals* requests
    waiting in compatible smaller buckets — ``(R', W')`` with ``R' ≤ R``
    and ``W' ≤ W``, **same method only** (a bucket program runs exactly
    one registered method per flush, so cross-method queues are never
    steal candidates no matter how starved; their own deadlines still
    bound them) — whose oldest request has waited at least
    ``steal_wait`` (default: ``max_wait / 2`` when a deadline is set,
    otherwise 0 = steal whenever there is room). Stolen requests are
    promoted into the flushing ``(R, W)`` shape by the batcher
    (:func:`repro.core.plan.promote_plan`), most-starved queue first, up
    to the flush's ``max_batch`` capacity. A bucket whose arrival rate is
    starved by a hot neighbour therefore retires at the hot bucket's flush
    cadence instead of waiting for its own fill or the end-of-stream
    drain. Promotion never changes an answer: clustering is per-entry and
    padding rows/width is inert (the bit-exactness contract, asserted in
    ``tests/test_scheduler.py``).

    Pair it with ``max_wait``: steals only ride flushes with spare room,
    and without a deadline the only flushes are *full* ones (``count ==
    max_batch``, zero room) — the policy would silently degenerate to
    full-bucket. :func:`make_policy` therefore requires ``max_wait`` for
    ``'coalesce'``; constructing the class directly without one is allowed
    for composition and tests.
    """

    name = "coalesce"

    def __init__(self, max_batch: int, max_wait: Optional[float] = None,
                 max_in_flight: Optional[int] = None,
                 steal_wait: Optional[float] = None):
        super().__init__(max_batch, max_wait=max_wait,
                         max_in_flight=max_in_flight)
        if steal_wait is None:
            steal_wait = max_wait / 2 if max_wait is not None else 0.0
        if steal_wait < 0:
            raise ValueError(f"steal_wait must be >= 0, got {steal_wait}")
        self.steal_wait = steal_wait

    def select_flushes(self, queues, now, telemetry) -> List[FlushDecision]:
        base = super().select_flushes(queues, now, telemetry)
        consumed: Dict[BucketKey, int] = {}
        for d in base:
            consumed[d.bucket] = consumed.get(d.bucket, 0) + d.count
        out: List[FlushDecision] = []
        for d in base:
            R, W = d.bucket[-2:]
            room = self.max_batch - d.count
            steals: List[Tuple[BucketKey, int]] = []
            if room > 0:
                cands = []
                for b2, q2 in queues.items():
                    if b2 == d.bucket:
                        continue
                    if b2[:-2] != d.bucket[:-2]:
                        # Cross-method: a bucket program runs exactly one
                        # registered method, so a 'precluster' queue can
                        # never be promoted into a 'pivot' flush (the
                        # batcher would refuse the decision with a
                        # ValueError). Its own deadline still bounds it.
                        continue
                    R2, W2 = b2[-2:]
                    if R2 > R or W2 > W:
                        continue        # would not fit the (R, W) budget
                    used = consumed.get(b2, 0)
                    rest = len(q2) - used
                    if rest <= 0:
                        continue
                    oldest = q2[used].admitted_at
                    if now - oldest < self.steal_wait:
                        continue        # not starving yet
                    cands.append((oldest, b2, rest))
                cands.sort()            # most-starved queue first
                for _, b2, rest in cands:
                    if room <= 0:
                        break
                    take = min(rest, room)
                    steals.append((b2, take))
                    consumed[b2] = consumed.get(b2, 0) + take
                    room -= take
            out.append(dataclasses.replace(d, steal=tuple(steals))
                       if steals else d)
        return out


class CostAwareCoalescingPolicy(CoalescingPolicy):
    """Coalescing with every steal priced by a :class:`FlushCostModel`.

    The age-only parent steals whenever a starving compatible bucket
    exists and the flush has room — even when promoting the stragglers
    inflates the pow2 sub-batch (empty device entries), pads every stolen
    row to a larger ``R``, or lands on a batch-axis shape whose program
    was never compiled. This subclass asks the cost model whether the
    deadline slack the steal saves covers that bill, and otherwise trims
    the steal to the prefix that rides existing padding for free
    (``group_pad(count) − count`` slots cost nothing). A rejected
    candidate is never stranded: its own ``max_wait`` deadline still
    fires, so the coalesce latency bound survives every rejection.

    When telemetry is cold the model abstains and the policy degrades to
    plain age-only coalescing — a cold engine is never throttled by a
    guess (the same discipline as :class:`AdaptivePolicy`).

    ``on_retire`` additionally feeds bucket-shape heat
    (:class:`~repro.serve.costmodel.ShapeHeat`) to the compiled-program
    LRU's ``touch``/``pin`` surface: the scheduler sees the retire stream,
    so it knows which shapes keep coming back long before the cache's own
    access order does — hot shapes outlive a churn of one-off cold shapes.

    Counters (``steals_accepted`` / ``steals_rejected`` /
    ``pad_entries_avoided``) are the policy's own observability surface,
    emitted by the benchmarks.
    """

    name = "cost"

    def __init__(self, max_batch: int, max_wait: Optional[float] = None,
                 max_in_flight: Optional[int] = None,
                 steal_wait: Optional[float] = None,
                 cost_model=None, heat=None):
        from .costmodel import FlushCostModel, ShapeHeat

        super().__init__(max_batch, max_wait=max_wait,
                         max_in_flight=max_in_flight, steal_wait=steal_wait)
        self.cost_model = cost_model if cost_model is not None \
            else FlushCostModel()
        self.heat = heat if heat is not None else ShapeHeat()
        self.steals_accepted = 0
        self.steals_rejected = 0
        self.pad_entries_avoided = 0

    def bind_engine(self, **kwargs) -> None:
        """Forwarded by the batcher at construction so pricing matches the
        engine's real execution profile (group padding, k, program sig)."""
        self.cost_model.bind_engine(**kwargs)

    def cost_stats(self) -> Dict[str, int]:
        """JSON-ready counters for benchmarks."""
        return {
            "steals_accepted": self.steals_accepted,
            "steals_rejected": self.steals_rejected,
            "pad_entries_avoided": self.pad_entries_avoided,
        }

    def select_flushes(self, queues, now, telemetry) -> List[FlushDecision]:
        base = super().select_flushes(queues, now, telemetry)
        # The parent plans steals assuming every earlier one executes, but
        # the batcher pops stolen requests from each source queue's
        # *front* — so once this policy trims a steal, later steals from
        # the same queue shift toward older entries at execution. Price
        # each steal group against the entries that will actually be
        # popped: native consumption (the parent's opening assumption)
        # plus the steals *kept* so far this tick.
        native: Dict[BucketKey, int] = {}
        for d in base:
            native[d.bucket] = native.get(d.bucket, 0) + d.count
        kept_from: Dict[BucketKey, int] = {}
        out: List[FlushDecision] = []
        for d in base:
            if not d.steal:
                out.append(d)
                continue
            flat: List[Tuple[BucketKey, float]] = []
            for src, cnt in d.steal:
                start = native.get(src, 0) + kept_from.get(src, 0)
                flat.extend((src, now - q.admitted_at)
                            for q in queues[src][start:start + cnt])
            keep = self._evaluate(d.bucket, d.count, flat, telemetry)
            self.steals_accepted += keep
            self.steals_rejected += len(flat) - keep
            # Keep the accepted prefix (most-starved first, the order the
            # parent built the steal list in), tracking kept counts per
            # source so later decisions re-anchor correctly.
            steals: List[Tuple[BucketKey, int]] = []
            kept = 0
            for src, cnt in d.steal:
                take = min(cnt, keep - kept)
                if take <= 0:
                    break
                steals.append((src, take))
                kept_from[src] = kept_from.get(src, 0) + take
                kept += take
            out.append(d if keep == len(flat)
                       else dataclasses.replace(d, steal=tuple(steals)))
        return out

    def release(self) -> None:
        """Drop this policy's program-cache pins (engine teardown)."""
        self.heat.release()

    def _evaluate(self, bucket, count, flat, telemetry) -> int:
        """How many of the candidate steals (a most-starved-first list of
        ``(source_bucket, age)``) to keep: the full set when it prices out,
        else the free prefix when *that* prices out, else none."""
        full = self.cost_model.price_steal(bucket, count, flat,
                                           self.max_wait, telemetry)
        if full.accepts(self.cost_model.hurdle):
            return len(flat)
        self.pad_entries_avoided += max(0, full.pad_entries_added)
        # Slots inside the already-padded group count are free of pow2
        # inflation; re-price just that prefix (promoted-row waste can
        # still reject it).
        free = max(0, self.cost_model.group_pad(count) - count)
        if free > 0 and free < len(flat):
            partial = self.cost_model.price_steal(bucket, count, flat[:free],
                                                  self.max_wait, telemetry)
            if partial.accepts(self.cost_model.hurdle):
                return free
        return 0

    def on_retire(self, bucket, telemetry) -> None:
        super().on_retire(bucket, telemetry)
        self.heat.on_retire(bucket)


POLICY_NAMES = ("full", "deadline", "adaptive", "coalesce", "cost")


def make_policy(spec=None, *, max_batch: int,
                max_wait: Optional[float] = None,
                max_in_flight: Optional[int] = None) -> SchedulerPolicy:
    """Resolve a policy argument: name, instance, or None (back-compat).

    ``None`` reproduces the pre-scheduler engine exactly: the deadline
    policy when ``max_wait`` is set, full-bucket otherwise, both carrying
    the static ``max_in_flight`` admission bound. ``'adaptive'`` uses
    ``max_in_flight`` (when given) as its ``max_window`` cap, since the
    dynamic window replaces the static knob.

    A :class:`SchedulerPolicy` *instance* carries its own knobs, so
    passing ``max_wait`` / ``max_in_flight`` alongside one is a conflict
    the instance would silently win — that raises ``ValueError`` instead
    (set the knobs on the policy itself).
    """
    if spec is None:
        spec = "deadline" if max_wait is not None else "full"
    if isinstance(spec, str):
        if spec == "full":
            return FullBucketPolicy(max_batch, max_in_flight=max_in_flight)
        if spec == "deadline":
            if max_wait is None:
                raise ValueError(
                    "policy='deadline' needs max_wait (the wait budget)")
            return DeadlinePolicy(max_batch, max_wait=max_wait,
                                  max_in_flight=max_in_flight)
        if spec == "adaptive":
            kwargs = {} if max_in_flight is None \
                else {"max_window": max_in_flight}
            return AdaptivePolicy(max_batch, max_wait=max_wait, **kwargs)
        if spec in ("coalesce", "cost"):
            if max_wait is None:
                raise ValueError(
                    f"policy={spec!r} needs max_wait: steals only ride "
                    "flushes with spare room, and without a deadline every "
                    "flush is full — the policy would silently act like "
                    "'full'")
            cls = CoalescingPolicy if spec == "coalesce" \
                else CostAwareCoalescingPolicy
            return cls(max_batch, max_wait=max_wait,
                       max_in_flight=max_in_flight)
        raise ValueError(f"unknown scheduling policy {spec!r}; expected one "
                         f"of {sorted(POLICY_NAMES)}")
    if isinstance(spec, SchedulerPolicy):
        conflicts = [name for name, val in
                     (("max_wait", max_wait), ("max_in_flight", max_in_flight))
                     if val is not None]
        if conflicts:
            raise ValueError(
                f"policy instance {type(spec).__name__} carries its own "
                f"schedule knobs; also passing {' and '.join(conflicts)} "
                "is a conflict the instance would silently ignore — set "
                "them on the policy itself")
        return spec
    raise TypeError(f"policy must be a name or SchedulerPolicy, "
                    f"got {type(spec).__name__}")


__all__ = [
    "BucketKey",
    "FlushDecision",
    "FlushTelemetry",
    "SchedulerPolicy",
    "FullBucketPolicy",
    "DeadlinePolicy",
    "AdaptivePolicy",
    "CoalescingPolicy",
    "CostAwareCoalescingPolicy",
    "POLICY_NAMES",
    "make_policy",
]
