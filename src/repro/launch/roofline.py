"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory     = bytes_accessed / (chips × 819e9 B/s HBM)
    collective = collective_bytes / (chips × 50e9 B/s ICI per link)

FLOPs/bytes sources. XLA's ``compiled.cost_analysis()`` counts a while-loop
body ONCE (verified empirically — a scan of 8 matmuls reports 1 matmul of
FLOPs), and every layer stack here is scanned. We therefore report BOTH:
``hlo_flops_raw`` (cost_analysis, undercounted) and the corrected values
obtained by walking the post-partitioning HLO with while-loop trip-count
multipliers (parsed from each loop condition's comparison constant — scans
lower to exactly that pattern). The same walk accumulates per-op collective
bytes (result-shape bytes × executions), which cost_analysis does not
expose at all. MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) is computed
from the config, and the ratio MODEL_FLOPS / HLO_FLOPs reports how much
compiled compute is "useful".
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]+?)\s+"
                    r"([\w\-]+)\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string
    (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _split_computations(hlo: str):
    """computation name -> its body lines; plus the ENTRY name."""
    comps = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        if ((line.startswith("%") or line.startswith("ENTRY"))
                and line.rstrip().endswith("{") and "->" in line):
            head = line.split()[1] if line.startswith("ENTRY") else (
                line.split()[0])
            cur = head.lstrip("%").rstrip("(")
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _participants(line: str, default: int) -> int:
    """Group size from replica_groups (iota `[G,P]<=[...]` or legacy
    `{{...},{...}}` format)."""
    rg = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if rg:
        return int(rg.group(2))
    rg = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if rg:
        return len(rg.group(1).split(","))
    stp = re.search(r"source_target_pairs=\{\{(.*)\}\}", line)
    if stp:
        return stp.group(1).count("{") + 1
    return default


_COLL_RE = re.compile(
    r"(?<![%\w.\-])(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_WHILE_RE = re.compile(r"(?<![%\w.\-])while\(")
_CALLLIKE_RE = re.compile(r"(?<![%\w.\-])(call|fusion|conditional)\(")


def _result_type(line: str) -> str:
    """Text between '= ' and the op call — the result type."""
    try:
        rhs = line.split(" = ", 1)[1]
    except IndexError:
        return ""
    m = _COLL_RE.search(rhs) or _WHILE_RE.search(rhs) or _CALLLIKE_RE.search(rhs)
    return rhs[: m.start()] if m else rhs


def collective_stats(hlo: str, default_participants: int = 1
                     ) -> CollectiveStats:
    """Walk the HLO from the entry computation, multiplying collective bytes
    by enclosing while-loop trip counts (``known_trip_count`` from XLA's
    backend_config — scans always carry it).

    Bytes per op = result-shape bytes x participants (global traffic) x
    loop multiplier. Async collectives are counted at their ``-start`` op
    (which carries replica_groups); a start's result is an (in, out) buffer
    tuple, so the max element is used as the wire size.
    """
    comps, entry = _split_computations(hlo)
    bytes_by = {k: 0.0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    if entry is None:
        return CollectiveStats(bytes_by, count_by)

    seen_stack = set()

    def walk(comp: str, mult: float):
        if comp not in comps or comp in seen_stack:
            return
        seen_stack.add(comp)
        for line in comps[comp]:
            if " = " not in line:
                continue
            mcoll = _COLL_RE.search(line)
            if mcoll:
                kind, suffix = mcoll.group(1), mcoll.group(2)
                if suffix == "-done":
                    continue
                type_str = _result_type(line)
                if suffix == "-start":
                    shapes = [_shape_bytes(f"{dt}[{dims}]")
                              for dt, dims in _SHAPE_RE.findall(type_str)]
                    b = max(shapes) if shapes else 0
                else:
                    b = _shape_bytes(type_str)
                parts = _participants(line, default_participants)
                bytes_by[kind] += b * parts * mult
                count_by[kind] += max(1, int(mult))
                continue
            if _WHILE_RE.search(line):
                body = _BODY_RE.search(line)
                trip_m = _TRIP_RE.search(line)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    walk(body.group(1), mult * trip)
                continue
            mcall = _CALLLIKE_RE.search(line)
            if mcall:
                if mcall.group(1) == "conditional":
                    br = _BRANCH_RE.search(line)
                    if br:
                        for c in br.group(1).split(","):
                            walk(c.strip().lstrip("%"), mult)
                else:
                    c = _CALLS_RE.search(line)
                    if c:
                        walk(c.group(1), mult)
        seen_stack.discard(comp)

    walk(entry, 1.0)
    return CollectiveStats(bytes_by, count_by)


# ---------------------------------------------------------------------------
# Analytic FLOPs/bytes per (config × shape) — scan-corrected ground truth.
# ---------------------------------------------------------------------------


def _attn_flops(cfg, tokens: int, kv_len: int) -> float:
    """Matmul FLOPs for attention projections + scores+values per token set."""
    d, hd = cfg.d_model, cfg.head_dim
    nq = cfg.num_heads * hd
    nkv = cfg.num_kv_heads * hd
    proj = 2.0 * tokens * d * (nq + 2 * nkv) + 2.0 * tokens * nq * d
    scores = 2.0 * tokens * kv_len * cfg.num_heads * hd * 2  # qk^T + pv
    return proj + scores


def _mlp_flops(cfg, tokens: int, ff: Optional[int] = None) -> float:
    f = ff or cfg.d_ff
    return 2.0 * tokens * cfg.d_model * f * 3


def forward_flops(cfg, batch: int, seq: int, kv_len: Optional[int] = None,
                  moe_impl: str = "sort", is_decode: bool = False) -> float:
    """Forward-pass matmul FLOPs (the quantity XLA would count, corrected).

    ``is_decode``: cross-attention K/V and encoder/image towers are cached —
    only the new token's q/self-kv projections and scores are paid.
    """
    t = batch * seq
    kv = kv_len if kv_len is not None else seq
    total = 0.0
    if cfg.family in ("dense", "vlm"):
        per = _attn_flops(cfg, t, kv) + _mlp_flops(cfg, t)
        if cfg.family == "vlm":
            g = cfg.num_layers // cfg.cross_attn_every
            n_self = cfg.num_layers - g
            total += n_self * (_attn_flops(cfg, t, kv) + _mlp_flops(cfg, t))
            timg = 0 if is_decode else batch * cfg.num_image_tokens
            d, hd = cfg.d_model, cfg.head_dim
            xproj = (2.0 * t * d * cfg.num_heads * hd
                     + 2.0 * timg * d * 2 * cfg.num_kv_heads * hd
                     + 2.0 * t * cfg.num_heads * hd * d)
            xscores = 2.0 * t * cfg.num_image_tokens * cfg.num_heads * hd * 2
            total += g * (xproj + xscores + _mlp_flops(cfg, t))
        else:
            total += cfg.num_layers * per
    elif cfg.family == "moe":
        ff = cfg.moe_d_ff or cfg.d_ff
        router = 2.0 * t * cfg.d_model * cfg.num_experts
        expert = _mlp_flops(cfg, t, ff) * cfg.experts_per_tok
        if moe_impl == "einsum":
            cap = t * cfg.experts_per_tok * 1.25
            expert = _mlp_flops(cfg, int(cap / max(1, t) * t), ff)
            expert = 2.0 * cap * cfg.d_model * ff * 3
            dispatch = 2.0 * t * cfg.num_experts * (
                cap / cfg.num_experts) * cfg.d_model * 2
            expert += dispatch
        total += cfg.num_layers * (_attn_flops(cfg, t, kv) + router + expert)
    elif cfg.family == "ssm":   # rwkv6
        d = cfg.d_model
        per_tm = 2.0 * t * d * d * 4 + 2.0 * t * d * d  # r,k,v,g proj + out
        h, n = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        per_wkv = 2.0 * t * h * n * n * 3               # scores/state/out
        per_cm = 2.0 * t * d * cfg.d_ff * 2 + 2.0 * t * d * d
        total += cfg.num_layers * (per_tm + per_wkv + per_cm)
    elif cfg.family == "hybrid":
        d = cfg.d_model
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        n = cfg.ssm_state
        proj = 2.0 * t * d * (2 * d_in + 2 * n + h) + 2.0 * t * d_in * d
        chunk = 64.0
        ssd = 2.0 * t * chunk * n + 2.0 * t * chunk * cfg.ssm_head_dim * h
        ssd += 2.0 * t * n * d_in * 2
        total += cfg.num_layers * (proj + ssd)
        g = cfg.num_layers // cfg.attn_every
        total += g * (_attn_flops(cfg, t, kv) + _mlp_flops(cfg, t))
    elif cfg.family == "encdec":
        te = 0 if is_decode else batch * cfg.encoder_seq
        if not is_decode:
            total += cfg.encoder_layers * (
                _attn_flops(cfg, te, cfg.encoder_seq) + _mlp_flops(cfg, te))
        d, hd = cfg.d_model, cfg.head_dim
        self_part = _attn_flops(cfg, t, kv)
        xproj = (2.0 * t * d * cfg.num_heads * hd
                 + 2.0 * te * d * 2 * cfg.num_kv_heads * hd
                 + 2.0 * t * cfg.num_heads * hd * d)
        xscores = 2.0 * t * cfg.encoder_seq * cfg.num_heads * hd * 2
        total += cfg.num_layers * (self_part + xproj + xscores
                                   + _mlp_flops(cfg, t))
    # embedding lookup ~ free; lm head:
    total += 2.0 * t * cfg.d_model * cfg.padded_vocab
    return total


def step_flops(cfg, shape, moe_impl: str = "sort") -> float:
    """Total FLOPs of the lowered program for this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 3.0 * forward_flops(cfg, b, s, moe_impl=moe_impl)  # fwd+bwd
    if shape.kind == "prefill":
        return forward_flops(cfg, b, s, moe_impl=moe_impl)
    # decode: one token against kv_len cache
    return forward_flops(cfg, b, 1, kv_len=s, moe_impl=moe_impl,
                         is_decode=True)


def model_flops(cfg, shape) -> float:
    """6·N·D with N = (active) params, D = processed tokens (train);
    2·N·D for inference kinds (fwd only)."""
    n = active_param_count(cfg)
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d_tokens


def active_param_count(cfg) -> int:
    n = cfg.param_count()
    if cfg.num_experts:
        ff = cfg.moe_d_ff or cfg.d_ff
        inactive = (cfg.num_experts - cfg.experts_per_tok) * 3 * cfg.d_model * ff
        n -= cfg.num_layers * inactive
    return n


def hbm_bytes(cfg, shape, param_bytes: int, cache_bytes: int = 0,
              opt_bytes: int = 0) -> float:
    """Analytic HBM traffic per step: weights are read once per microbatch
    pass (fwd + bwd re-read + optimizer read/write), caches read+written,
    activations ~ 2× residual stream per layer."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        traffic = param_bytes * 3.0 + opt_bytes * 2.0
    elif shape.kind == "prefill":
        traffic = param_bytes + cache_bytes
    else:
        traffic = param_bytes + cache_bytes  # full cache read each token
    t = b * (s if shape.kind != "decode" else 1)
    act = 2.0 * t * cfg.d_model * 2 * max(1, cfg.num_layers)
    return traffic + act


@dataclasses.dataclass
class Roofline:
    chips: int
    flops: float
    bytes_hbm: float
    coll_bytes: float
    hlo_flops_raw: float
    hlo_bytes_raw: float
    model_flops_: float

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_ / max(1.0, self.flops)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        model compute."""
        t_star = max(self.t_compute, self.t_memory, self.t_collective)
        t_model = self.model_flops_ / (self.chips * PEAK_FLOPS_BF16)
        return t_model / max(t_star, 1e-30)

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops": self.flops,
            "bytes_hbm": self.bytes_hbm,
            "collective_bytes": self.coll_bytes,
            "hlo_flops_raw": self.hlo_flops_raw,
            "hlo_bytes_raw": self.hlo_bytes_raw,
            "model_flops": self.model_flops_,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# --- Batched ELL kernel models (the clustering engine's hot loop) ----------
#
# The two Pallas kernels the fused bucket program spends its rounds in
# (repro.kernels.neighbor_min): one invocation sweeps a (B, R, W) int32 ELL
# adjacency. These analytic models give the autotuner's perf tests a
# hardware lower bound to assert measured walls against — a wall below the
# model bound means the measurement (or the model) is broken.

ELL_KERNELS = ("neighbor_min", "label_agree")


def ell_kernel_flops(kernel: str, b: int, r: int, w: int) -> float:
    """Element-op count of one batched ELL kernel invocation.

    Per (entry, row, col): ``neighbor_min`` does a rank gather, an activity
    gather, a select and a running min (≈4 ops); ``label_agree`` does a
    label gather, a compare and an accumulate (≈3 ops). Element ops, not
    MXU FLOPs — these kernels are VPU/gather bound by construction.
    """
    if kernel not in ELL_KERNELS:
        raise ValueError(f"unknown ELL kernel {kernel!r}; "
                         f"expected one of {ELL_KERNELS}")
    per_elem = 4.0 if kernel == "neighbor_min" else 3.0
    return per_elem * b * r * w


def ell_kernel_bytes(kernel: str, b: int, r: int, w: int) -> float:
    """Lower bound on HBM traffic of one batched ELL kernel invocation.

    int32 throughout: the (B, R, W) ELL read once; one gathered word per
    ELL entry per gathered table (``neighbor_min`` gathers ranks and
    activity, ``label_agree`` gathers labels); the (B, R+1) state vectors
    read once; the (B, R) output written once. A lower bound — gathers
    that miss cache cost full lines, so real traffic is ≥ this.
    """
    if kernel not in ELL_KERNELS:
        raise ValueError(f"unknown ELL kernel {kernel!r}; "
                         f"expected one of {ELL_KERNELS}")
    n_tables = 2 if kernel == "neighbor_min" else 1
    ell_words = b * r * w
    gather_words = n_tables * ell_words
    state_words = n_tables * b * (r + 1)
    out_words = b * r
    return 4.0 * (ell_words + gather_words + state_words + out_words)


@dataclasses.dataclass(frozen=True)
class KernelRoofline:
    """Roofline model of one batched ELL kernel invocation (no
    collectives — batch entries are independent)."""

    kernel: str
    b: int
    r: int
    w: int
    flops: float
    bytes_hbm: float
    peak_flops: float
    mem_bw: float

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / self.mem_bw

    @property
    def t_model(self) -> float:
        """The model's lower bound on the invocation wall (seconds)."""
        return max(self.t_compute, self.t_memory)

    @property
    def bottleneck(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"

    def as_dict(self) -> dict:
        return {
            "kernel": self.kernel,
            "shape": [self.b, self.r, self.w],
            "flops": self.flops,
            "bytes_hbm": self.bytes_hbm,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_model_s": self.t_model,
            "bottleneck": self.bottleneck,
        }


def ell_kernel_roofline(kernel: str, b: int, r: int, w: int, *,
                        peak_flops: float = PEAK_FLOPS_BF16,
                        mem_bw: float = HBM_BW) -> KernelRoofline:
    """Roofline bound for one ``(B, R, W)`` batched ELL kernel invocation
    (TPU v5e constants by default — on other hardware the bound is still a
    valid *lower* bound for slower parts, which is how the perf tests use
    it: measured walls must never beat the model)."""
    return KernelRoofline(kernel=kernel, b=int(b), r=int(r), w=int(w),
                          flops=ell_kernel_flops(kernel, b, r, w),
                          bytes_hbm=ell_kernel_bytes(kernel, b, r, w),
                          peak_flops=peak_flops, mem_bw=mem_bw)


__all__ = [
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW",
    "collective_stats", "CollectiveStats",
    "forward_flops", "step_flops", "model_flops", "active_param_count",
    "hbm_bytes", "Roofline",
    "ELL_KERNELS", "ell_kernel_flops", "ell_kernel_bytes",
    "KernelRoofline", "ell_kernel_roofline",
]
