"""Continuous-batching serving loop over the prefill/decode entry points.

Slot-based scheduler (vLLM-style, TPU-static shapes): a fixed-size decode
batch of ``max_slots`` sequences; finished sequences release their slot and
the next queued request is prefilled into it. Because TPU programs are
shape-static, the decode step always runs the full slot batch with a
per-slot ``active`` mask; empty slots simply decode garbage that is never
emitted (the standard padding trade on accelerators).

This is the token path of the unified serving API: it implements the same
:class:`repro.serve.engine.ClusterEngine` protocol (``admit`` / ``flush`` /
``retire`` / ``stats`` / ``pending``) as the clustering path, so one outer
loop (``engine.serve_all``) can drive either. Positions are tracked per
slot; the decode kernel uses a scalar step index per call with per-slot
masking via position arrays. This module is deliberately host-side Python:
the device-side work is only ``prefill`` and ``decode_step``, everything
else is queue management.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import jax.numpy as jnp
import numpy as np

from .engine import EngineStats


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (len,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats(EngineStats):
    prefills: int = 0
    decode_steps: int = 0
    emitted_tokens: int = 0
    wasted_slot_steps: int = 0      # inactive-slot decode work (padding cost)


class ContinuousBatcher:
    """Schedules requests through a single-sequence prefill + slot decode.

    For simplicity each slot owns an independent cache (prefill batch 1);
    a production deployment would paged-attention the slots into one cache
    pool — the scheduling logic here is identical.
    """

    def __init__(self, model, params, max_slots: int = 4,
                 cache_len: int = 512, eos_token: int = 1,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.eos = eos_token
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.slot_caches: List = [None] * max_slots
        self.slot_pos: np.ndarray = np.zeros(max_slots, np.int32)
        self.slot_last: np.ndarray = np.zeros(max_slots, np.int32)
        self._finished: Deque[Request] = deque()
        # Slot-fill is this path's (only) scheduling policy — named in the
        # protocol's stats surface like the clustering path's policies.
        self.stats = ServeStats(policy="slot-fill")

    # -- ClusterEngine protocol ------------------------------------------

    def admit(self, req: Request) -> List[Request]:
        """Queue a request and prefill it into a free slot if one exists.

        A request can retire at admission: prefill emits the first token,
        which may already hit EOS or satisfy ``max_new_tokens`` — retiring
        here (not after the next decode tick) keeps such requests from
        decoding one garbage token past their stop condition.
        """
        self.queue.append(req)
        self.stats.submitted += 1
        self._admit()
        self._retire()
        return self.retire()

    def flush(self, max_ticks: int = 10_000) -> List[Request]:
        """Decode until every admitted request finishes (or tick budget)."""
        ticks = 0
        while (self.queue or any(r is not None for r in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.retire()

    def retire(self) -> List[Request]:
        """Drain finished requests not yet handed back to the caller."""
        out = list(self._finished)
        self._finished.clear()
        return out

    def pending(self) -> int:
        return len(self.queue) + sum(r is not None for r in self.slots)

    # -- Scheduler internals ----------------------------------------------

    def submit(self, req: Request):
        """Deprecated alias for :meth:`admit` (prefills into a free slot
        immediately, like admit — device work moved from the first ``step``
        to submission time)."""
        self.admit(req)

    def _admit(self):
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                logits, caches = self.model.prefill(
                    self.params, batch, cache_len=self.cache_len)
                self.stats.prefills += 1
                tok = int(jnp.argmax(
                    logits[0, : self.model.cfg.vocab_size]))
                req.out_tokens.append(tok)
                self.slots[i] = req
                self.slot_caches[i] = caches
                self.slot_pos[i] = len(req.prompt)
                self.slot_last[i] = tok
                self.stats.emitted_tokens += 1

    def _retire(self):
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (req.out_tokens and req.out_tokens[-1] == self.eos)
                    or self.slot_pos[i] >= self.cache_len - 1):
                req.done = True
                self.slots[i] = None
                self.slot_caches[i] = None
                self._finished.append(req)
                self.stats.retired += 1

    def step(self):
        """One scheduler tick: admit → decode all active slots → retire.

        Retire runs immediately after admit as well: a request whose
        prefill token already ends it (EOS / max_new_tokens=1) must free
        its slot before the decode pass, not emit one token past the stop.
        """
        self._admit()
        self._retire()
        active = [i for i in range(self.max_slots) if self.slots[i] is not None]
        if not active:
            return False
        for i in active:
            req = self.slots[i]
            tok = jnp.asarray([self.slot_last[i]], jnp.int32)
            logits, caches = self.model.decode_step(
                self.params, tok, self.slot_caches[i],
                jnp.int32(int(self.slot_pos[i])))
            self.slot_caches[i] = caches
            nxt = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
            req.out_tokens.append(nxt)
            self.slot_last[i] = nxt
            self.slot_pos[i] += 1
            self.stats.emitted_tokens += 1
        self.stats.decode_steps += 1
        self.stats.wasted_slot_steps += self.max_slots - len(active)
        self._retire()
        return True

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drive the loop to completion; returns the finished requests."""
        return self.flush(max_ticks=max_ticks)


__all__ = ["Request", "ContinuousBatcher", "ServeStats"]
