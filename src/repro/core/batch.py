"""Batched multi-graph PIVOT engine — the public entry point.

The per-graph engine (``correlation_cluster``) retraces and recompiles for
every new ``(n, m)`` shape, which is hopeless for serving millions of small
clustering queries. The batch engine packs many small graphs into **shape
buckets** and runs each bucket through one fused device program, so compile
count is O(#buckets · log B), not O(#graphs).

The engine is layered (this module is the thin composition of the two):

* :mod:`repro.core.plan` — host side: ``plan_graph`` bucketing, the
  ``pack_bucket`` ELL packer (with prebuilt ``PackedRows`` assembly for
  the serving layer's admission-time packing), ``PackStats`` pad
  accounting, and the lease-based ``BucketBufferPool`` staging reuse.
* :mod:`repro.core.executor` — device side: the fused bucket programs
  (rounds body × cost pass × best-of-k, composed from the method/objective
  registries in :mod:`repro.core.programs`), the bounded LRU of compiled
  bucket programs, and the ``BucketExecutor`` implementations (``sync``
  blocking, ``async`` pipelined, ``sharded`` multi-device ``shard_map``).

Bit-exactness contract: for the same per-graph PRNG key,
``correlation_cluster_batch`` returns labels, costs and picked sample
indices **bit-identical** to per-graph ``correlation_cluster`` — under any
executor, any flush grouping (including partial deadline flushes), and
both kernel paths. Enforced in ``tests/test_batch.py``,
``tests/test_engine.py`` and ``tests/test_executor.py``.

Benchmarks: ``PYTHONPATH=src python benchmarks/batch_bench.py`` and
``benchmarks/serve_bench.py`` (both take ``--executor {sync,async,sharded}``
and emit machine-readable ``BENCH_*.json``).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np

from .graph import Graph

# Backward-compatible re-exports: the pre-split module exposed all of these
# (_pack_bucket is the deprecated shim of pack_bucket).
from .plan import (  # noqa: F401
    MAX_ROWS, MAX_WIDTH, MIN_ROWS, MIN_WIDTH, BucketBufferPool, GraphPlan,
    PackedRows, PackStats, StagingLease, _pack_bucket, build_packed_rows,
    pack_bucket, plan_graph, promote_plan, result_for_plan,
)
from .executor import (  # noqa: F401
    IN_MIS, REMOVED, UNDECIDED, AsyncExecutor, BucketExecutor, InFlightBucket,
    ShardedExecutor, SyncExecutor, _batch_pivot_cost_impl, _gather_rows,
    make_executor, pack_and_submit, program_cache_capacity,
    program_cache_info, program_cache_size, run_bucket_program,
    set_program_cache_capacity,
)


def _cost_host(g: Graph, labels: np.ndarray) -> int:
    """Disagreement cost, same convention as ``core.cost.clustering_cost``.

    The serving path computes cost on device (see the fused program); this
    integer-exact numpy version is kept as the oracle the tests compare
    against.
    """
    und = g.undirected_edges()
    intra_pos = int((labels[und[:, 0]] == labels[und[:, 1]]).sum()) \
        if len(und) else 0
    pos_disagree = g.m - intra_pos
    sizes = np.bincount(labels, minlength=g.n)
    intra_pairs = int((sizes.astype(np.int64) * (sizes - 1) // 2).sum())
    return pos_disagree + (intra_pairs - intra_pos)


def _minmax_cost_host(g: Graph, labels: np.ndarray) -> int:
    """Worst-vertex disagreement oracle, alongside :func:`_cost_host`.

    Full-graph semantics (every positive edge attributed to both
    endpoints); the device ``'minmax'`` cost pass scores the
    eligible-induced capped subgraph, so the two agree exactly when the
    degree cap drops nothing (see :mod:`repro.core.programs`).
    """
    from .programs import minmax_cost_host

    return minmax_cost_host(g.n, g.undirected_edges(), labels)


def correlation_cluster_batch(
    graphs: Sequence[Graph],
    keys: Optional[Sequence[jax.Array] | jax.Array] = None,
    method: str = "pivot",
    eps: float = 2.0,
    lams: Optional[Sequence[Optional[int]]] = None,
    num_samples: int = 1,
    use_kernel: bool = False,
    pool: Optional[BucketBufferPool] = None,
    with_stats: bool = False,
    executor=None,
    objective: str = "disagree",
):
    """Cluster many graphs through the shape-bucketed batch engine.

    Args:
      graphs: the positive-edge graphs (``Graph`` instances).
      keys: per-graph PRNG keys (one key broadcast to all if a single key is
        given; defaults to ``PRNGKey(0)`` like the per-graph api).
      method: one of {METHODS} — each a registered
        :class:`~repro.core.programs.BucketProgramSpec`:
{METHOD_LINES}
      objective: one of {OBJECTIVES} — the registered cost pass scoring
        each sample before best-of-k selection:
{OBJECTIVE_LINES}
      lams: optional per-graph arboricity bounds (estimated when omitted).
      num_samples: best-of-k PIVOT — each graph is clustered under ``k``
        folded keys *within the same bucket* and the lowest-cost replica is
        selected by an on-device argmin, matching
        ``correlation_cluster(num_samples=k)`` bit-exactly (including the
        picked sample index). Must be >= 1.
      use_kernel: route neighbour-min and the cost reduction through the
        batched Pallas kernels.
      pool: optional :class:`BucketBufferPool` — reuse host staging buffers
        and run the donated device program (the serving path).
      with_stats: also return the packer's :class:`PackStats` as
        ``(results, stats)`` so callers track padding without re-deriving it.
      executor: a :class:`~repro.core.executor.BucketExecutor`, one of
        ``'sync'``/``'async'``/``'sharded'``, or None (sync). With the
        async/sharded executors all buckets are dispatched before any
        result is harvested, so packing overlaps device execution.

    Returns one :class:`repro.core.api.ClusterResult` per input graph with
    labels/costs bit-identical to per-graph ``correlation_cluster`` calls
    under the same keys (plus ``PackStats`` when ``with_stats``).
    """
    from .api import ClusterResult, sample_keys  # deferred: api imports us
    from .programs import objective_spec

    objective_spec(objective)        # fail fast, listing registered names
    if num_samples < 1:
        raise ValueError(
            f"num_samples must be >= 1, got {num_samples} (use 1 for a "
            "single PIVOT draw)")

    graphs = list(graphs)
    n_graphs = len(graphs)
    stats = PackStats()
    if n_graphs == 0:
        return ([], stats) if with_stats else []
    if keys is None:
        keys = [jax.random.PRNGKey(0)] * n_graphs
    elif isinstance(keys, jax.Array) and keys.ndim <= 1:
        # One key (legacy uint32 (2,) or typed 0-d) broadcast to all graphs.
        keys = [keys] * n_graphs
    else:
        keys = list(keys)
    if len(keys) != n_graphs:
        raise ValueError(f"{len(keys)} keys for {n_graphs} graphs")
    if lams is None:
        lams = [None] * n_graphs

    k = num_samples
    ex = make_executor(executor)
    plans = [plan_graph(g, method=method, eps=eps, lam=lam)
             for g, lam in zip(graphs, lams)]

    buckets: dict = {}
    for gi, plan in enumerate(plans):
        buckets.setdefault(plan.bucket, []).append(gi)

    # Dispatch every bucket before harvesting any: with an async or sharded
    # executor the host packs bucket i+1 while bucket i computes.
    handles: List[InFlightBucket] = []
    for members in buckets.values():
        bplans = [plans[gi] for gi in members]
        bkeys = [sample_keys(keys[gi], k) for gi in members]
        handle, bucket_stats = pack_and_submit(
            bplans, bkeys, k, ex, pool=pool, use_kernel=use_kernel,
            payload=(members, bplans), track=False, objective=objective)
        handles.append(handle)
        stats.merge(bucket_stats)

    results_by_graph: dict = {}
    for handle in handles:       # submission order: block at most once each
        labels, costs, picked, rounds = handle.result()
        members, bplans = handle.payload
        for slot, (gi, plan) in enumerate(zip(members, bplans)):
            results_by_graph[gi] = result_for_plan(
                plan, labels[slot], int(costs[slot]), int(picked[slot]),
                int(rounds[slot]), k, method)

    results: List[ClusterResult] = [results_by_graph[gi]
                                    for gi in range(n_graphs)]
    return (results, stats) if with_stats else results


def _registry_doc() -> None:
    # Fill the method/objective sections of the docstring from the program
    # registry, so adding a method can never leave stale user-facing docs.
    from .programs import method_spec, objective_spec, registered_methods, \
        registered_objectives

    def names(seq):
        return "/".join(f"``'{name}'``" for name in seq)

    def lines(seq, describe):
        return "\n".join(f"        * ``'{name}'`` — {describe(name)}"
                         for name in seq)

    doc = correlation_cluster_batch.__doc__
    if doc is None:              # stripped docstrings (python -OO)
        return
    doc = doc.replace("{METHODS}", names(registered_methods()))
    doc = doc.replace("{METHOD_LINES}", lines(
        registered_methods(), lambda m: method_spec(m).description))
    doc = doc.replace("{OBJECTIVES}", names(registered_objectives()))
    doc = doc.replace("{OBJECTIVE_LINES}", lines(
        registered_objectives(), lambda o: objective_spec(o).description))
    correlation_cluster_batch.__doc__ = doc


_registry_doc()
del _registry_doc


__all__ = [
    "GraphPlan", "PackStats", "BucketBufferPool", "StagingLease",
    "plan_graph", "promote_plan", "result_for_plan",
    "correlation_cluster_batch",
    "BucketExecutor", "SyncExecutor", "AsyncExecutor", "ShardedExecutor",
    "InFlightBucket", "make_executor", "program_cache_size",
    "program_cache_capacity", "set_program_cache_capacity",
    "program_cache_info", "run_bucket_program",
    "MIN_ROWS", "MIN_WIDTH", "MAX_ROWS", "MAX_WIDTH",
]
