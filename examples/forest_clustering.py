"""Forest (λ=1) special case: matchings ⇒ optimum correlation clustering.

    PYTHONPATH=src python examples/forest_clustering.py
"""

import jax
import numpy as np

from repro.core import (build_graph, correlation_cluster, matching_size,
                        max_matching_forest)
from repro.core.graph import random_forest


def main():
    rng = np.random.default_rng(1)
    g = build_graph(5_000, random_forest(5_000, rng))
    exact = correlation_cluster(g, method="forest_exact")
    approx = correlation_cluster(g, method="forest_approx",
                                 key=jax.random.PRNGKey(0))
    m_star = matching_size(max_matching_forest(g))
    print(f"forest n=5000 m={g.m}, max matching = {m_star}")
    print(f"exact   cost={exact.cost}  (= m − |M*| = {g.m - m_star})")
    print(f"approx  cost={approx.cost}  ratio="
          f"{approx.cost / max(1, exact.cost):.4f}  "
          f"rounds={approx.info['rounds']}")


if __name__ == "__main__":
    main()
