"""Model facade: one object tying config + plan + the three entry points
(train loss, prefill, decode) and producing dry-run ``input_specs``.

``abstract_params`` / ``abstract_caches`` use ``jax.eval_shape`` so the
dry-run never allocates the (up to 314B-parameter) trees — only
ShapeDtypeStructs flow into ``jit(...).lower()``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import decoding, transformer
from .common import is_pm, split_params
from .sharding import ShardingPlan
from .transformer import RunConfig


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    plan: ShardingPlan
    rc: RunConfig
    param_dtype: Any = jnp.bfloat16

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array):
        """Concrete params (smoke/testing scale only)."""
        tree = transformer.init_model(self.cfg, key, self.plan,
                                      self.param_dtype)
        return split_params(tree)

    def abstract_params(self):
        """(ShapeDtypeStruct tree, spec tree) without allocation.

        The init runs under ``eval_shape`` (never allocating the up-to-314B
        tree); the spec tree — plain Python objects — is captured by side
        effect during the single abstract trace.
        """
        store = {}

        def f(k):
            tree = transformer.init_model(self.cfg, k, self.plan,
                                          self.param_dtype)
            vals, specs = split_params(tree)
            store["specs"] = specs
            return vals

        vals = jax.eval_shape(f, jax.random.PRNGKey(0))
        return vals, store["specs"]

    def abstract_caches(self, batch: int, seq_len: int,
                        cache_dtype=jnp.bfloat16):
        store = {}

        def f():
            tree = decoding.init_caches(self.cfg, batch, seq_len, self.plan,
                                        cache_dtype)
            vals, specs = split_params(tree)
            store["specs"] = specs
            return vals

        vals = jax.eval_shape(f)
        return vals, store["specs"]

    def init_caches(self, batch: int, seq_len: int, cache_dtype=jnp.bfloat16):
        return split_params(
            decoding.init_caches(self.cfg, batch, seq_len, self.plan,
                                 cache_dtype))

    # -- entry points ---------------------------------------------------------
    def loss(self, params, batch):
        return transformer.loss_fn(params, self.cfg, self.plan, self.rc, batch)

    def forward(self, params, batch):
        return transformer.forward(params, self.cfg, self.plan, self.rc, batch)

    def prefill(self, params, batch, cache_len: Optional[int] = None,
                cache_dtype=jnp.bfloat16):
        return decoding.prefill(params, self.cfg, self.plan, self.rc, batch,
                                cache_len=cache_len, cache_dtype=cache_dtype)

    def decode_step(self, params, token, caches, pos):
        return decoding.decode_step(params, self.cfg, self.plan, self.rc,
                                    token, caches, pos)

    # -- dry-run inputs -------------------------------------------------------
    def input_specs(self, shape: ShapeConfig, act_dtype=jnp.bfloat16
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            specs = {
                "tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": sds((b, s), jnp.int32)}
        else:  # decode: one new token against a seq_len cache
            specs = {"token": sds((b,), jnp.int32)}
        if cfg.family == "encdec" and shape.kind != "decode":
            specs["audio_embeds"] = sds((b, cfg.encoder_seq, cfg.d_model),
                                        act_dtype)
        if cfg.family == "vlm" and shape.kind != "decode":
            specs["image_embeds"] = sds((b, cfg.num_image_tokens, cfg.d_model),
                                        act_dtype)
        return specs

    def input_shardings(self, shape: ShapeConfig):
        p_batch = self.plan.P("batch")
        p_batch_seq = self.plan.P("batch", None)
        p_embed3 = self.plan.P("batch", None, None)
        if shape.kind == "decode":
            out = {"token": p_batch}
        elif shape.kind == "prefill":
            out = {"tokens": p_batch_seq}
        else:
            out = {"tokens": p_batch_seq, "labels": p_batch_seq}
        if self.cfg.family == "encdec" and shape.kind != "decode":
            out["audio_embeds"] = p_embed3
        if self.cfg.family == "vlm" and shape.kind != "decode":
            out["image_embeds"] = p_embed3
        return out


def build_model(cfg: ModelConfig, plan: Optional[ShardingPlan] = None,
                rc: Optional[RunConfig] = None,
                param_dtype=jnp.bfloat16) -> Model:
    return Model(cfg=cfg, plan=plan or ShardingPlan.null(),
                 rc=rc or RunConfig(), param_dtype=param_dtype)


__all__ = ["Model", "build_model"]
