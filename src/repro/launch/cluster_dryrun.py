import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run + roofline for the paper's OWN workload: distributed PIVOT
correlation clustering on the production mesh (§Perf H3).

Method: the per-round SPMD program (one MIS round) is lowered/compiled on a
256-way edge-sharded mesh and its collective bytes extracted from the HLO;
round *counts* are measured by running the full algorithm eagerly on the
host at the same graph size (they are data-dependent, so the while loop
carries no static trip count). Total collective bytes = rounds ×
bytes/round (+ capture pass). Variants:

  raw        — PIVOT without the degree cap (Chierichetti-style baseline)
  capped     — Theorem 26 degree cap first (the paper's contribution)
  packed     — + int8 hit-flag collective instead of the 2nd rank pmin
               (beyond-paper; winner set is recomputable from the 1st pmin)
  phased     — + Algorithm 1 prefix scheduling: phase i communicates
               O(t_i)-sized state, bytes = Σ_i depth_i · bytes(t_i)
"""

import argparse
import json
import math
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core import (algorithm1, build_graph, degree_threshold,
                        greedy_mis_parallel, random_permutation_ranks)
from repro.core.dist import _dist_mis_program, _pad_edges_for_mesh
from repro.core.graph import scale_free
from repro.launch import roofline as rl


def _flat_mesh(chips: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:chips]), ("shard",))


def _round_program_bytes(n: int, edges_per_shard: int, mesh: Mesh,
                         packed: bool) -> dict:
    """Lower ONE MIS round on the mesh; return collective bytes per round."""
    chips = mesh.devices.size
    e_total = edges_per_shard * chips

    def one_round(src, dst, ranks, status):
        def spmd(src_l, dst_l, ranks_r, status_r):
            from repro.core.dist import _local_segment_min
            und = status_r == 0
            local = _local_segment_min(src_l, dst_l, ranks_r, und, n)
            nmin = jax.lax.pmin(local, "shard")[:n]
            winners = und & (ranks_r < nmin)
            if packed:
                dst_ok = dst_l < n
                dst_idx = jnp.minimum(dst_l, n - 1)
                vals = (dst_ok & winners[dst_idx]).astype(jnp.int8)
                loc = jnp.zeros((n + 1,), jnp.int8).at[
                    jnp.minimum(src_l, n)].max(vals)
                hit_any = jax.lax.pmax(loc, "shard")[:n] > 0
                hit = und & (~winners) & hit_any
            else:
                local2 = _local_segment_min(src_l, dst_l, ranks_r, winners, n)
                wmin = jax.lax.pmin(local2, "shard")[:n]
                hit = und & (~winners) & (wmin < 2**31 - 1)
            status_r = jnp.where(winners, 1, status_r)
            status_r = jnp.where(hit, 2, status_r)
            return status_r

        return _shard_map(
            spmd, mesh=mesh,
            in_specs=(P("shard"), P("shard"), P(), P()),
            out_specs=P(),
        )(src, dst, ranks, status)

    sds = jax.ShapeDtypeStruct
    sh_e = NamedSharding(mesh, P("shard"))
    sh_r = NamedSharding(mesh, P())
    fn = jax.jit(one_round,
                 in_shardings=(sh_e, sh_e, sh_r, sh_r),
                 out_shardings=sh_r)
    with mesh:
        lowered = fn.lower(sds((e_total,), jnp.int32),
                           sds((e_total,), jnp.int32),
                           sds((n,), jnp.int32), sds((n,), jnp.int32))
        compiled = lowered.compile()
    coll = rl.collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "bytes_per_round": coll.total_bytes,
        "by_kind": coll.bytes_by_kind,
        "per_device_bytes": mem.argument_size_in_bytes
        + mem.temp_size_in_bytes,
    }


def run(n: int = 1 << 17, attach: int = 8, chips: int = 256,
        seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    edges, lam = scale_free(n, attach, rng)
    g = build_graph(n, edges)
    delta = g.max_degree()
    key = jax.random.PRNGKey(seed)
    ranks = random_permutation_ranks(n, key)

    # --- measured round counts (data-dependent) --------------------------
    depth_raw = int(greedy_mis_parallel(g, ranks).rounds)
    thresh = degree_threshold(lam, 2.0)
    high = np.asarray(g.deg) > thresh
    eligible = jnp.asarray(~high)
    depth_capped = int(greedy_mis_parallel(g, ranks, eligible=eligible).rounds)

    # Algorithm 1 phase stats on the capped subgraph (for the phased model).
    from repro.core.degree_cap import degree_capped_pivot
    capped = degree_capped_pivot(g, lam=lam, key=key, eps=2.0,
                                 engine="phased")
    ledger = capped.inner.ledger
    phases = [(p.prefix_end - p.prefix_start, max(1, p.depth))
              for p in ledger.phases]

    # --- per-round collective bytes from the compiled SPMD program -------
    mesh = _flat_mesh(chips)
    m_eff = int((~high[np.asarray(g.src[: 2 * g.m])]).sum())  # capped edges
    eps_raw = math.ceil(2 * g.m / chips)
    eps_cap = math.ceil(m_eff / chips)
    r_raw = _round_program_bytes(n, eps_raw, mesh, packed=False)
    r_packed = _round_program_bytes(n, eps_cap, mesh, packed=True)
    r_unpacked_cap = _round_program_bytes(n, eps_cap, mesh, packed=False)

    def total(bpr, rounds):
        return bpr * rounds + bpr / 2  # + capture pass (one pmin)

    # Phased: bytes scale with the phase's prefix size (state vectors are
    # O(t_i)); use packed per-round bytes scaled by t_i/n.
    phased_bytes = sum(
        r_packed["bytes_per_round"] * (t / n) * depth for t, depth in phases)

    variants = {
        "raw_unpacked": total(r_raw["bytes_per_round"], depth_raw),
        "capped_unpacked": total(r_unpacked_cap["bytes_per_round"],
                                 depth_capped),
        "capped_packed": total(r_packed["bytes_per_round"], depth_capped),
        "capped_packed_phased": phased_bytes + r_packed["bytes_per_round"],
    }
    seg_flops = 2.0 * 2 * g.m  # compare+select per directed edge per round
    out = {
        "n": n, "m": int(g.m), "lambda": lam, "delta": int(delta),
        "threshold": thresh, "high_degree": int(high.sum()),
        "depth_raw": depth_raw, "depth_capped": depth_capped,
        "phases": phases,
        "bytes_per_round_unpacked": r_raw["bytes_per_round"],
        "bytes_per_round_packed": r_packed["bytes_per_round"],
        "per_device_bytes": r_raw["per_device_bytes"],
        "variants_total_collective_bytes": variants,
        "t_collective_s": {k: v / (chips * rl.ICI_BW)
                           for k, v in variants.items()},
        "t_compute_s": seg_flops * depth_raw / (chips * rl.PEAK_FLOPS_BF16),
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 17)
    ap.add_argument("--attach", type=int, default=8)
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    res = run(n=args.n, attach=args.attach, chips=args.chips)
    print(json.dumps(res, indent=2, default=float))
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(res, indent=2, default=float))
    return 0


if __name__ == "__main__":
    sys.exit(main())
