"""Public correlation-clustering API — the paper's algorithms, composed.

``correlation_cluster`` is the single entry point used by the data-pipeline
dedup stage and the standalone examples. Methods:

* ``pivot``         — Corollary 28: degree-cap (Thm 26, ε) + PIVOT (3-approx
                      in expectation). The paper's headline algorithm.
* ``pivot_phased``  — same, inner engine = Algorithm 1 (phase/chunk
                      scheduling with MPC round ledger).
* ``pivot_raw``     — PIVOT without the degree cap (baseline comparator;
                      this is what Chierichetti et al. simulate).
* ``precluster``    — constant-round neighbourhood-agreement pre-clustering
                      (arXiv 2106.08448); the per-graph reference of the
                      batch engine's ``'precluster'`` bucket program.
* ``forest_exact``  — Corollary 27/31(1): maximum matching (λ=1 inputs).
* ``forest_approx`` — Lemma 29/Cor 31(2,3): maximal matching + length-3
                      augmentation passes.
* ``cliques``       — Corollary 32: deterministic O(λ²), O(1) rounds.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import forest as forest_mod
from .arboricity import arboricity_bounds
from .cliques import clique_clustering
from .cost import clustering_cost
from .degree_cap import degree_capped_pivot, degree_threshold
from .dist import distributed_pivot, edge_shard_mesh
from .graph import Graph, build_graph
from .mis import random_permutation_ranks
from .pivot import pivot


@dataclasses.dataclass
class ClusterResult:
    labels: np.ndarray
    cost: int
    method: str
    info: dict


def sample_keys(key: jax.Array, num_samples: int) -> list:
    """Best-of-k key schedule shared by the single and batch engines.

    ``num_samples <= 1`` uses the caller's key untouched (bit-compat with
    pre-sampling behaviour); otherwise each sample folds its index in.
    """
    if num_samples <= 1:
        return [key]
    return [jax.random.fold_in(key, i) for i in range(num_samples)]


def correlation_cluster(
    g: Graph | np.ndarray,
    n: Optional[int] = None,
    method: str = "pivot",
    eps: float = 2.0,
    lam: Optional[int] = None,
    key: Optional[jax.Array] = None,
    distributed: bool = False,
    mesh=None,
    use_kernel: bool = False,
    num_samples: int = 1,
) -> ClusterResult:
    """Cluster a complete signed graph given its positive edges.

    Args:
      g: a :class:`Graph` or an (m, 2) positive edge array (then pass ``n``).
      lam: arboricity of E⁺; estimated via degeneracy if omitted.
      eps: Theorem 26 ε (ε=2 reproduces the paper's 3-approx threshold 12λ).
      distributed: run the edge-sharded shard_map engine across the mesh.
      num_samples: best-of-k for the randomized PIVOT methods — run ``k``
        independent permutations (keys ``fold_in(key, i)``) and keep the
        lowest-cost clustering. PIVOT is a 3-approx *in expectation*; taking
        the min over a few draws tightens the realized cost cheaply.
    """
    if not isinstance(g, Graph):
        if n is None:
            raise ValueError("pass n with a raw edge array")
        g = build_graph(n, g)
    key = key if key is not None else jax.random.PRNGKey(0)
    info: dict = {}

    if lam is None and method in ("pivot", "pivot_phased", "cliques",
                                  "precluster"):
        lo, hi = arboricity_bounds(g, exact=g.n <= 200_000)
        lam = hi  # degeneracy upper bound; only moves the O(λ/ε) constant
        info["lambda_estimate"] = (lo, hi)

    if method == "precluster":
        # Host reference of the batch engine's constant-round program:
        # same degree-cap planning, same ranks, same integer agreement
        # predicate and propagation — bit-identical labels per key.
        from .plan import plan_graph
        from .programs import precluster_host

        plan = plan_graph(g, method="precluster", eps=eps, lam=lam)
        best = None
        for i, k in enumerate(sample_keys(key, num_samples)):
            ranks = np.asarray(random_permutation_ranks(g.n, k))
            labels_i, rounds_i = precluster_host(
                g.n, plan.canonical_edges, plan.eligible, ranks)
            cost_i = clustering_cost(g, labels_i)
            if best is None or cost_i < best[0]:
                best = (cost_i, labels_i, rounds_i, i)
        cost, labels, rounds, picked = best
        info.update(depth=rounds, threshold=plan.threshold,
                    high_degree=int((~plan.eligible).sum()))
        if num_samples > 1:
            info.update(num_samples=num_samples, picked_sample=picked)
        return ClusterResult(labels=np.asarray(labels), cost=cost,
                             method=method, info=info)

    if method in ("pivot", "pivot_phased", "pivot_raw"):
        engine = "phased" if method == "pivot_phased" else "rounds"

        def run_once(k):
            run_info: dict = {}
            if method == "pivot_raw":
                if distributed:
                    ranks = random_permutation_ranks(g.n, k)
                    labels, _, rounds = distributed_pivot(g, ranks, mesh=mesh)
                    run_info["depth"] = rounds
                else:
                    res = pivot(g, k, engine="rounds", use_kernel=use_kernel)
                    labels, run_info["depth"] = res.labels, res.depth
            elif distributed:
                thresh = degree_threshold(lam, eps)
                high = np.asarray(g.deg) > thresh
                ranks = random_permutation_ranks(g.n, k)
                # Degree cap in the distributed engine: ineligible vertices
                # get rank ∞ by exclusion — implemented by masking them as
                # REMOVED up-front via a rank shift (they never win nor get
                # captured).
                labels, in_mis, rounds = _distributed_capped(
                    g, ranks, high, mesh=mesh)
                run_info.update(depth=rounds, threshold=thresh,
                                high_degree=int(high.sum()))
            else:
                res = degree_capped_pivot(g, lam=lam, key=k, eps=eps,
                                          engine=engine,
                                          use_kernel=use_kernel)
                labels = res.labels
                run_info.update(
                    threshold=res.threshold,
                    high_degree=int(res.high_mask.sum()),
                    depth=res.inner.depth if res.inner else -1,
                )
                if res.inner and res.inner.ledger:
                    run_info["mpc_rounds"] = res.inner.ledger.total_rounds
                    run_info["ledger"] = res.inner.ledger.summary()
            return labels, run_info

        best = None
        for i, k in enumerate(sample_keys(key, num_samples)):
            labels_i, info_i = run_once(k)
            cost_i = clustering_cost(g, labels_i)
            if best is None or cost_i < best[0]:
                best = (cost_i, labels_i, info_i, i)
        cost, labels, run_info, picked = best
        info.update(run_info)
        if num_samples > 1:
            info.update(num_samples=num_samples, picked_sample=picked)
        return ClusterResult(labels=np.asarray(labels), cost=cost,
                             method=method, info=info)

    if method == "forest_exact":
        partner = forest_mod.max_matching_forest(g)
        labels = forest_mod.clustering_from_matching(partner)
        info["matching_size"] = forest_mod.matching_size(partner)
    elif method == "forest_approx":
        partner, rounds = forest_mod.augmenting_matching_parallel(g, key)
        labels = forest_mod.clustering_from_matching(partner)
        info.update(matching_size=forest_mod.matching_size(partner),
                    rounds=rounds)
    elif method == "cliques":
        labels = np.asarray(clique_clustering(g))
    else:
        # Batch-engine methods come from the program registry; host-only
        # methods are this module's own — one generated list, never stale.
        from .programs import registered_methods

        host_only = ("pivot_phased", "forest_exact", "forest_approx",
                     "cliques")
        supported = tuple(sorted(set(registered_methods()) | set(host_only)))
        raise ValueError(f"unknown method {method!r}; expected one of "
                         f"{supported}")

    return ClusterResult(
        labels=np.asarray(labels),
        cost=clustering_cost(g, labels),
        method=method,
        info=info,
    )


def _distributed_capped(g: Graph, ranks, high: np.ndarray, mesh=None):
    """Degree-capped PIVOT on the distributed engine: drop edges incident to
    high-degree vertices device-side, then run; high vertices singleton."""
    n = g.n
    highj = jnp.asarray(high)
    src_ok = (g.src < n)
    src_i = jnp.minimum(g.src, n - 1)
    dst_i = jnp.minimum(g.dst, n - 1)
    keep = src_ok & ~highj[src_i] & ~highj[dst_i]
    src = jnp.where(keep, g.src, n)
    dst = jnp.where(keep, g.dst, n)
    g2 = Graph(n=n, m=g.m, src=src, dst=dst, row_offsets=g.row_offsets,
               deg=g.deg, eid=g.eid)
    labels, in_mis, rounds = distributed_pivot(g2, ranks, mesh=mesh)
    own = np.arange(n, dtype=np.int32)
    labels = np.where(high, own, labels)
    return labels, in_mis, rounds


# Batched multi-graph engine (shape-bucketed ELL; see core/batch.py).
# Imported at the bottom: batch.py pulls ClusterResult from this module.
from .batch import correlation_cluster_batch  # noqa: E402

__all__ = ["ClusterResult", "correlation_cluster",
           "correlation_cluster_batch", "sample_keys"]
