"""RWKV6 ("Finch") block: data-dependent per-channel decay, attention-free.

Recurrence per head (state S ∈ R^{N×N}, k-dim × v-dim):
    o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)
    S_t = diag(w_t) · S_{t-1} + k_t ⊗ v_t
with the *data-dependent* decay ``w_t = exp(−exp(w0 + lora(x̄_t)))`` per
channel — the paper-defining feature of RWKV6 vs RWKV4/5.

Two equivalent evaluators (tested against each other):
* ``rwkv_scan``    — exact sequential ``lax.scan`` over T (decode + oracle).
* ``rwkv_chunked`` — chunk-parallel: intra-chunk via factored decay matmuls
  in log-space with per-chunk re-centering (chunk 32 keeps the
  ``exp(−cum)`` factor bounded), inter-chunk via a short scan. This is the
  MXU-friendly form a TPU deployment would run for train/prefill.

Token shift uses the static learned mix (the per-projection LoRA shift of
the reference implementation is folded into one mix vector per stream —
noted in DESIGN.md).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import Pm, dense_init, rms_norm


def rwkv_dims(cfg: ModelConfig):
    n = cfg.rwkv_head_dim
    h = cfg.d_model // n
    return h, n


def init_rwkv_time_mix(cfg: ModelConfig, kg, dtype, plan):
    d = cfg.d_model
    lora = cfg.rwkv_decay_lora
    return {
        "mix": Pm(jnp.full((5, d), 0.5, dtype), plan.P(None, None)),
        "wr": Pm(dense_init(kg(), (d, d), dtype), plan.P("embed", "ff")),
        "wk": Pm(dense_init(kg(), (d, d), dtype), plan.P("embed", "ff")),
        "wv": Pm(dense_init(kg(), (d, d), dtype), plan.P("embed", "ff")),
        "wg": Pm(dense_init(kg(), (d, d), dtype), plan.P("embed", "ff")),
        "w0": Pm(jnp.full((d,), -2.0, jnp.float32), plan.P(None)),
        "w_a": Pm(dense_init(kg(), (d, lora), jnp.float32), plan.P("embed", None)),
        "w_b": Pm(dense_init(kg(), (lora, d), jnp.float32), plan.P(None, None)),
        "u": Pm(jnp.zeros((d,), jnp.float32), plan.P(None)),
        "wo": Pm(dense_init(kg(), (d, d), dtype), plan.P("ff", "embed")),
        "ln_x": Pm(jnp.ones((d,), dtype), plan.P(None)),
    }


def init_rwkv_channel_mix(cfg: ModelConfig, kg, dtype, plan):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix": Pm(jnp.full((2, d), 0.5, dtype), plan.P(None, None)),
        "wk": Pm(dense_init(kg(), (d, f), dtype), plan.P("embed", "ff")),
        "wv": Pm(dense_init(kg(), (f, d), dtype), plan.P("ff", "embed")),
        "wr": Pm(dense_init(kg(), (d, d), dtype), plan.P("embed", None)),
    }


def _token_shift(x, x_prev):
    """x (B,T,d); x_prev (B,1,d) carry. Returns shifted (B,T,d), new carry."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _mix(x, shifted, mu):
    return x * mu + shifted * (1.0 - mu)


class RWKVCache(NamedTuple):
    tm_prev: jnp.ndarray   # (B, 1, d) token-shift carry (time mix)
    cm_prev: jnp.ndarray   # (B, 1, d) token-shift carry (channel mix)
    state: jnp.ndarray     # (B, H, N, N) wkv state (fp32)


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, n = rwkv_dims(cfg)
    return RWKVCache(
        tm_prev=jnp.zeros((batch, 1, cfg.d_model), dtype),
        cm_prev=jnp.zeros((batch, 1, cfg.d_model), dtype),
        state=jnp.zeros((batch, h, n, n), jnp.float32),
    )


def _projections(p, cfg, x, x_prev):
    """Shared front-end of the time-mix: projections + decay."""
    shifted, carry = _token_shift(x, x_prev)
    mu = p["mix"].astype(x.dtype)
    xr = _mix(x, shifted, mu[0])
    xk = _mix(x, shifted, mu[1])
    xv = _mix(x, shifted, mu[2])
    xg = _mix(x, shifted, mu[3])
    xw = _mix(x, shifted, mu[4])
    r = xr @ p["wr"]
    k = xk @ p["wk"]
    v = xv @ p["wv"]
    g = jax.nn.silu(xg @ p["wg"])
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_a"]) @ p["w_b"]
    logw = -jnp.exp(jnp.clip(p["w0"] + lora, -8.0, 1.0))   # log w ∈ [-e, 0)
    return r, k, v, g, logw, carry


def rwkv_scan(r, k, v, logw, u, state):
    """Exact recurrence. r/k/v (B,T,H,N); logw (B,T,H,N); u (H,N);
    state (B,H,N,N). Returns o (B,T,H,N), final state."""
    w = jnp.exp(logw)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                    # (B,H,N)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)  # (B,H,N,N)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in
                (r.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32), w))
    state, o = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(o, 0, 1), state


def rwkv_chunked(r, k, v, logw, u, state, chunk: int = 16):
    """Chunk-parallel evaluation, math-equivalent to :func:`rwkv_scan`.

    Factored decays: contribution of j to output at i (j < i) is
    ``exp(cum[i-1] − cum[j])`` per channel, where cum is the inclusive
    cumsum of log w. Computed as r̃_i = r_i·exp(cum[i-1]−c₀),
    k̃_j = k_j·exp(c₀−cum[j]) with per-chunk re-centering c₀ = cum[0] to
    bound the positive exponent. With logw clamped to ≥ −e the worst-case
    exponent is chunk·e, so chunk ≤ 32 stays inside f32 range (chunk 16
    default leaves 2× headroom); larger chunks overflow — enforced.
    """
    assert chunk <= 32, "rwkv_chunked: decay factorization overflows f32 beyond chunk=32"
    b, t, h, n = r.shape
    pad = (-t) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=0.0)
    tt = t + pad
    nc = tt // chunk
    rq = r.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    kq = k.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    vq = v.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    lw = logw.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    cum = jnp.cumsum(lw, axis=2)                       # inclusive
    center = cum[:, :, :1]                             # c₀ per chunk
    # r̃_i carries decay from chunk start up to i-1 (exclusive of w_i).
    cum_excl = cum - lw                                # exclusive prefix
    r_dec = rq * jnp.exp(cum_excl - center)
    k_dec = kq * jnp.exp(center - cum)
    scores = jnp.einsum("bcihn,bcjhn->bchij", r_dec, k_dec)  # j<i strictly
    iq = jnp.arange(chunk)
    strict = (iq[:, None] > iq[None, :])[None, None, None]
    scores = jnp.where(strict, scores, 0.0)
    # Diagonal u-bonus.
    diag = jnp.einsum("bcihn,hn,bcihn->bcih", rq, u, kq)
    y_intra = jnp.einsum("bchij,bcjhn->bcihn", scores, vq)
    y_intra += diag[..., None] * vq

    # Inter-chunk: o_i += (r_i ⊙ exp(cum_excl_i)) S_prev ; chunk state update.
    decay_to_end = jnp.exp(cum[:, :, -1:] - cum)       # Σ_{m>j} logw (≤0 ok)
    s_chunk = jnp.einsum("bcjhk,bcjhv->bchkv", kq * decay_to_end, vq)
    total = jnp.exp(cum[:, :, -1])                     # (B,nc,H,N)

    def scan_fn(s_prev, inp):
        s_c, dec = inp
        return dec[..., None] * s_prev + s_c, s_prev

    s_final, s_prevs = jax.lax.scan(
        scan_fn, state.astype(jnp.float32),
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)              # (B,nc,H,N,N)
    y_inter = jnp.einsum("bcihk,bchkv->bcihv",
                         rq * jnp.exp(cum_excl), s_prevs)
    y = (y_intra + y_inter).reshape(b, tt, h, n)[:, :t]
    return y, s_final


def rwkv_time_mix(p, cfg: ModelConfig, x, x_prev, state, impl="chunked"):
    """x (B,T,d) → (B,T,d), (carry, state)."""
    b, t, d = x.shape
    h, n = rwkv_dims(cfg)
    r, k, v, g, logw, carry = _projections(p, cfg, x, x_prev)
    rh = r.reshape(b, t, h, n)
    kh = k.reshape(b, t, h, n)
    vh = v.reshape(b, t, h, n)
    lwh = logw.reshape(b, t, h, n)
    uh = p["u"].reshape(h, n)
    fn = rwkv_chunked if impl == "chunked" else rwkv_scan
    o, s_final = fn(rh, kh, vh, lwh, uh, state)
    o = o.reshape(b, t, d).astype(x.dtype)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
    return o @ p["wo"], carry, s_final


def rwkv_channel_mix(p, cfg: ModelConfig, x, x_prev):
    shifted, carry = _token_shift(x, x_prev)
    mu = p["mix"].astype(x.dtype)
    xk = _mix(x, shifted, mu[0])
    xr = _mix(x, shifted, mu[1])
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])
    return out, carry


__all__ = [
    "init_rwkv_time_mix", "init_rwkv_channel_mix", "rwkv_time_mix",
    "rwkv_channel_mix", "rwkv_scan", "rwkv_chunked", "RWKVCache",
    "init_rwkv_cache", "rwkv_dims",
]
