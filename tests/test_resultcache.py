"""Content-addressed result cache + single-flight coalescing.

The contracts under test (core/plan.py graph_fingerprint,
serve/resultcache.py, serve/cluster_batcher.py):

* fingerprint sensitivity — equal content + exact key ⇒ equal digest;
  differing key, eps, num_samples, method, or graph content ⇒ miss;
* a cache hit retires at admission with labels/cost/picked bit-identical
  to a cold flush (and to the per-graph engine), across sync/async/sharded
  executors and deadline/coalesce/cost policies;
* a single-flight subscriber rides an identical queued/in-flight request's
  harvest — never a duplicate packed row, never visible to policies in
  queue depth/age — and rides the requeue-on-error path when the flush's
  handle is poisoned, retrying rather than dropping;
* the LRU store enforces capacity/byte bounds with hit/miss/eviction/
  collision counters, and hits are payload-verified (a digest collision
  can never serve another graph's labels).
"""

import jax
import numpy as np
import pytest

from repro.core import (
    build_graph,
    correlation_cluster,
    graph_fingerprint,
    plan_graph,
)
from repro.core.executor import AsyncExecutor
from repro.core.graph import path, random_arboric
from repro.core.plan import GraphFingerprint
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
from repro.serve.resultcache import ResultCache, make_result_cache


def _rand_graph(n, lam, seed):
    edges, _ = random_arboric(n, lam, np.random.default_rng(seed))
    return build_graph(n, edges)


def _assert_matches(g, key, res, **kwargs):
    ref = correlation_cluster(g, key=key, **kwargs)
    assert (res.labels == ref.labels).all()
    assert res.cost == ref.cost


@pytest.fixture(autouse=True)
def _unpin_program_cache():
    """Cost-policy heat tracking pins bucket shapes in the *global*
    program cache; never let pins leak between tests."""
    yield
    from repro.core.executor import program_cache_info, program_cache_unpin

    for bucket in program_cache_info()["pinned"]:
        while program_cache_unpin(tuple(bucket)):
            pass


# ---------------------------------------------------------------------------
# Fingerprint: canonical, collision-checked, sensitive to what matters.
# ---------------------------------------------------------------------------


def test_fingerprint_stable_across_equal_content():
    g1 = build_graph(10, path(10))
    g2 = build_graph(10, path(10))          # distinct object, same content
    key = jax.random.PRNGKey(3)
    fp1 = graph_fingerprint(plan_graph(g1), key)
    fp2 = graph_fingerprint(plan_graph(g2), key)
    assert fp1 == fp2
    assert fp1.digest == fp2.digest and fp1.payload == fp2.payload


def test_fingerprint_sensitivity():
    """Differing key, eps, num_samples, method, lam, or content must miss."""
    g = _rand_graph(20, 2, seed=0)
    plan = plan_graph(g)
    key = jax.random.PRNGKey(0)
    base = graph_fingerprint(plan, key)
    variants = [
        graph_fingerprint(plan, jax.random.PRNGKey(1)),
        graph_fingerprint(plan, jax.random.fold_in(key, 0)),
        graph_fingerprint(plan, key, num_samples=4),
        graph_fingerprint(plan, key, eps=1.0),
        graph_fingerprint(plan_graph(g, method="pivot_raw"), key,
                          method="pivot_raw"),
        graph_fingerprint(plan_graph(g, lam=7), key),       # resolved λ
        graph_fingerprint(plan_graph(_rand_graph(20, 2, seed=1)), key),
    ]
    digests = {fp.digest for fp in variants}
    assert base.digest not in digests
    assert len(digests) == len(variants), "variant fingerprints collided"


def test_fingerprint_method_objective_matrix():
    """Same graph + same PRNG key across every registered method ×
    objective must produce pairwise-distinct digests — the result cache's
    method/objective isolation rests entirely on this (PR 10)."""
    g = _rand_graph(20, 2, seed=0)
    key = jax.random.PRNGKey(0)
    digests = {}
    for method in ("pivot", "pivot_raw", "precluster"):
        plan = plan_graph(g, method=method)
        for objective in ("disagree", "minmax"):
            fp = graph_fingerprint(plan, key, method=method,
                                   objective=objective)
            digests[(method, objective)] = fp.digest
    assert len(set(digests.values())) == len(digests), (
        "method/objective fingerprint matrix aliased: "
        f"{sorted(digests)}")


def test_result_cache_isolated_across_methods_and_objectives():
    """Engine-level satellite 3: a 'pivot' winner in a shared cache must
    never be served to a 'precluster' admission of the same (graph, key),
    nor a 'disagree' winner to a 'minmax' engine — each is a cold miss
    that re-flushes and retires its own method's bit-exact result."""
    shared = ResultCache(capacity=64)
    g = _rand_graph(14, 1, seed=3)
    key = jax.random.PRNGKey(5)

    a = ClusterBatcher(max_batch=1, result_cache=shared)
    done = {r.uid: r for r in a.admit(ClusterRequest(uid=0, graph=g,
                                                     key=key))}
    done.update((r.uid, r) for r in a.flush())
    assert shared.stats.insertions == 1

    # Same engine, same graph+key, other method: must miss and re-flush.
    done.update((r.uid, r)
                for r in a.admit(ClusterRequest(uid=1, graph=g, key=key,
                                                method="precluster")))
    done.update((r.uid, r) for r in a.flush())
    assert a.stats.cache_hits == 0 and a.stats.flushes == 2
    assert done[1].result.method == "precluster"
    _assert_matches(g, key, done[0].result)
    _assert_matches(g, key, done[1].result, method="precluster")

    # A minmax engine on the same shared cache: same content, other
    # objective — also a miss; its inserted winner is a third entry.
    b = ClusterBatcher(max_batch=1, result_cache=shared,
                       objective="minmax")
    out = b.admit(ClusterRequest(uid=2, graph=g, key=key))
    out.extend(b.flush())
    assert b.stats.cache_hits == 0 and b.stats.flushes == 1
    assert shared.stats.insertions == 3

    # Control: the isolation is per-key, not a broken cache — replaying
    # the original (method, objective) is still a pure hit.
    hit = a.admit(ClusterRequest(uid=3, graph=g, key=key))
    assert len(hit) == 1 and a.stats.cache_hits == 1
    assert (hit[0].result.labels == done[0].result.labels).all()


def test_fingerprint_distinguishes_same_bucket_different_graphs():
    """Two graphs landing in the same (R, W) bucket must not alias."""
    a = build_graph(6, path(6))
    b = build_graph(7, path(7))             # same (8, 4) bucket
    key = jax.random.PRNGKey(0)
    pa, pb = plan_graph(a), plan_graph(b)
    assert pa.bucket == pb.bucket
    assert graph_fingerprint(pa, key).digest != \
        graph_fingerprint(pb, key).digest


# ---------------------------------------------------------------------------
# ResultCache store: LRU bounds, counters, collision verification.
# ---------------------------------------------------------------------------


def _fp(tag: str) -> GraphFingerprint:
    import hashlib

    payload = tag.encode()
    return GraphFingerprint(
        digest=hashlib.blake2b(payload, digest_size=16).hexdigest(),
        payload=payload)


def test_result_cache_lru_eviction_and_counters():
    cache = ResultCache(capacity=2)
    labels = np.arange(4, dtype=np.int32)
    cache.put(_fp("a"), labels, 1, 0, 2)
    cache.put(_fp("b"), labels, 2, 0, 2)
    assert cache.get(_fp("a")) is not None      # refreshes a's recency
    cache.put(_fp("c"), labels, 3, 0, 2)        # evicts b (LRU)
    assert cache.get(_fp("b")) is None
    assert cache.get(_fp("a")) is not None
    assert cache.get(_fp("c")) is not None
    s = cache.stats
    assert (s.hits, s.misses, s.evictions, s.insertions) == (3, 1, 1, 3)
    assert s.entries == 2 and len(cache) == 2
    assert s.bytes > 0


def test_result_cache_byte_bound_and_owned_labels():
    cache = ResultCache(capacity=100, max_bytes=1200)
    src = np.arange(64, dtype=np.int32)
    cache.put(_fp("a"), src, 1, 0, 2)
    src[:] = -1                                  # cache must own a copy
    labels, cost, picked, rounds = cache.get(_fp("a"))
    assert (labels == np.arange(64)).all()
    assert (cost, picked, rounds) == (1, 0, 2)
    cache.put(_fp("b"), np.arange(64, dtype=np.int32), 2, 1, 3)
    cache.put(_fp("c"), np.arange(64, dtype=np.int32), 3, 1, 3)
    assert cache.stats.evictions >= 1            # byte bound enforced
    assert cache.stats.bytes <= 1200


def test_result_cache_collision_is_detected_not_served():
    """Same digest, different canonical payload ⇒ counted collision, miss."""
    cache = ResultCache(capacity=4)
    real = _fp("real")
    forged = GraphFingerprint(digest=real.digest, payload=b"forged")
    cache.put(real, np.zeros(3, np.int32), 0, 0, 1)
    assert cache.get(forged) is None
    assert cache.stats.collisions == 1
    assert cache.get(real) is not None           # resident entry untouched


def test_result_cache_put_is_idempotent():
    cache = ResultCache(capacity=4)
    cache.put(_fp("a"), np.zeros(3, np.int32), 0, 0, 1)
    bytes0 = cache.stats.bytes
    cache.put(_fp("a"), np.zeros(3, np.int32), 0, 0, 1)
    assert cache.stats.insertions == 1 and cache.stats.bytes == bytes0


def test_make_result_cache_specs():
    assert make_result_cache(None) is None
    assert make_result_cache(False) is None
    assert make_result_cache(True).capacity == ResultCache().capacity
    assert make_result_cache(17).capacity == 17
    shared = ResultCache(capacity=3)
    assert make_result_cache(shared) is shared
    with pytest.raises(ValueError, match="result_cache"):
        make_result_cache("yes")


# ---------------------------------------------------------------------------
# Cache hits: bit-exact with the cold flush, across executors × policies.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
@pytest.mark.parametrize("policy", ["deadline", "coalesce", "cost"])
def test_cache_hit_bit_exact_across_executors_and_policies(executor, policy):
    """Cold flush, then identical repeat admissions: every hit must return
    labels/cost/picked bit-identical to the cold result and the per-graph
    engine, under every executor and policy combination."""
    graphs = [build_graph(6, path(6)), _rand_graph(12, 2, seed=2),
              _rand_graph(20, 2, seed=3)]
    batcher = ClusterBatcher(max_batch=4, max_wait=0.01, executor=executor,
                             policy=policy, num_samples=2)
    cold = {}
    for uid, g in enumerate(graphs):
        for r in batcher.admit(ClusterRequest(uid=uid, graph=g,
                                              key=jax.random.PRNGKey(uid))):
            cold[r.uid] = r
    for r in batcher.flush():
        cold[r.uid] = r
    assert sorted(cold) == [0, 1, 2]
    assert batcher.stats.cache_hits == 0

    for uid, g in enumerate(graphs):
        # Fresh objects, same content + key: must hit, retiring at admit.
        out = batcher.admit(ClusterRequest(
            uid=100 + uid, graph=build_graph(g.n, _edges_of(g)),
            key=jax.random.PRNGKey(uid)))
        assert [r.uid for r in out] == [100 + uid]
        hit = out[0]
        assert (hit.result.labels == cold[uid].result.labels).all()
        assert hit.result.cost == cold[uid].result.cost
        assert hit.result.info == cold[uid].result.info
        _assert_matches(g, jax.random.PRNGKey(uid), hit.result,
                        num_samples=2)
    assert batcher.stats.cache_hits == 3
    assert batcher.stats.flushes == batcher.stats.cache_misses >= 1 \
        or batcher.stats.flushes >= 1   # hits added no flushes
    assert batcher.pending() == 0
    batcher.close()


def _edges_of(g):
    und = g.undirected_edges()
    return [(int(u), int(v)) for u, v in und]


# ---------------------------------------------------------------------------
# Single-flight: subscribers ride the primary's flush, invisibly to the
# scheduler, and survive a poisoned flush via the requeue path.
# ---------------------------------------------------------------------------


def test_single_flight_subscriber_rides_primary_flush():
    g = build_graph(10, path(10))
    batcher = ClusterBatcher(max_batch=2)
    key = jax.random.PRNGKey(5)
    r_primary = ClusterRequest(uid=0, graph=g, key=key)
    r_dup = ClusterRequest(uid=1, graph=build_graph(10, path(10)), key=key)
    batcher.admit(r_primary)
    batcher.admit(r_dup)
    # The duplicate subscribed: not queued, bucket depth stays 1, so the
    # full-bucket policy correctly did not flush a "full" 2-bucket.
    bucket = r_primary.plan.queue_key
    assert [r.uid for r in batcher.buckets[bucket]] == [0]
    assert batcher.stats.subscribed == 1 and batcher.stats.flushes == 0
    assert batcher.pending() == 2

    done = {r.uid: r for r in batcher.flush()}
    assert sorted(done) == [0, 1]
    assert (done[0].result.labels == done[1].result.labels).all()
    assert done[0].result.cost == done[1].result.cost
    _assert_matches(g, key, done[1].result)
    assert batcher.stats.clustered == 2         # one row, two results
    assert batcher.pending() == 0
    # The winner was cached: a third identical admit is a pure hit.
    out = batcher.admit(ClusterRequest(uid=2, graph=build_graph(10, path(10)),
                                       key=key))
    assert [r.uid for r in out] == [2] and batcher.stats.cache_hits == 1


class _WithholdingExecutor(AsyncExecutor):
    """Refuses to retire handles while ``withhold`` is set, keeping
    submitted flushes pinned in flight from the batcher's point of view."""

    def __init__(self):
        super().__init__()
        self.withhold = False

    def retire(self):
        if self.withhold:
            return []
        return super().retire()


def test_subscriber_to_in_flight_request():
    """A duplicate arriving while the primary is already *in flight* (not
    queued) must still subscribe, not pack a new row."""
    ex = _WithholdingExecutor()
    g = build_graph(8, path(8))
    key = jax.random.PRNGKey(9)
    batcher = ClusterBatcher(max_batch=2, executor=ex)
    ex.withhold = True
    batcher.admit(ClusterRequest(uid=0, graph=g, key=key))
    batcher.admit(ClusterRequest(uid=1, graph=build_graph(6, path(6)),
                                 key=jax.random.PRNGKey(1)))
    batcher.admit(ClusterRequest(uid=2, graph=build_graph(8, path(8)),
                                 key=jax.random.PRNGKey(2)))   # fills (8,4)
    # (8, 4) flushed but withheld from harvest; admit a duplicate of uid=0
    # while its primary is in flight.
    assert batcher.stats.flushes == 1
    dup = ClusterRequest(uid=3, graph=build_graph(8, path(8)), key=key)
    batcher.admit(dup)
    assert batcher.stats.subscribed == 1 and batcher.stats.cache_hits == 0
    ex.withhold = False
    done = {r.uid: r for r in batcher.flush()}
    assert sorted(done) == [0, 1, 2, 3]
    assert (done[3].result.labels == done[0].result.labels).all()
    assert done[3].result.cost == done[0].result.cost
    _assert_matches(g, key, done[3].result)


class _ExplodingOutput:
    """Device-output stand-in: reports ready, then fails the fetch."""

    def is_ready(self):
        return True

    def __array__(self, *args, **kwargs):
        raise RuntimeError("device fetch exploded")


class _PoisonOnceExecutor(AsyncExecutor):
    """Poisons the next submitted flush's outputs so its fetch fails —
    the poisoned-handle path of the harvest."""

    def __init__(self):
        super().__init__()
        self.poison_next = False

    def _post_submit(self, handle):
        if self.poison_next:
            handle._outputs = (_ExplodingOutput(),) * 4
            self.poison_next = False


def test_subscribers_requeue_and_retry_on_poisoned_flush():
    """A failed flush requeues its primaries with subscribers attached —
    the retry serves both, bit-exactly; nobody is dropped."""
    ex = _PoisonOnceExecutor()
    batcher = ClusterBatcher(max_batch=4, executor=ex)
    g = build_graph(10, path(10))
    key = jax.random.PRNGKey(4)
    primary = ClusterRequest(uid=0, graph=g, key=key)
    batcher.admit(primary)
    dup = ClusterRequest(uid=1, graph=build_graph(10, path(10)), key=key)
    batcher.admit(dup)                           # subscribes to primary
    assert batcher.stats.subscribed == 1
    other = ClusterRequest(uid=2, graph=build_graph(6, path(6)),
                           key=jax.random.PRNGKey(2))  # different bucket
    batcher.admit(other)
    # Poison the next submitted flush — buckets drain in insertion order,
    # so the primary's bucket gets the bad handle; ``other``'s is clean.
    ex.poison_next = True
    with pytest.raises(RuntimeError, match="exploded"):
        batcher.flush()                          # poisoned fetch surfaces
    # Primary is back in its native bucket, subscriber still attached.
    bucket = primary.plan.queue_key
    assert primary in batcher.buckets.get(bucket, [])
    assert dup in primary.subscribers and not dup.done
    assert batcher.pending() == 2                # other already harvested
    done = {r.uid: r for r in batcher.flush()}   # clean retry
    assert sorted(done) == [0, 1, 2]
    assert (done[1].result.labels == done[0].result.labels).all()
    _assert_matches(g, key, done[1].result)
    _assert_matches(other.graph, jax.random.PRNGKey(2), done[2].result)
    assert batcher.pending() == 0


def test_cache_disabled_means_no_fingerprints_no_coalescing():
    g = build_graph(10, path(10))
    key = jax.random.PRNGKey(0)
    batcher = ClusterBatcher(max_batch=4, result_cache=False)
    r1 = ClusterRequest(uid=0, graph=g, key=key)
    r2 = ClusterRequest(uid=1, graph=build_graph(10, path(10)), key=key)
    batcher.admit(r1)
    batcher.admit(r2)
    assert r1.fingerprint is None and r2.fingerprint is None
    assert [r.uid for r in batcher.buckets[r1.plan.queue_key]] == [0, 1]
    assert batcher.stats.subscribed == 0 and batcher.stats.cache_hits == 0
    assert batcher.stats.result_cache is None
    done = {r.uid: r for r in batcher.flush()}
    assert (done[0].result.labels == done[1].result.labels).all()


def test_shared_cache_across_engines():
    """A ResultCache instance passed to two engines shares winners: the
    second engine's first admission of known content is a pure hit."""
    shared = ResultCache(capacity=64)
    g = build_graph(12, path(12))
    key = jax.random.PRNGKey(6)
    a = ClusterBatcher(max_batch=1, result_cache=shared)
    done_a = {r.uid: r
              for r in a.admit(ClusterRequest(uid=0, graph=g, key=key))}
    done_a.update((r.uid, r) for r in a.flush())
    b = ClusterBatcher(max_batch=1, result_cache=shared)
    out = b.admit(ClusterRequest(uid=0, graph=build_graph(12, path(12)),
                                 key=key))
    assert len(out) == 1 and b.stats.cache_hits == 1
    assert b.stats.flushes == 0
    assert (out[0].result.labels == done_a[0].result.labels).all()
    assert shared.stats.hits == 1
    # Engine-level misses are per engine; the shared stats object is the
    # cache's own lifetime view, surfaced on both engines' stats.
    assert a.stats.result_cache is shared.stats
    assert b.stats.result_cache is shared.stats


def test_eviction_causes_refetch_not_wrong_result():
    """A capacity-1 cache alternating two graphs always re-flushes the
    evicted one — never serves the wrong entry."""
    cache = ResultCache(capacity=1)
    batcher = ClusterBatcher(max_batch=1, result_cache=cache)
    g_a, g_b = build_graph(6, path(6)), build_graph(7, path(7))
    for rep in range(2):
        for uid, g in ((0, g_a), (1, g_b)):
            out = batcher.admit(ClusterRequest(
                uid=10 * rep + uid, graph=build_graph(g.n, _edges_of(g)),
                key=jax.random.PRNGKey(uid)))
            out.extend(batcher.flush())
            _assert_matches(g, jax.random.PRNGKey(uid), out[0].result)
    assert cache.stats.evictions >= 2
    assert batcher.stats.cache_hits == 0         # always evicted in between
    assert batcher.stats.clustered == 4


# ---------------------------------------------------------------------------
# Stats: snapshot() deep-copies the nested mutable fields.
# ---------------------------------------------------------------------------


def test_stats_snapshot_is_deep():
    batcher = ClusterBatcher(max_batch=1)
    snap = batcher.stats.snapshot()
    batcher.admit(ClusterRequest(uid=0, graph=build_graph(6, path(6)),
                                 key=jax.random.PRNGKey(0)))
    batcher.flush()
    live = batcher.stats
    assert live.latency.total_flushes - snap.latency.total_flushes == 1
    assert live.result_cache.insertions - snap.result_cache.insertions == 1
    # The shallow copy this replaces would alias both nested objects and
    # read deltas of zero.
    import dataclasses as dc

    shallow = dc.replace(live)
    assert shallow.latency is live.latency
    assert batcher.stats.snapshot().latency is not live.latency
