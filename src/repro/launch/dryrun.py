import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract memory/cost/collective analyses.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 host placeholder
devices. Run as::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--out artifacts/...json]

Exit code 0 = compile succeeded (memory_analysis + cost_analysis recorded).
"""

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, supports_shape
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import RunConfig, build_model, mesh_axis_sizes, resolve_plan
from repro.train.optimizer import OptConfig
from repro.train.train_step import StepConfig, abstract_train_state, make_train_step


# Per-arch scale policy: grad-accum steps for train_4k, optimizer-state
# dtype, and remat. Derived from the v5e HBM budget (see DESIGN.md §5).
POLICY = {
    "whisper-base":         dict(accum=1,  state_dtype="float32"),
    "qwen3-8b":             dict(accum=8,  state_dtype="float32"),
    "granite-3-2b":         dict(accum=4,  state_dtype="float32"),
    "stablelm-12b":         dict(accum=8,  state_dtype="float32"),
    "smollm-135m":          dict(accum=1,  state_dtype="float32"),
    "olmoe-1b-7b":          dict(accum=2,  state_dtype="float32"),
    "grok-1-314b":          dict(accum=16, state_dtype="bfloat16",
                                 accum_dtype="bfloat16"),
    "zamba2-2.7b":          dict(accum=8,  state_dtype="float32"),
    "rwkv6-1.6b":           dict(accum=4,  state_dtype="float32"),
    "llama-3.2-vision-90b": dict(accum=16, state_dtype="bfloat16",
                                  accum_dtype="bfloat16"),
}


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             attn_impl: str = "chunked", moe_impl: str = "sort",
             accum: int | None = None, remat: bool = True,
             compress: bool = False, save_hlo: str | None = None,
             expert_mode: str = "auto", moe_token_chunk: int = 8192,
             reduce_dtype: str = "f32") -> dict:
    from repro.models.common import set_matmul_reduce_dtype
    set_matmul_reduce_dtype(reduce_dtype)
    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axis_sizes(mesh)
    chips = mesh.devices.size
    plan = resolve_plan(cfg, shape, axes, expert_mode=expert_mode)
    pol = POLICY[arch]
    accum = accum if accum is not None else (
        pol["accum"] if shape.kind == "train" else 1)
    # Clamp: each microbatch must still divide the batch-sharding span.
    batch_ax = plan.axes.get("batch")
    span = 1
    if batch_ax is not None:
        for a in ((batch_ax,) if isinstance(batch_ax, str) else batch_ax):
            span *= axes[a]
    while accum > 1 and (shape.global_batch // accum) % span != 0:
        accum //= 2

    rc = RunConfig(attn_impl=attn_impl, moe_impl=moe_impl,
                   moe_token_chunk=moe_token_chunk,
                   remat=(remat and shape.kind == "train"),
                   mesh=mesh if moe_impl == "ep_local" else None)
    model = build_model(cfg, plan=plan, rc=rc, param_dtype=jnp.bfloat16)
    params_sds, param_specs = model.abstract_params()
    in_specs = model.input_specs(shape)
    in_shard = model.input_shardings(shape)

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "chips": chips, "kind": shape.kind,
        "plan": {k: str(v) for k, v in plan.axes.items()},
        "accum": accum, "attn_impl": attn_impl, "moe_impl": moe_impl,
        "expert_mode": expert_mode, "moe_token_chunk": moe_token_chunk,
        "reduce_dtype": reduce_dtype,
        "param_count": int(cfg.param_count()),
        "param_bytes": _tree_bytes(params_sds),
    }

    opt_bytes = 0
    cache_bytes = 0
    if shape.kind == "train":
        oc = OptConfig(state_dtype=pol["state_dtype"])
        sc = StepConfig(accum_steps=accum, compress_cross_pod=compress,
                        accum_dtype=pol.get("accum_dtype", "float32"))
        state_sds, state_specs = abstract_train_state(model, oc, sc)
        opt_bytes = _tree_bytes(state_sds.opt.mu) * 2
        step = make_train_step(model, oc, sc)
        batch_sds = {k: in_specs[k] for k in in_specs}
        metric_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
        fn = jax.jit(
            step,
            in_shardings=(_shardings(mesh, state_specs),
                          _shardings(mesh, in_shard)),
            out_shardings=(_shardings(mesh, state_specs),
                           _shardings(mesh, metric_specs)),
            donate_argnums=(0,),
        )
        args = (state_sds, batch_sds)
    elif shape.kind == "prefill":
        caches_sds, cache_specs = model.abstract_caches(
            shape.global_batch, shape.seq_len)
        cache_bytes = _tree_bytes(caches_sds)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len)

        logit_spec = plan.P("batch", "vocab")
        fn = jax.jit(
            prefill_fn,
            in_shardings=(_shardings(mesh, param_specs),
                          _shardings(mesh, in_shard)),
            out_shardings=(NamedSharding(mesh, logit_spec),
                           _shardings(mesh, cache_specs)),
        )
        args = (params_sds, in_specs)
    else:  # decode
        caches_sds, cache_specs = model.abstract_caches(
            shape.global_batch, shape.seq_len)
        cache_bytes = _tree_bytes(caches_sds)

        def decode_fn(params, token, caches, pos):
            return model.decode_step(params, token, caches, pos)

        logit_spec = plan.P("batch", "vocab")
        fn = jax.jit(
            decode_fn,
            in_shardings=(_shardings(mesh, param_specs),
                          NamedSharding(mesh, plan.P("batch")),
                          _shardings(mesh, cache_specs),
                          NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, logit_spec),
                           _shardings(mesh, cache_specs)),
            donate_argnums=(2,),
        )
        args = (params_sds, in_specs["token"], caches_sds,
                jax.ShapeDtypeStruct((), jnp.int32))

    result["opt_bytes"] = opt_bytes
    result["cache_bytes"] = cache_bytes

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = rl.collective_stats(hlo, default_participants=chips)
    if save_hlo:
        Path(save_hlo).write_text(hlo)

    flops = rl.step_flops(cfg, shape, moe_impl=moe_impl)
    if shape.kind == "train" and rc.remat:
        # remat recomputes the forward in the backward: ~4/3 of fwd+bwd.
        flops = flops + rl.forward_flops(cfg, shape.global_batch,
                                         shape.seq_len, moe_impl=moe_impl)
    bytes_hbm = rl.hbm_bytes(cfg, shape, result["param_bytes"], cache_bytes,
                             opt_bytes)
    roof = rl.Roofline(
        chips=chips,
        flops=flops,
        bytes_hbm=bytes_hbm,
        coll_bytes=coll.total_bytes,
        hlo_flops_raw=float(cost.get("flops", 0.0)),
        hlo_bytes_raw=float(cost.get("bytes accessed", 0.0)),
        model_flops_=rl.model_flops(cfg, shape),
    )

    result.update(
        status="ok",
        lower_s=round(t_lower - t0, 1),
        compile_s=round(t_compile - t_lower, 1),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            per_device_total=(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + mem.output_size_in_bytes
                              - mem.alias_size_in_bytes),
        ),
        collectives=dict(bytes=coll.bytes_by_kind, counts=coll.count_by_kind),
        roofline=roof.as_dict(),
    )
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--moe-impl", default="sort")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--expert-mode", default="auto", choices=["auto","ep","tp"])
    ap.add_argument("--reduce-dtype", default="f32", choices=["f32","bf16"])
    ap.add_argument("--moe-token-chunk", type=int, default=8192)
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args(argv)

    try:
        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       attn_impl=args.attn_impl, moe_impl=args.moe_impl,
                       accum=args.accum, remat=not args.no_remat,
                       compress=args.compress, save_hlo=args.save_hlo,
                       expert_mode=args.expert_mode,
                       moe_token_chunk=args.moe_token_chunk,
                       reduce_dtype=args.reduce_dtype)
    except Exception as e:  # record the failure mode — it is a bug signal
        import traceback
        res = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multi_pod, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}

    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(res, indent=2))
    dump = {k: v for k, v in res.items() if k != "traceback"}
    print(json.dumps(dump, indent=2))
    if res["status"] == "ok":
        m = res["memory"]
        print(f"\n== {args.arch} × {args.shape} "
              f"{'(2 pods, 512 chips)' if args.multi_pod else '(1 pod, 256 chips)'} ==")
        print(f"per-device bytes: args={m['argument_bytes']/2**30:.2f}GiB "
              f"temp={m['temp_bytes']/2**30:.2f}GiB "
              f"total={m['per_device_total']/2**30:.2f}GiB")
        r = res["roofline"]
        print(f"roofline: compute={r['t_compute_s']:.4f}s "
              f"memory={r['t_memory_s']:.4f}s "
              f"collective={r['t_collective_s']:.4f}s "
              f"→ {r['bottleneck']}-bound; useful={r['useful_ratio']:.2f} "
              f"frac={r['roofline_fraction']:.2f}")
    return 0 if res["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
