"""stablelm-12b [dense]: 40L, d=5120, 32H (GQA kv=8), ff=13824,
vocab=100352. [hf:stabilityai/stablelm-2-12b; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        d_ff=13824, vocab_size=100352, head_dim=160, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, vocab_round=64,
    )
