"""Continuous batching for clustering-as-a-service — the *mechanics* half.

Implements the :class:`repro.serve.engine.ClusterEngine` protocol for graph
queries: incoming graphs are **admitted** into the ``(method, R, W)`` queue
their registered bucket program and padded shape map to, buckets **flush**
through the injected :class:`~repro.core.executor.BucketExecutor`, and
flushed requests **retire** with their results attached. One engine serves
mixed-method traffic: a request may carry its own ``method`` (defaulting to
the engine's), and because a bucket program runs exactly one registered
method per flush, queues coalesce only within a method — policies never
see, and must never propose, a cross-method steal (``_execute`` refuses one
with a ``ValueError`` if a custom policy tries). *When* a bucket flushes, at what
sub-batch size, whether an admission is refused, and whether a flush steals
work from a starving neighbour bucket are not decided here: every decision
is delegated to the injected :class:`~repro.serve.scheduler.SchedulerPolicy`
(``policy=``), and this class only executes the
:class:`~repro.serve.scheduler.FlushDecision` values it returns. The
batcher owns the queues, the staging leases, the packing, the harvest, and
the stats — the policy owns the schedule.

Scheduling policies (see :mod:`repro.serve.scheduler` for the full story)
  ``policy=`` takes ``'full'`` (flush only full buckets), ``'deadline'``
  (bound any request's wait by ``max_wait``), ``'adaptive'`` (deadline +
  a dynamic in-flight admission window derived from observed flush
  latency, replacing the static ``max_in_flight`` knob), ``'coalesce'``
  (work-stealing: starving smaller-bucket requests are promoted into a
  compatible larger bucket's flush via
  :func:`repro.core.plan.promote_plan`), ``'cost'`` (coalescing with each
  steal priced by :class:`~repro.serve.costmodel.FlushCostModel` — taken
  only when the wait it saves covers the pad/compile cost it adds — plus
  shape-heat eviction hints to the compiled-program LRU), any
  :class:`~repro.serve.scheduler.SchedulerPolicy` instance, or ``None`` —
  which reproduces the historical behaviour from ``max_wait`` /
  ``max_in_flight`` alone. A policy *instance* carries its own knobs:
  combining one with ``max_wait``/``max_in_flight`` raises ``ValueError``
  instead of silently ignoring the knobs.

Executor injection (how a flush reaches the device)
  ``ClusterBatcher(executor=...)`` takes ``'sync'`` (block per flush — the
  classic path), ``'async'`` (non-blocking dispatch: the batcher packs and
  flushes the next bucket while the previous one computes and transfers;
  completed flushes are harvested on the next ``admit``/``poll``/``retire``),
  ``'sharded'`` (one flush data-parallel across all local devices via
  ``shard_map``), or any :class:`BucketExecutor` instance. Results are
  bit-identical under every executor *and every policy* — scheduling can
  never change an answer, including coalesced flushes where a request runs
  at a promoted ``(R, W)`` shape. An executor instance must not be shared
  between engines: the batcher harvests *all* of its executor's handles.

Admission backpressure (bounded in-flight work)
  The policy's ``on_admit`` gate refuses requests while its admission
  window is full — ``admit`` raises :class:`AdmissionRejected` (counted in
  ``stats.rejected``), the signal a front-end needs to shed load instead
  of queueing unboundedly when arrivals outrun the device. The static
  window is ``max_in_flight``; the adaptive policy derives a dynamic one
  from flush-latency telemetry.

Admission-time packing (build/assemble split)
  With ``prebuild_rows=True`` (default) every cold admission finishes its
  per-graph packing work right away: :func:`repro.core.plan.
  build_packed_rows` scatters the plan's canonical edge list into the
  graph's :class:`~repro.core.plan.PackedRows` and dispatches its rank
  permutations, once per request. Flushes then *assemble* buckets by row
  copies into the leased staging arrays — the argsort/bincount host work
  leaves the flush critical path, which is what the admission-time split
  buys (JetStream-style: per-request preprocessing at admission, batch
  assembly a memcpy). ``prebuild_rows=False`` keeps the legacy
  derive-at-flush packing; both paths are bit-identical and the
  ``pack_split`` scenario in ``benchmarks/serve_bench.py`` asserts the
  assemble-vs-pack latency win.

Telemetry (the policies' stats surface)
  Every harvested flush records its host bucket-assembly time and
  submit→fetch wall time — stamped by the executor layer on the
  :class:`~repro.core.executor.InFlightBucket` handle — into
  ``stats.latency`` (a :class:`~repro.serve.scheduler.FlushTelemetry`),
  keyed by bucket shape; prebuilt admissions record their per-request
  row-build time into the same telemetry's ``build`` stream. Policies
  read the EWMAs; benchmarks emit the p50/p99 summaries.

Buffer reuse
  All flushes route through one :class:`repro.core.plan.BucketBufferPool`:
  host staging arrays per bucket shape are **leased** per flush, refilled
  in place, and run through the donated device program. A lease is only
  released once its flush's outputs are fetched, so pipelined flushes of
  the same bucket shape get distinct buffer generations — a buffer feeding
  an in-flight program is never refilled.

Result cache + single-flight coalescing (repeat traffic)
  ``ClusterBatcher(result_cache=...)`` content-addresses every admission
  by :func:`repro.core.plan.graph_fingerprint` — the canonical hash of
  the planned request's ELL content, exact PRNG key, and
  ``method``/``num_samples``/``eps``. A fingerprint found in the
  :class:`~repro.serve.resultcache.ResultCache` retires at admission,
  bit-identical to a cold flush (only post-selection winners are cached,
  keyed on the exact key). A fingerprint matching a *queued or in-flight*
  request subscribes to that flush's harvest instead of packing a
  duplicate row; subscribers stay attached to their primary through the
  requeue-on-error path, so a failed flush retries them. Subscribers
  never appear in the bucket queues and neither cached nor subscribed
  admissions consult the policy's ``on_admit`` gate — they add no device
  work, so policies see exactly the queue depths/ages that will pack.

Clocks
  The engine clock (``clock=``, monotonic seconds, injectable) is the
  *only* time source scheduling decisions see: ``admitted_at`` stamps,
  deadline ages, steal thresholds. No code path falls back to a bare
  ``time.monotonic()`` call, so tests and simulators drive virtual time
  deterministically. (Telemetry wall/pack latencies are real wall-clock
  measurements from the executor layer — they describe the device, not
  the request stream.)
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BucketBufferPool, make_executor, plan_graph
from repro.core.api import ClusterResult, sample_keys
from repro.core.executor import pack_and_submit
from repro.core.graph import Graph
from repro.core.plan import (GraphFingerprint, GraphPlan,
                             build_packed_rows, graph_fingerprint,
                             promote_plan, result_for_plan)
from repro.core.programs import method_spec, objective_spec
from repro.util import next_pow2

from .engine import AdmissionRejected, EngineStats
from .resultcache import ResultCacheStats, make_result_cache
from .scheduler import FlushDecision, FlushTelemetry, make_policy


@dataclasses.dataclass
class ClusterRequest:
    uid: int
    graph: Graph
    key: jax.Array
    lam: Optional[int] = None
    method: Optional[str] = None    # None = the engine's default method
    result: Optional[ClusterResult] = None
    done: bool = False
    admitted_at: Optional[float] = None     # engine clock time of admission
    plan: Optional[GraphPlan] = None        # resolved once at admission
    fingerprint: Optional[GraphFingerprint] = None  # content address (cache)
    # Single-flight: identical requests admitted while this one is queued
    # or in flight ride its harvest instead of packing duplicate rows.
    subscribers: List["ClusterRequest"] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class ClusterStats(EngineStats):
    flushes: int = 0
    deadline_flushes: int = 0    # partial flushes forced by max_wait
    coalesced_flushes: int = 0   # flushes that stole from another bucket
    stolen_requests: int = 0     # requests promoted into a larger bucket
    clustered: int = 0
    padded_slots: int = 0        # empty device entries, from the packer
    pad_vertex_waste: int = 0    # Σ (R − n) over clustered graphs
    buckets_seen: int = 0        # distinct (method, R, W) queues admitted
    rejected: int = 0            # admissions refused by backpressure
    in_flight_peak: int = 0      # max concurrent in-flight flushes seen
    cache_misses: int = 0        # admissions that went the cold path
    subscribed: int = 0          # single-flight riders on identical requests
    latency: FlushTelemetry = dataclasses.field(
        default_factory=FlushTelemetry)  # per-bucket flush wall/pack times
    # Autotune telemetry from the last warmup(autotune=True): tuning-cache
    # counters (hits/misses/stale/sweeps) + per-tier sweep records.
    tuning: Optional[dict] = None
    # Live counters of the engine's result cache (None = caching off).
    # Cache-lifetime, not engine-lifetime, when the cache is shared
    # between engines; the scalar cache_hits/cache_misses above are this
    # engine's own. Mutable and aliased to the cache — delta accounting
    # must go through EngineStats.snapshot(), not dataclasses.replace.
    result_cache: Optional[ResultCacheStats] = None


class ClusterBatcher:
    """Bucketed clustering engine: queue/lease/harvest mechanics, with all
    flush/admission decisions delegated to a scheduling policy.

    Implements the :class:`~repro.serve.engine.ClusterEngine` protocol
    (``admit`` / ``flush`` / ``retire`` / ``stats`` / ``pending``), plus
    :meth:`poll` to give time-based policies (deadline, coalescing) a tick.

    Args:
      max_batch: bucket capacity; the default policies flush a bucket when
        it holds this many requests.
      max_wait: optional deadline in seconds (engine-clock). With the
        default policy selection, setting it selects the deadline policy:
        ``poll()`` flushes any bucket whose oldest request has waited
        longer, padded to the next power-of-two sub-batch. ``None`` = full
        buckets only.
      clock: the engine clock (monotonic seconds). Injectable so tests and
        simulators can drive virtual time; ``None`` selects
        ``time.monotonic``. Every scheduling decision uses this clock and
        nothing else.
      num_samples: best-of-k PIVOT per request (``< 1`` is coerced to 1;
        the engine itself rejects invalid values).
      method: the engine's default bucket program (any method registered
        in :mod:`repro.core.programs`); a request carrying its own
        ``method`` overrides it per-admission — one engine serves mixed
        ``'pivot'``/``'precluster'`` traffic, with queues, result-cache
        fingerprints and steal compatibility all keyed per method.
      objective: the registered cost pass scoring samples before
        best-of-k selection (``'disagree'`` default, ``'minmax'``);
        engine-wide, carried into every fingerprint and flush.
      pool: buffer pool shared by all flushes (created if omitted).
      executor: bucket executor name (``'sync'``/``'async'``/``'sharded'``)
        or instance — see the module docstring. Default ``'sync'``.
      max_in_flight: optional static bound on concurrently in-flight
        flushes; the policy's ``on_admit`` gate raises
        :class:`AdmissionRejected` at the bound. ``None`` disables
        backpressure (one-shot / offline driving).
      policy: scheduling policy name (``'full'``/``'deadline'``/
        ``'adaptive'``/``'coalesce'``/``'cost'``) or
        :class:`~repro.serve.scheduler.SchedulerPolicy` instance; ``None``
        derives the historical behaviour from ``max_wait``/``max_in_flight``.
        An instance must carry its own ``max_wait``/``max_in_flight`` —
        passing those knobs alongside one raises ``ValueError``.
      result_cache: content-addressed result cache + single-flight
        coalescing. ``True`` (default) creates a default-sized
        :class:`~repro.serve.resultcache.ResultCache`; ``False``/``None``
        disables both (every admission packs and flushes); an ``int``
        sets the entry capacity; a :class:`ResultCache` instance is
        shared as-is (e.g. one cache across engines/corpora). A cache
        hit retires at admission, bit-identical to a cold flush — the
        fingerprint covers the exact PRNG key, so caching never trades
        determinism for speed.
      prebuild_rows: build each cold admission's
        :class:`~repro.core.plan.PackedRows` at admission (default), so
        flushes assemble buckets by row copies instead of re-deriving
        every graph's ELL rows. ``False`` restores the legacy
        derive-at-flush packing — bit-identical results either way (the
        benchmark's ``pack_split`` scenario runs both arms).
    """

    def __init__(self, max_batch: int = 64, method: str = "pivot",
                 eps: float = 2.0, num_samples: int = 1,
                 objective: str = "disagree",
                 use_kernel: bool = False,
                 max_wait: Optional[float] = None,
                 clock=None,
                 pool: Optional[BucketBufferPool] = None,
                 executor="sync",
                 max_in_flight: Optional[int] = None,
                 policy=None,
                 result_cache=True,
                 prebuild_rows: bool = True):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait is not None and max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.max_batch = max_batch
        self.method = method
        method_spec(method)          # fail fast, listing registered methods
        objective_spec(objective)
        self.objective = objective
        self.eps = eps
        self.num_samples = max(1, num_samples)
        self.use_kernel = use_kernel
        self.max_wait = max_wait
        self.clock = time.monotonic if clock is None else clock
        self.pool = pool if pool is not None else BucketBufferPool()
        self.executor = make_executor(executor)
        self.max_in_flight = max_in_flight
        self.prebuild_rows = prebuild_rows
        self.policy = make_policy(policy, max_batch=max_batch,
                                  max_wait=max_wait,
                                  max_in_flight=max_in_flight)
        # Policies that price decisions (the cost-aware coalescer) need the
        # engine's execution profile — group padding rule, best-of-k count,
        # compiled-program signature. Optional structural hook.
        bind = getattr(self.policy, "bind_engine", None)
        if bind is not None:
            bind(executor=self.executor, num_samples=self.num_samples,
                 use_kernel=self.use_kernel, donate=self.pool.donate,
                 objective=self.objective)
        self.result_cache = make_result_cache(result_cache)
        # Queues keyed by GraphPlan.queue_key = (method, R, W): requests
        # coalesce only when they share both the padded shape and the
        # bucket program that will run them.
        self.buckets: Dict[Tuple[str, int, int], List[ClusterRequest]] = {}
        self._bucket_keys_seen: set = set()
        self._retired: Deque[ClusterRequest] = deque()
        self._in_flight_reqs = 0
        self._subscribed_pending = 0
        # Single-flight registry: fingerprint digest → the primary request
        # currently queued or in flight for that content. Entries live
        # until the primary's result is delivered (a requeued-on-error
        # primary stays registered, so its subscribers retry with it).
        self._single_flight: Dict[str, ClusterRequest] = {}
        self.stats = ClusterStats(
            policy=self.policy.name,
            result_cache=self.result_cache.stats
            if self.result_cache is not None else None)

    # -- ClusterEngine protocol ------------------------------------------

    def admit(self, req: ClusterRequest,
              now: Optional[float] = None) -> List[ClusterRequest]:
        """Admit a request; returns whatever retired as a consequence.

        Shape/width validation happens here (``plan_graph`` raises for
        graphs exceeding the largest supported bucket) and so does
        backpressure — the policy's ``on_admit`` gate refuses while its
        admission window is full (:class:`AdmissionRejected`, counted in
        ``stats.rejected``). A request the engine cannot take fails at
        admission, not inside a later batched flush.

        The leading harvest here raises immediately (unlike ``poll``'s,
        which defers): it runs *before* the request is queued, so the
        caller can safely retry the same ``admit`` — deferring would
        admit the request and then raise, inviting a double admission.

        With a result cache enabled, admission is content-addressed
        first: a fingerprint hit retires the request immediately —
        bit-identical to a cold flush, no queueing, no device work — and
        a fingerprint matching a *queued or in-flight* request subscribes
        to that flush's harvest (single-flight) instead of packing a
        duplicate row. Neither path consults the policy's ``on_admit``
        backpressure gate: they add no device work to the window the gate
        protects. Subscribers never appear in the bucket queues, so
        policies cannot double-count them in queue depth or ages.
        """
        self._harvest()
        now = self.clock() if now is None else now
        if req.plan is None:
            # Resolved once; a retry after AdmissionRejected (and the
            # flush itself) reuses the plan verbatim.
            req.plan = self._plan_for(req.graph, lam=req.lam,
                                      method=req.method)
            req.lam = req.plan.lam
        plan = req.plan
        if self.result_cache is not None:
            if req.fingerprint is None:
                req.fingerprint = graph_fingerprint(
                    plan, req.key, method=plan.method,
                    num_samples=self.num_samples, eps=self.eps,
                    objective=self.objective)
            cached = self.result_cache.get(req.fingerprint)
            if cached is not None:
                req.admitted_at = now
                self.stats.submitted += 1
                self.stats.cache_hits += 1
                self._deliver(req, *cached)
                self._run_policy(now)
                return self.retire()
            primary = self._single_flight.get(req.fingerprint.digest)
            if primary is not None:
                req.admitted_at = now
                primary.subscribers.append(req)
                self._subscribed_pending += 1
                self.stats.submitted += 1
                self.stats.subscribed += 1
                self._run_policy(now)
                return self.retire()
        if not self.policy.on_admit(self.buckets, now, self._telemetry()):
            self.stats.rejected += 1
            raise AdmissionRejected(
                f"policy {self.policy.name!r} refused admission with "
                f"{self.executor.in_flight} flushes in flight; retry after "
                "retiring")
        req.admitted_at = now
        if self.prebuild_rows and plan.rows is None:
            # The request's per-graph packing work, done once here — the
            # ELL scatter from the plan's canonical edges plus the async
            # rank dispatch — so its flushes only copy rows. Placed after
            # the cache/single-flight/backpressure gates: only requests
            # that will actually pack pay the build.
            t_build = time.perf_counter()
            plan.rows = build_packed_rows(
                plan, sample_keys(req.key, self.num_samples))
            self.stats.latency.record_build(
                plan.queue_key, time.perf_counter() - t_build)
        self.buckets.setdefault(plan.queue_key, []).append(req)
        if req.fingerprint is not None:
            self._single_flight[req.fingerprint.digest] = req
            # Counted here (not at the probe) so a rejected-then-retried
            # admission registers one miss, not one per retry.
            self.stats.cache_misses += 1
        self.stats.submitted += 1
        self._bucket_keys_seen.add(plan.queue_key)
        self.stats.buckets_seen = len(self._bucket_keys_seen)
        self._run_policy(now)
        return self.retire()

    def flush(self) -> List[ClusterRequest]:
        """Drain every bucket (end of stream), full or partial, and block
        for all in-flight work. End-of-stream draining is mechanics, not
        policy — every queue flushes at its native shape.

        Errors are deferred until every bucket has been drained (same
        discipline as the policy tick): one bad flush — a failed harvest
        of an earlier dispatch *or* a pack/submit failure of one bucket —
        must not strand the remaining queues undispatched or leave work
        computing unharvested. The first error is re-raised after the
        blocking harvest; the failed flush's requests are requeued, so a
        retrying caller loses nothing.
        """
        first_err: Optional[BaseException] = None
        for bucket in list(self.buckets):
            try:
                err = self._execute(
                    FlushDecision(bucket=bucket,
                                  count=len(self.buckets[bucket])))
            except Exception as dispatch_err:
                # Pack/submit failed; _execute already requeued the popped
                # requests (this bucket will be retried by a later flush).
                err = dispatch_err
            first_err = first_err or err
        # Always block for the in-flight work, even on an earlier error —
        # flush()'s contract is that nothing is left computing.
        harvest_err = self._harvest(block=True, defer=True)
        first_err = first_err or harvest_err
        if first_err is not None:
            raise first_err
        return self.retire()

    def retire(self) -> List[ClusterRequest]:
        """Drain finished requests not yet handed back to the caller
        (harvesting any flushes that completed since the last call)."""
        self._harvest()
        out = list(self._retired)
        self._retired.clear()
        return out

    def pending(self) -> int:
        """Admitted-but-unfinished requests: bucketed + in flight +
        single-flight subscribers riding a queued/in-flight primary."""
        return sum(len(v) for v in self.buckets.values()) \
            + self._in_flight_reqs + self._subscribed_pending

    def close(self) -> None:
        """Release engine resources held in process-global state — today
        that is the cost policy's program-cache pins (``ShapeHeat`` also
        backstops this from ``__del__``, but a long-lived process swapping
        engines should release deterministically). Idempotent **at the
        pin-refcount level**: closing twice, or ``__del__`` after an
        explicit ``close()``, never decrements a pin refcount a second
        time — so it can never strip a shape another live engine still
        pins (asserted in ``tests/test_executor.py``). The engine remains
        usable for draining afterwards; draining may re-pin, which the
        ``__del__`` backstop releases again."""
        release = getattr(self.policy, "release", None)
        if release is not None:
            release()

    def __del__(self):
        try:
            self.close()
        except Exception:       # interpreter teardown: modules may be gone
            pass

    # -- Policy driving ----------------------------------------------------

    def poll(self, now: Optional[float] = None) -> List[ClusterRequest]:
        """Give the policy a time tick: harvest completed flushes, let the
        policy flush whatever its schedule says is due (overdue deadline
        buckets, coalesced steals, ...), and return the retired requests.

        The tick's leading harvest defers its errors like the mid-tick
        ones: a failed earlier flush surfacing here must not stop the due
        decisions from dispatching (its requests are requeued first, so
        the policy already sees them back in their buckets).
        """
        now = self.clock() if now is None else now
        first_err = self._harvest(defer=True)
        self._run_policy(now, pending_err=first_err)
        return self.retire()

    def oldest_wait(self, now: Optional[float] = None) -> float:
        """Age of the oldest pending request (0.0 when idle), on the
        engine clock."""
        now = self.clock() if now is None else now
        ages = [now - reqs[0].admitted_at
                for reqs in self.buckets.values() if reqs]
        return max(ages, default=0.0)

    def warmup(self, graphs, autotune: bool = False,
               candidates=None, repeats: int = 3) -> int:
        """Precompile every pow2 sub-batch program the workload can hit.

        Deadline flushes run partial buckets at power-of-two sub-batch
        sizes, so a cold engine pays a jit compile the first time each
        ``(G_pad, R, W)`` shape appears — a latency spike exactly where the
        deadline policy promises a bound. JetStream warms its prefill
        buckets ahead of serving for the same reason. Given sample graphs
        covering the expected shape buckets, this compiles every sub-batch
        program *for this engine's executor* (the sharded executor floors
        sub-batches at its device count, so it usually has fewer) via
        zero-filled dummy tensors; nothing is returned to callers.
        Returns the number of programs compiled.

        ``autotune=True`` first sweeps the kernel ``block_rows``
        candidate set (:mod:`repro.kernels.autotune`) per bucket tier over
        *real packed bucket tensors* built from the sample graphs, records
        each winner in the process tuning cache, and only then runs the
        compile loop — so the compiled programs bake the tuned block
        shapes in (the program key carries them). Tiers whose winners are
        already cached are skipped entirely: a second process warming up
        against a populated ``REPRO_TUNING_CACHE`` performs zero sweep
        timings (the cache hit counters prove it). Sweep telemetry lands
        in ``stats.tuning``.
        """
        from repro.core.executor import program_cache_size, \
            run_bucket_program

        before = program_cache_size()
        k = self.num_samples
        by_bucket: Dict[Tuple[int, int], List[GraphPlan]] = {}
        for g in graphs:
            # Same resolution helper as admission — warmup can never plan
            # a graph differently from the admission that will follow it.
            plan = self._plan_for(g)
            by_bucket.setdefault(plan.bucket, []).append(plan)
        for bucket, plans in by_bucket.items():
            R, W = bucket
            pads, g_pad = set(), 1
            while g_pad <= next_pow2(self.max_batch):
                pads.add(self.executor.group_pad(g_pad))
                g_pad *= 2
            if autotune:
                self._autotune_bucket(plans, sorted(pads),
                                      candidates, repeats)
            for gp in sorted(pads):
                b = gp * k
                ell = jnp.full((b, R, W), R, dtype=jnp.int32)
                ranks = jnp.full((b, R + 1), np.iinfo(np.int32).max,
                                 dtype=jnp.int32)
                elig = jnp.zeros((b, R + 1), dtype=bool)
                m = jnp.zeros((b,), dtype=jnp.int32)
                jax.block_until_ready(run_bucket_program(
                    ell, ranks, elig, m, k=k, use_kernel=self.use_kernel,
                    donate=self.pool.donate, mesh=self.executor.mesh,
                    method=self.method, objective=self.objective))
        if autotune:
            from repro.kernels.autotune import tuning_info

            self.stats.tuning = tuning_info()
        return program_cache_size() - before

    def _autotune_bucket(self, plans, pads, candidates, repeats) -> None:
        """Sweep kernel block shapes for one bucket, per distinct batch
        tier, over real packed tensors — skipping already-tuned tiers.

        The sweep times the kernels directly (engine ``use_kernel`` does
        not matter: winners are recorded for whichever engine does run the
        kernel path). Tier check goes through ``TuningCache.get`` with
        counting on, so warmup hits/misses are observable engine-side.

        Sweep tensors pack into leased pool staging — the same
        ``pack_bucket`` + :class:`~repro.core.plan.BucketBufferPool` path
        flushes use, not ad-hoc buffers — so the pool's lease invariant
        covers the sweep too. The lease is released right after the sweep
        returns: ``sweep_bucket`` copies host→device and blocks on every
        timing, so nothing in flight reads the staging afterwards.
        """
        from repro.core.plan import pack_bucket
        from repro.kernels import autotune as _at

        cache = _at.tuning_cache()
        R, W = plans[0].bucket
        k = self.num_samples
        done_tiers = set()
        for gp in pads:
            tier = _at.batch_tier(gp * k)
            if tier in done_tiers:
                continue
            done_tiers.add(tier)
            if all(cache.get(kern, R, W, tier) is not None
                   for kern in _at.KERNELS):
                continue        # tuned by an earlier process: zero sweeps
            # Fill the padded group axis with real plans (cycling the
            # samples) so the measured tensors match what flushes run.
            use = list(plans)
            while len(use) < gp:
                use.extend(plans)
            use = use[:gp]
            keys = [sample_keys(jax.random.PRNGKey(i), k)
                    for i in range(len(use))]
            lease = self.pool.acquire(gp * k, R, W)
            try:
                ell, ranks, elig, _m, _pad = pack_bucket(
                    use, keys, k=k, g_pad=gp, staging=lease.arrays)
                _at.sweep_bucket(ell, ranks, elig, cache=cache,
                                 candidates=candidates, repeats=repeats)
            finally:
                lease.release()

    # -- Internals ---------------------------------------------------------

    def _plan_for(self, graph: Graph, lam: Optional[int] = None,
                  method: Optional[str] = None) -> GraphPlan:
        """The engine's single ``plan_graph`` call site — admission and
        warmup both resolve method/eps/lam through here, so the two can
        never diverge. ``method=None`` means the engine default."""
        return plan_graph(graph, method=method if method is not None
                          else self.method, eps=self.eps, lam=lam)

    def _telemetry(self) -> FlushTelemetry:
        """The policies' stats surface, with ``in_flight`` refreshed — the
        single place that syncs it, so no policy call sees a stale count."""
        telemetry = self.stats.latency
        telemetry.in_flight = self.executor.in_flight
        return telemetry

    def _run_policy(self, now: float,
                    pending_err: Optional[BaseException] = None) -> None:
        """Ask the policy what to flush and execute each decision.

        Every decision executes before any harvest error surfaces: a
        failed *earlier* flush harvested opportunistically mid-tick must
        not silently drop the remaining decisions (a due deadline flush
        would be skipped past its budget — the regression in
        ``tests/test_scheduler.py::test_harvest_error_does_not_drop_
        remaining_decisions``). Dispatch (pack/submit) failures of one
        decision are contained the same way — the popped requests are
        already requeued, the rest of the schedule still runs.
        ``pending_err`` lets a caller's leading harvest join the same
        discipline (``poll``); the first error is re-raised once the
        tick's schedule has been fully dispatched.
        """
        first_err = pending_err
        for decision in self.policy.select_flushes(self.buckets, now,
                                                   self._telemetry()):
            try:
                err = self._execute(decision)
            except Exception as dispatch_err:
                err = dispatch_err
            first_err = first_err or err
        if first_err is not None:
            raise first_err

    def _take(self, bucket: Tuple[str, int, int],
              count: int) -> List[ClusterRequest]:
        """Pop up to ``count`` oldest requests from one bucket queue."""
        q = self.buckets.get(bucket)
        if not q or count <= 0:
            return []
        taken, rest = q[:count], q[count:]
        if rest:
            self.buckets[bucket] = rest
        else:
            self.buckets.pop(bucket, None)
        return taken

    def _requeue(self, reqs: Sequence[ClusterRequest]) -> None:
        """Put popped requests back at the *front* of their own bucket
        queues (each request's native plan bucket), preserving age order —
        stolen requests return to the queue they were stolen from."""
        by_bucket: Dict[Tuple[str, int, int], List[ClusterRequest]] = {}
        for r in reqs:
            by_bucket.setdefault(r.plan.queue_key, []).append(r)
        for bucket, rs in by_bucket.items():
            self.buckets[bucket] = rs + self.buckets.get(bucket, [])

    def _execute(self,
                 decision: FlushDecision) -> Optional[BaseException]:
        """Carry out one policy decision: pop the requests it names
        (including steals from smaller buckets), promote plans to the
        decision's ``(R, W)`` shape, pack, and hand to the executor.

        Packing/dispatch errors raise (nothing was dispatched, the popped
        requests are requeued); errors from the opportunistic trailing
        harvest — they belong to a *previous* flush — are returned instead
        of raised, so the caller can finish its tick before surfacing them.
        """
        reqs = self._take(decision.bucket, decision.count)
        stolen: List[ClusterRequest] = []
        for src, cnt in decision.steal:
            stolen.extend(self._take(src, cnt))
        all_reqs = reqs + stolen
        if not all_reqs:
            return None
        k = self.num_samples
        method, R, W = decision.bucket
        bad = next((r for r in all_reqs if r.plan.method != method), None)
        if bad is not None:
            # The built-in policies never propose this (their steal filters
            # require queue_key method equality); a custom policy that does
            # is refused here with the requests safely requeued — a bucket
            # program runs exactly one registered method per flush.
            self._requeue(all_reqs)
            raise ValueError(
                f"flush decision for method {method!r} names a "
                f"{bad.plan.method!r} request: a bucket program runs "
                "exactly one registered method — cross-method "
                "coalescing/stealing is refused")
        # Promotion is a no-op for native requests; for stolen ones it
        # re-targets the plan at the flush's larger shape (bit-exact),
        # relaying any prebuilt rows via pad-copies. Prebuilt plans drew
        # their rank permutations at admission, so no sample keys are
        # derived for them here — that fold_in work is off the flush path.
        plans = [promote_plan(r.plan, R, W) for r in all_reqs]
        bkeys = [None if p.rows is not None else sample_keys(r.key, k)
                 for r, p in zip(all_reqs, plans)]
        try:
            _, pack = pack_and_submit(
                plans, bkeys, k, self.executor, pool=self.pool,
                use_kernel=self.use_kernel, payload=all_reqs,
                objective=self.objective)
        except BaseException:
            # Nothing was dispatched (the helper released the staging
            # lease): requeue the popped requests so none are lost, then
            # surface the error to the caller.
            self._requeue(all_reqs)
            raise
        self._in_flight_reqs += len(all_reqs)
        self.stats.flushes += 1
        if decision.deadline:
            self.stats.deadline_flushes += 1
        if stolen:
            self.stats.coalesced_flushes += 1
            self.stats.stolen_requests += len(stolen)
        # Pad accounting straight from the packer — no re-derivation here.
        self.stats.padded_slots += pack.padded_entries
        self.stats.pad_vertex_waste += pack.pad_vertex_waste
        self.stats.in_flight_peak = max(self.stats.in_flight_peak,
                                        self.executor.in_flight)
        return self._harvest(defer=True)

    def _deliver(self, req: ClusterRequest, labels_row: np.ndarray,
                 cost: int, picked: int, rounds: int) -> None:
        """Attach one result (device row or cache entry) and retire it."""
        req.result = result_for_plan(req.plan, labels_row, cost, picked,
                                     rounds, self.num_samples,
                                     req.plan.method)
        req.done = True
        self.stats.retired += 1
        self._retired.append(req)

    def _harvest(self, block: bool = False,
                 defer: bool = False) -> Optional[BaseException]:
        """Collect completed flushes from the executor into the retired
        queue (``block=True`` waits for everything in flight).

        A flush whose fetch fails (device-side runtime error surfacing at
        ``result()``) has its requests requeued into their native buckets
        — ahead of newer arrivals, preserving deadline age order — and the
        first such error is re-raised after every other handle has been
        processed, so one bad flush can neither lose requests nor strand
        the handles behind it. Single-flight subscribers stay attached to
        their requeued primary, so a failed flush *retries* them rather
        than dropping them. With ``defer=True`` the first error is
        *returned* instead of raised — mid-tick callers (``_execute``,
        ``flush``) finish dispatching their remaining decisions before
        surfacing it. Successful harvests fan each primary's device row
        out to its subscribers, insert the post-selection winner into the
        result cache, record the flush's wall/assemble latency into
        ``stats.latency``, and notify the policy.
        """
        handles = self.executor.drain() if block else self.executor.retire()
        first_err: Optional[BaseException] = None
        for handle in handles:
            reqs = handle.payload
            try:
                labels, costs, picked, rounds = handle.result()
            except BaseException as err:
                self._in_flight_reqs -= len(reqs)
                if reqs:
                    self._requeue(reqs)
                if first_err is None:
                    first_err = err
                continue
            for slot, req in enumerate(reqs):
                row = labels[slot]
                cost, pick = int(costs[slot]), int(picked[slot])
                depth = int(rounds[slot])
                self._deliver(req, row, cost, pick, depth)
                self.stats.clustered += 1
                if req.subscribers:
                    subs, req.subscribers = req.subscribers, []
                    for sub in subs:
                        # Same device row, the subscriber's own plan —
                        # identical content by fingerprint equality, so
                        # the result is bit-identical to a cold flush.
                        self._deliver(sub, row, cost, pick, depth)
                        self.stats.clustered += 1
                        self._subscribed_pending -= 1
                if req.fingerprint is not None:
                    self._single_flight.pop(req.fingerprint.digest, None)
                    if self.result_cache is not None:
                        self.result_cache.put(
                            req.fingerprint, row[: req.plan.n],
                            cost, pick, depth)
            self._in_flight_reqs -= len(reqs)
            if handle.shape is not None and handle.wall_seconds is not None:
                bucket = (handle.method, handle.shape[1], handle.shape[2])
                self.stats.latency.record(bucket, handle.wall_seconds,
                                          handle.assemble_seconds,
                                          depth=handle.inflight_at_submit,
                                          compile_s=handle.compile_seconds)
                if handle.compile_seconds is not None:
                    # Program-cache miss: feed the observed compile wall
                    # into the learned compile-cost stream.
                    self.stats.latency.record_compile(
                        bucket, handle.compile_seconds)
                self.policy.on_retire(bucket, self.stats.latency)
        if defer:
            return first_err
        if first_err is not None:
            raise first_err
        return None

    # -- Back-compat aliases (pre-engine API) ------------------------------

    def submit(self, req: ClusterRequest) -> List[ClusterRequest]:
        """Deprecated alias for :meth:`admit`."""
        return self.admit(req)

    def flush_all(self) -> List[ClusterRequest]:
        """Deprecated alias for :meth:`flush`."""
        return self.flush()


__all__ = ["ClusterRequest", "ClusterStats", "ClusterBatcher",
           "AdmissionRejected"]
