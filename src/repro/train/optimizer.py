"""Sharded AdamW with precision policies + LR schedules.

Optimizer state mirrors the parameter tree (same PartitionSpecs — ZeRO:
states live wherever their parameter shard lives). ``state_dtype`` is the
scale lever: fp32 moments for ≤15B models; bf16 moments for grok-1-314B and
llama-3.2-vision-90B, without which Adam state alone (12 bytes/param fp32)
exceeds a v5e's 16 GB at 314B/256 chips (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"      # float32 | bfloat16


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def opt_init(params, oc: OptConfig) -> OptState:
    dt = jnp.dtype(oc.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def opt_state_specs(param_specs):
    """State spec tree mirroring the param specs (for in_shardings)."""
    from jax.sharding import PartitionSpec as P
    return OptState(mu=param_specs, nu=param_specs, step=P())


def lr_at(oc: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, oc.warmup_steps))
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(1, oc.total_steps - oc.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = oc.min_lr_ratio + (1.0 - oc.min_lr_ratio) * cos
    return oc.lr * warm * scale


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (skip norms/biases/scalars)."""
    name = str(path[-1]) if path else ""
    return not any(k in name for k in ("ln", "norm", "bias", "u", "w0",
                                       "mix", "gate", "A_log", "D",
                                       "dt_bias"))


def opt_update(grads, state: OptState, params, oc: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics).

    Moment math runs in the state dtype: fp32 for the standard policy, bf16
    for the ≥90B policy — "fully bf16 Adam". The bf16 path avoids four
    param-sized fp32 transients per leaf, which alone overflows a v5e on
    grok-1-314B (the scalar (1−β) products are still exact in f32 and only
    the leaf-wide tensors round).
    """
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    step = state.step + 1
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(oc.state_dtype)
    cdt = sdt if sdt == jnp.bfloat16 else jnp.float32

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        gc = g.astype(cdt)
        mu_n = mu.astype(cdt) * jnp.asarray(b1, cdt) + gc * jnp.asarray(
            1 - b1, cdt)
        nu_n = nu.astype(cdt) * jnp.asarray(b2, cdt) + jnp.square(gc) * (
            jnp.asarray(1 - b2, cdt))
        upd = (mu_n / c1.astype(cdt)) / (
            jnp.sqrt(nu_n / c2.astype(cdt)) + jnp.asarray(oc.eps, cdt))
        if oc.weight_decay and _decay_mask(path):
            upd = upd + jnp.asarray(oc.weight_decay, cdt) * p.astype(cdt)
        new_p.append((p.astype(cdt) - lr.astype(cdt) * upd).astype(p.dtype))
        new_mu.append(mu_n.astype(sdt))
        new_nu.append(nu_n.astype(sdt))

    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    mu2 = jax.tree_util.tree_unflatten(treedef, new_mu)
    nu2 = jax.tree_util.tree_unflatten(treedef, new_nu)
    return params2, OptState(mu=mu2, nu=nu2, step=step), {
        "grad_norm": gnorm, "lr": lr}


__all__ = ["OptConfig", "OptState", "opt_init", "opt_state_specs",
           "opt_update", "lr_at", "clip_by_global_norm", "global_norm"]
