"""Roofline tooling: HLO collective walker (trip counts, async starts,
participants) + analytic FLOPs sanity + batched ELL kernel models (the
autotuner's hardware lower bound)."""

import textwrap

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (
    ELL_KERNELS,
    Roofline,
    active_param_count,
    collective_stats,
    ell_kernel_bytes,
    ell_kernel_flops,
    ell_kernel_roofline,
    forward_flops,
    model_flops,
    step_flops,
)

HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
      %p = (s32[], f32[16,16]) parameter(0)
      %ar = f32[16,16]{1,0} all-reduce(%gte), channel_id=1, replica_groups=[4,8]<=[32], to_apply=%add
      ROOT %t = (s32[], f32[16,16]) tuple(%iv, %ar)
    }

    %cond (p2: (s32[], f32[16,16])) -> pred[] {
      %p2 = (s32[], f32[16,16]) parameter(0)
      ROOT %lt = pred[] compare(%iv2, %c), direction=LT
    }

    ENTRY %main (a: f32[16,16]) -> f32[16,16] {
      %a = f32[16,16]{1,0} parameter(0)
      %ag = f32[64,16]{1,0} all-gather(%a), channel_id=2, replica_groups=[8,4]<=[32], dimensions={0}
      %w = (s32[], f32[16,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      %cps = (f32[16,16], f32[16,16]) collective-permute-start(%a), channel_id=3, source_target_pairs={{0,1},{1,0}}
      %cpd = f32[16,16]{1,0} collective-permute-done(%cps)
      ROOT %out = f32[16,16]{1,0} add(%cpd, %a)
    }
""")


def test_collective_walker_trip_counts_and_async():
    cs = collective_stats(HLO, default_participants=32)
    # all-gather: 64*16*4 bytes × 4 participants = 16384
    assert cs.bytes_by_kind["all-gather"] == 64 * 16 * 4 * 4
    # all-reduce inside while ×10 trips, 8 participants
    assert cs.bytes_by_kind["all-reduce"] == 16 * 16 * 4 * 8 * 10
    assert cs.count_by_kind["all-reduce"] == 10
    # collective-permute-start counted once (max tuple element), done
    # skipped; participants = number of source_target_pairs (2 here)
    assert cs.bytes_by_kind["collective-permute"] == 16 * 16 * 4 * 2
    assert cs.count_by_kind["collective-permute"] == 1


def test_analytic_flops_scale_with_tokens():
    cfg = get_config("qwen3-8b")
    f1 = forward_flops(cfg, 1, 1024)
    f2 = forward_flops(cfg, 2, 1024)
    assert 1.9 < f2 / f1 < 2.1
    # ~2·N·D at short seq (attention negligible)
    n = cfg.param_count()
    assert 0.8 < f1 / (2 * n * 1024) < 1.3


def test_moe_active_params():
    cfg = get_config("olmoe-1b-7b")
    total = cfg.param_count()
    active = active_param_count(cfg)
    assert active < 0.35 * total  # 8/64 experts active (+dense parts)


def test_train_flops_is_3x_forward():
    cfg = get_config("granite-3-2b")
    shape = SHAPES["train_4k"]
    assert abs(step_flops(cfg, shape)
               / (3 * forward_flops(cfg, shape.global_batch,
                                    shape.seq_len)) - 1) < 1e-6


def test_decode_flops_excludes_encoder():
    cfg = get_config("whisper-base")
    dec = SHAPES["decode_32k"]
    pre = SHAPES["prefill_32k"]
    f_dec = step_flops(cfg, dec)
    f_pre = step_flops(cfg, pre)
    assert f_dec < 0.05 * f_pre  # one token vs 32k prompt + encoder


def test_roofline_terms_and_bottleneck():
    r = Roofline(chips=256, flops=1e18, bytes_hbm=1e12, coll_bytes=1e12,
                 hlo_flops_raw=1e16, hlo_bytes_raw=1e12, model_flops_=8e17)
    assert r.t_compute > r.t_memory
    assert r.bottleneck == "compute"
    assert 0.79 < r.useful_ratio < 0.81
    assert abs(r.roofline_fraction - 0.8) < 1e-6


# --- batched ELL kernel models ---------------------------------------------


def test_ell_kernel_models_scale_and_validate():
    for kern in ELL_KERNELS:
        # Linear in every axis of the swept (B, R, W) volume.
        assert ell_kernel_flops(kern, 8, 64, 8) \
            == 2 * ell_kernel_flops(kern, 4, 64, 8)
        assert ell_kernel_bytes(kern, 4, 128, 8) \
            > ell_kernel_bytes(kern, 4, 64, 8)
        assert ell_kernel_bytes(kern, 4, 64, 16) \
            > ell_kernel_bytes(kern, 4, 64, 8)
    # neighbor_min gathers two tables, label_agree one.
    assert ell_kernel_bytes("neighbor_min", 4, 64, 8) \
        > ell_kernel_bytes("label_agree", 4, 64, 8)
    with pytest.raises(ValueError):
        ell_kernel_flops("fused_softmax", 4, 64, 8)
    with pytest.raises(ValueError):
        ell_kernel_bytes("fused_softmax", 4, 64, 8)


def test_ell_kernel_roofline_bottleneck_and_dict():
    # ~3.5 element-ops/byte max: on any real FLOPS/BW ratio these kernels
    # are memory bound; force the opposite with a tiny peak to check both
    # branches.
    r = ell_kernel_roofline("neighbor_min", 8, 128, 16)
    assert r.t_model == max(r.t_compute, r.t_memory)
    assert r.bottleneck == "memory"
    slow = ell_kernel_roofline("neighbor_min", 8, 128, 16,
                               peak_flops=1e6, mem_bw=1e15)
    assert slow.bottleneck == "compute"
    d = r.as_dict()
    assert d["shape"] == [8, 128, 16]
    assert d["t_model_s"] == r.t_model
    assert d["bottleneck"] == "memory"


@pytest.mark.slow
def test_measured_kernel_walls_respect_roofline():
    """The tentpole's closed loop: sweep real packed bucket tensors, then
    assert (a) every measured wall is >= the hardware model bound — the
    TPU-v5e roofline is a lower bound for any slower backend, so a wall
    beating it means the timing or the model is broken — and (b) a fresh
    best-of-repeats re-measurement of the tuned block is no slower than
    the 256-default beyond timing noise."""
    import time

    import jax
    import numpy as np

    from repro.core import build_graph
    from repro.core.api import sample_keys
    from repro.core.graph import random_arboric
    from repro.core.plan import pack_bucket, plan_graph
    from repro.kernels import autotune as at
    from repro.kernels.ops import label_agree_ell_batch, neighbor_min_ell_batch

    prev = at.set_tuning_cache(at.TuningCache(path=None))
    try:
        rng = np.random.default_rng(5)
        graphs = []
        for _ in range(4):
            edges, _ = random_arboric(48, 2, rng)
            graphs.append(build_graph(48, edges))
        plans = [plan_graph(g) for g in graphs]
        keys = [sample_keys(jax.random.PRNGKey(i), 1)
                for i in range(len(plans))]
        ell, ranks, elig, _m, _pad = pack_bucket(plans, keys, k=1, g_pad=4)
        b, r, w = (int(s) for s in ell.shape)

        records = at.sweep_bucket(ell, ranks, elig, candidates=(16, 32),
                                  repeats=2)
        assert len(records) == len(ELL_KERNELS)
        for rec in records:
            bound = ell_kernel_roofline(rec["kernel"], b, r, w).t_model
            for ms in rec["timings_ms"].values():
                assert ms * 1e-3 >= bound, (
                    f"{rec['kernel']} measured {ms:.4f}ms beats the "
                    f"roofline bound {bound * 1e3:.4f}ms")

        # Re-measure default vs tuned fresh (sweep winners are argmin by
        # construction; a fresh timing is the meaningful comparison).
        labels_p = jax.numpy.broadcast_to(
            jax.numpy.arange(r + 1, dtype=jax.numpy.int32), (b, r + 1))
        calls = {
            "neighbor_min": lambda br: neighbor_min_ell_batch(
                ell, ranks, elig, block_rows=br),
            "label_agree": lambda br: label_agree_ell_batch(
                ell, labels_p, block_rows=br),
        }
        cache = at.tuning_cache()
        tier = at.batch_tier(b)
        for kern, call in calls.items():
            tuned = cache.get(kern, r, w, tier, count=False)
            assert tuned is not None

            def best_of(br, n=2):
                call(br).block_until_ready()      # compile untimed
                walls = []
                for _ in range(n):
                    t0 = time.perf_counter()
                    call(br).block_until_ready()
                    walls.append(time.perf_counter() - t0)
                return min(walls)

            t_tuned = best_of(tuned)
            t_default = best_of(min(at.DEFAULT_BLOCK_ROWS, r))
            assert t_tuned <= t_default * 1.3 + 1e-3, (
                f"{kern}: tuned block {tuned} ({t_tuned * 1e3:.3f}ms) "
                f"slower than default ({t_default * 1e3:.3f}ms)")
    finally:
        at.set_tuning_cache(prev)
