"""Forest (λ = 1) specialization: matchings ⇒ correlation clustering.

Corollary 27: a *maximum* matching on E⁺ yields an optimum clustering.
Lemma 29: an α-approximate matching yields an α-approximate clustering.

Implementations:
* :func:`max_matching_forest` — exact maximum matching by leaf-peeling
  (host oracle; greedy leaf-matching is optimal on forests).
* :func:`maximal_matching_parallel` — round-parallel random-priority maximal
  matching (local-minimum edges), O(log n) rounds w.h.p.; 2-approx ⇒
  2-approx clustering (always ≥ the Lemma 29 bound).
* :func:`augmenting_matching_parallel` — improves a matching by flipping
  vertex-disjoint length-3 augmenting paths in parallel passes
  (Hopcroft–Karp style, the mechanism behind the paper's (1+ε) citations);
  each pass is O(1) MPC rounds on a bounded-degree forest.
* :func:`clustering_from_matching` — matched pairs = clusters of 2, rest
  singletons.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .mis import INF_RANK

UINT_BIG = jnp.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Exact maximum matching on forests (host oracle).
# ---------------------------------------------------------------------------


def max_matching_forest(g: Graph) -> np.ndarray:
    """partner[v] = matched neighbour or -1. Leaf-peeling is optimal on
    forests (standard exchange argument)."""
    n = g.n
    dst = np.asarray(g.dst)
    row = np.asarray(g.row_offsets)
    deg = np.asarray(g.deg).copy()
    alive = np.ones(n, dtype=bool)
    partner = np.full(n, -1, dtype=np.int32)

    from collections import deque

    leaves = deque(v for v in range(n) if deg[v] == 1)
    zero = deque(v for v in range(n) if deg[v] == 0)

    def neighbors(v):
        for e in range(row[v], row[v + 1]):
            u = int(dst[e])
            if u < n and alive[u]:
                yield u

    while leaves:
        v = leaves.popleft()
        if not alive[v] or deg[v] != 1:
            continue
        us = [u for u in neighbors(v)]
        if not us:
            alive[v] = False
            continue
        u = us[0]
        partner[v], partner[u] = u, v
        alive[v] = alive[u] = False
        for x in range(row[u], row[u + 1]):
            w = int(dst[x])
            if w < n and alive[w]:
                deg[w] -= 1
                if deg[w] == 1:
                    leaves.append(w)
        for x in range(row[v], row[v + 1]):
            w = int(dst[x])
            if w < n and alive[w]:
                deg[w] -= 1
                if deg[w] == 1:
                    leaves.append(w)
    return partner


def matching_size(partner: np.ndarray) -> int:
    return int((np.asarray(partner) >= 0).sum()) // 2


# ---------------------------------------------------------------------------
# Parallel maximal matching (local-minimum edges).
# ---------------------------------------------------------------------------


def _edge_priorities(g: Graph, key: jax.Array) -> jnp.ndarray:
    """Symmetric random priority per *directed* COO slot: a random
    permutation of undirected edge ids (exactly unique — tie-free), shared by
    both directions via ``g.eid``. Padding slots get UINT_BIG."""
    perm = jax.random.permutation(key, g.m).astype(jnp.uint32) if g.m else (
        jnp.zeros((0,), jnp.uint32))
    perm_pad = jnp.concatenate([perm, jnp.array([UINT_BIG], jnp.uint32)])
    return perm_pad[jnp.minimum(g.eid, g.m)]


@jax.jit
def maximal_matching_parallel(g: Graph, key: jax.Array
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Random-priority maximal matching. Returns (partner, rounds)."""
    n = g.n
    pri = _edge_priorities(g, key)  # (E,) uint32, symmetric, unique
    src_ok = g.src < n

    def body(state):
        partner, rounds = state
        free = partner < 0
        src_i = jnp.minimum(g.src, n - 1)
        dst_i = jnp.minimum(g.dst, n - 1)
        live = src_ok & free[src_i] & free[dst_i]
        vals = jnp.where(live, pri, UINT_BIG)
        vmin = jnp.full((n + 1,), UINT_BIG, jnp.uint32).at[
            jnp.minimum(g.src, n)
        ].min(vals)
        is_min = live & (vals == vmin[src_i]) & (vals == vmin[dst_i]) & (
            vals < UINT_BIG
        )
        # local-minimum edges are vertex-disjoint except priority ties on a
        # shared vertex — ties broken inside the key; a vertex adopts the
        # unique min edge.
        new_partner = jnp.full((n + 1,), -1, jnp.int32).at[
            jnp.where(is_min, g.src, n)
        ].max(jnp.where(is_min, g.dst, -1))
        partner = jnp.where((partner < 0) & (new_partner[:-1] >= 0),
                            new_partner[:-1], partner)
        return partner, rounds + 1

    def cond(state):
        partner, rounds = state
        free = partner < 0
        src_i = jnp.minimum(g.src, n - 1)
        dst_i = jnp.minimum(g.dst, n - 1)
        live = src_ok & free[src_i] & free[dst_i]
        return jnp.any(live) & (rounds < 10_000)

    partner0 = jnp.full((n,), -1, jnp.int32)
    partner, rounds = jax.lax.while_loop(cond, body, (partner0, jnp.int32(0)))
    return partner, rounds


# ---------------------------------------------------------------------------
# Length-3 augmenting-path improvement passes.
# ---------------------------------------------------------------------------


def augmenting_matching_parallel(g: Graph, key: jax.Array,
                                 passes: int = 4) -> Tuple[np.ndarray, int]:
    """Maximal matching + parallel length-3 augmentation passes.

    Each pass finds a set of vertex-disjoint augmenting paths
    ``u (free) — v = w (matched) — x (free)`` and flips them, strictly
    increasing |M|. On forests this converges quickly toward maximum
    (benchmarked ratio; Lemma 29 turns the matching ratio into the clustering
    ratio). Returns (partner, rounds_used).
    """
    n = g.n
    partner, rounds = maximal_matching_parallel(g, key)
    partner = np.array(partner)  # writable host copy
    total_rounds = int(rounds)
    dst = np.asarray(g.dst)
    row = np.asarray(g.row_offsets)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))

    for _ in range(passes):
        free = partner < 0
        # Each free vertex proposes to a matched neighbour (min id).
        prop = np.full(n, -1, dtype=np.int64)
        for v in np.flatnonzero(free):
            for e in range(row[v], row[v + 1]):
                u = int(dst[e])
                if u < n and partner[u] >= 0:
                    prop[v] = u
                    break
        # Matched edge (v, w) with free proposers on both sides → augment.
        # Conflict resolution: each matched vertex accepts min proposer.
        accept = np.full(n, -1, dtype=np.int64)
        order = rng.permutation(np.flatnonzero(prop >= 0))
        for u in order:
            t = prop[u]
            if accept[t] < 0:
                accept[t] = u
        flipped = 0
        done = np.zeros(n, dtype=bool)
        for v in range(n):
            w = partner[v]
            if w < 0 or w < v or done[v] or done[w]:
                continue
            a, b = accept[v], accept[w]
            if a >= 0 and b >= 0 and a != b and partner[a] < 0 and partner[b] < 0:
                partner[a], partner[v] = v, a
                partner[w], partner[b] = b, w
                done[[v, w]] = True
                accept[[v, w]] = -1
                flipped += 1
        total_rounds += 3  # propose, accept, flip: O(1) rounds per pass
        if flipped == 0:
            break
    return partner, total_rounds


def clustering_from_matching(partner: np.ndarray) -> np.ndarray:
    """Matched pair → cluster min(u, v); unmatched → singleton."""
    partner = np.asarray(partner)
    n = len(partner)
    own = np.arange(n, dtype=np.int32)
    return np.where(partner >= 0, np.minimum(own, partner), own).astype(np.int32)


def forest_cost_from_matching(g: Graph, partner: np.ndarray) -> int:
    """cost = m − |M| on a forest (all disagreements are positive edges cut)."""
    return g.m - matching_size(partner)


__all__ = [
    "max_matching_forest",
    "matching_size",
    "maximal_matching_parallel",
    "augmenting_matching_parallel",
    "clustering_from_matching",
    "forest_cost_from_matching",
]
