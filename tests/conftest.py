"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single-device CPU; only launch/dryrun.py forces 512 host devices (in its own
process)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
