"""Continuous batching for clustering-as-a-service, with deadline flushes.

Implements the :class:`repro.serve.engine.ClusterEngine` protocol for graph
queries: incoming graphs are **admitted** into the shape bucket their padded
``(R, W)`` size maps to, a bucket **flushes** through the injected
:class:`~repro.core.executor.BucketExecutor` the moment it fills
``max_batch`` slots — or, under the deadline policy, as soon as its oldest
request has waited ``max_wait`` seconds — and flushed requests **retire**
with their results attached.

Executor injection (how a flush reaches the device)
  ``ClusterBatcher(executor=...)`` takes ``'sync'`` (block per flush — the
  classic path), ``'async'`` (non-blocking dispatch: the batcher packs and
  flushes the next bucket while the previous one computes and transfers;
  completed flushes are harvested on the next ``admit``/``poll``/``retire``),
  ``'sharded'`` (one flush data-parallel across all local devices via
  ``shard_map``), or any :class:`BucketExecutor` instance. Results are
  bit-identical under every executor — scheduling can never change an
  answer. An executor instance must not be shared between engines: the
  batcher harvests *all* of its executor's handles.

Admission backpressure (bounded in-flight work)
  With ``max_in_flight`` set, ``admit`` raises :class:`AdmissionRejected`
  (and counts ``stats.rejected``) while that many flushes are still in
  flight — the signal a front-end needs to shed load instead of queueing
  unboundedly when arrivals outrun the device.

Deadline policy (bounded tail latency)
  With ``max_wait`` set, :meth:`ClusterBatcher.poll` flushes any bucket
  whose oldest request is past its budget as a *partial* flush, padded to
  the next power-of-two sub-batch so the jit cache stays
  O(#buckets · log max_batch). Padding actually performed on the device is
  reported by the packer itself (``PackStats`` fields), so
  :class:`ClusterStats` can never drift from what ran.

Buffer reuse
  All flushes route through one :class:`repro.core.plan.BucketBufferPool`:
  host staging arrays per bucket shape are **leased** per flush, refilled
  in place, and run through the donated device program. A lease is only
  released once its flush's outputs are fetched, so pipelined flushes of
  the same bucket shape get distinct buffer generations — a buffer feeding
  an in-flight program is never refilled.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BucketBufferPool, make_executor, plan_graph
from repro.core.api import ClusterResult, sample_keys
from repro.core.executor import pack_and_submit
from repro.core.graph import Graph
from repro.core.plan import GraphPlan, result_for_plan
from repro.util import next_pow2

from .engine import EngineStats


class AdmissionRejected(RuntimeError):
    """Raised by ``admit`` when ``max_in_flight`` flushes are outstanding."""


@dataclasses.dataclass
class ClusterRequest:
    uid: int
    graph: Graph
    key: jax.Array
    lam: Optional[int] = None
    result: Optional[ClusterResult] = None
    done: bool = False
    admitted_at: Optional[float] = None     # engine clock time of admission
    plan: Optional[GraphPlan] = None        # resolved once at admission


@dataclasses.dataclass
class ClusterStats(EngineStats):
    flushes: int = 0
    deadline_flushes: int = 0    # partial flushes forced by max_wait
    clustered: int = 0
    padded_slots: int = 0        # empty device entries, from the packer
    pad_vertex_waste: int = 0    # Σ (R − n) over clustered graphs
    buckets_seen: int = 0        # distinct (R, W) buckets admitted
    rejected: int = 0            # admissions refused by backpressure
    in_flight_peak: int = 0      # max concurrent in-flight flushes seen


class ClusterBatcher:
    """Bucketed clustering engine: full-bucket flushes + deadline flushes.

    Implements the :class:`~repro.serve.engine.ClusterEngine` protocol
    (``admit`` / ``flush`` / ``retire`` / ``stats`` / ``pending``), plus
    :meth:`poll` for the ``max_wait`` deadline policy.

    Args:
      max_batch: bucket capacity; a bucket flushes when it holds this many
        requests.
      max_wait: optional deadline in seconds (engine-clock): ``poll()``
        flushes any bucket whose oldest request has waited longer, padded
        to the next power-of-two sub-batch. ``None`` = full buckets only.
      clock: the engine clock (monotonic seconds). Injectable so tests and
        simulators can drive virtual time.
      num_samples: best-of-k PIVOT per request (``< 1`` is coerced to 1;
        the engine itself rejects invalid values).
      pool: buffer pool shared by all flushes (created if omitted).
      executor: bucket executor name (``'sync'``/``'async'``/``'sharded'``)
        or instance — see the module docstring. Default ``'sync'``.
      max_in_flight: optional bound on concurrently in-flight flushes;
        ``admit`` raises :class:`AdmissionRejected` at the bound. ``None``
        disables backpressure (one-shot / offline driving).
    """

    def __init__(self, max_batch: int = 64, method: str = "pivot",
                 eps: float = 2.0, num_samples: int = 1,
                 use_kernel: bool = False,
                 max_wait: Optional[float] = None,
                 clock=time.monotonic,
                 pool: Optional[BucketBufferPool] = None,
                 executor="sync",
                 max_in_flight: Optional[int] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait is not None and max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.max_batch = max_batch
        self.method = method
        self.eps = eps
        self.num_samples = max(1, num_samples)
        self.use_kernel = use_kernel
        self.max_wait = max_wait
        self.clock = clock
        self.pool = pool if pool is not None else BucketBufferPool()
        self.executor = make_executor(executor)
        self.max_in_flight = max_in_flight
        self.buckets: Dict[Tuple[int, int], List[ClusterRequest]] = {}
        self._bucket_keys_seen: set = set()
        self._retired: Deque[ClusterRequest] = deque()
        self._in_flight_reqs = 0
        self.stats = ClusterStats()

    # -- ClusterEngine protocol ------------------------------------------

    def admit(self, req: ClusterRequest,
              now: Optional[float] = None) -> List[ClusterRequest]:
        """Admit a request; returns the retired batch if its bucket flushed.

        Shape/width validation happens here (``plan_graph`` raises for
        graphs exceeding the largest supported bucket) and so does
        backpressure (:class:`AdmissionRejected` while ``max_in_flight``
        flushes are outstanding) — a request the engine cannot take fails
        at admission, not inside a later batched flush.
        """
        self._harvest()
        if (self.max_in_flight is not None
                and self.executor.in_flight >= self.max_in_flight):
            self.stats.rejected += 1
            raise AdmissionRejected(
                f"{self.executor.in_flight} flushes in flight >= "
                f"max_in_flight={self.max_in_flight}; retry after retiring")
        plan = plan_graph(req.graph, method=self.method, eps=self.eps,
                          lam=req.lam)
        req.plan = plan         # resolved once; the flush reuses it verbatim
        req.lam = plan.lam
        req.admitted_at = self.clock() if now is None else now
        slot_list = self.buckets.setdefault(plan.bucket, [])
        slot_list.append(req)
        self.stats.submitted += 1
        self._bucket_keys_seen.add(plan.bucket)
        self.stats.buckets_seen = len(self._bucket_keys_seen)
        if len(slot_list) >= self.max_batch:
            self._flush(plan.bucket)
        return self.retire()

    def flush(self) -> List[ClusterRequest]:
        """Drain every bucket (end of stream), full or partial, and block
        for all in-flight work."""
        for bucket in list(self.buckets):
            self._flush(bucket)
        self._harvest(block=True)
        return self.retire()

    def retire(self) -> List[ClusterRequest]:
        """Drain finished requests not yet handed back to the caller
        (harvesting any flushes that completed since the last call)."""
        self._harvest()
        out = list(self._retired)
        self._retired.clear()
        return out

    def pending(self) -> int:
        """Admitted-but-unfinished requests: bucketed + in flight."""
        return sum(len(v) for v in self.buckets.values()) \
            + self._in_flight_reqs

    # -- Deadline policy --------------------------------------------------

    def poll(self, now: Optional[float] = None) -> List[ClusterRequest]:
        """Flush buckets whose oldest request has waited past ``max_wait``.

        Without a deadline configured this still harvests completed
        in-flight flushes. Partial buckets are padded to the next
        power-of-two sub-batch by the packer, so deadline flushes stay
        within the O(#buckets · log B) compile budget.
        """
        if self.max_wait is None:
            return self.retire()
        now = self.clock() if now is None else now
        for bucket, reqs in list(self.buckets.items()):
            if reqs and now - reqs[0].admitted_at >= self.max_wait:
                self._flush(bucket, deadline=True)
        return self.retire()

    def oldest_wait(self, now: Optional[float] = None) -> float:
        """Age of the oldest pending request (0.0 when idle)."""
        now = self.clock() if now is None else now
        ages = [now - reqs[0].admitted_at
                for reqs in self.buckets.values() if reqs]
        return max(ages, default=0.0)

    def warmup(self, graphs) -> int:
        """Precompile every pow2 sub-batch program the workload can hit.

        Deadline flushes run partial buckets at power-of-two sub-batch
        sizes, so a cold engine pays a jit compile the first time each
        ``(G_pad, R, W)`` shape appears — a latency spike exactly where the
        deadline policy promises a bound. JetStream warms its prefill
        buckets ahead of serving for the same reason. Given sample graphs
        covering the expected shape buckets, this compiles every sub-batch
        program *for this engine's executor* (the sharded executor floors
        sub-batches at its device count, so it usually has fewer) via
        zero-filled dummy tensors; nothing is returned to callers.
        Returns the number of programs compiled.
        """
        from repro.core.executor import program_cache_size, \
            run_bucket_program

        before = program_cache_size()
        k = self.num_samples
        seen = set()
        for g in graphs:
            bucket = plan_graph(g, method=self.method, eps=self.eps).bucket
            if bucket in seen:
                continue
            seen.add(bucket)
            R, W = bucket
            pads, g_pad = set(), 1
            while g_pad <= next_pow2(self.max_batch):
                pads.add(self.executor.group_pad(g_pad))
                g_pad *= 2
            for gp in sorted(pads):
                b = gp * k
                ell = jnp.full((b, R, W), R, dtype=jnp.int32)
                ranks = jnp.full((b, R + 1), np.iinfo(np.int32).max,
                                 dtype=jnp.int32)
                elig = jnp.zeros((b, R + 1), dtype=bool)
                m = jnp.zeros((b,), dtype=jnp.int32)
                jax.block_until_ready(run_bucket_program(
                    ell, ranks, elig, m, k=k, use_kernel=self.use_kernel,
                    donate=self.pool.donate, mesh=self.executor.mesh))
        return program_cache_size() - before

    # -- Internals ---------------------------------------------------------

    def _flush(self, bucket: Tuple[int, int], deadline: bool = False) -> None:
        """Pack one bucket and hand it to the executor (maybe async)."""
        reqs = self.buckets.pop(bucket, [])
        if not reqs:
            return
        k = self.num_samples
        plans = [r.plan for r in reqs]
        bkeys = [sample_keys(r.key, k) for r in reqs]
        try:
            _, pack = pack_and_submit(
                plans, bkeys, k, self.executor, pool=self.pool,
                use_kernel=self.use_kernel, payload=reqs)
        except BaseException:
            # Nothing was dispatched (the helper released the staging
            # lease): requeue the popped requests so none are lost, then
            # surface the error to the caller.
            self.buckets[bucket] = reqs
            raise
        self._in_flight_reqs += len(reqs)
        self.stats.flushes += 1
        if deadline:
            self.stats.deadline_flushes += 1
        # Pad accounting straight from the packer — no re-derivation here.
        self.stats.padded_slots += pack.padded_entries
        self.stats.pad_vertex_waste += pack.pad_vertex_waste
        self.stats.in_flight_peak = max(self.stats.in_flight_peak,
                                        self.executor.in_flight)
        self._harvest()

    def _harvest(self, block: bool = False) -> None:
        """Collect completed flushes from the executor into the retired
        queue (``block=True`` waits for everything in flight).

        A flush whose fetch fails (device-side runtime error surfacing at
        ``result()``) has its requests requeued into their bucket — ahead
        of newer arrivals, preserving deadline age order — and the first
        such error is re-raised after every other handle has been
        processed, so one bad flush can neither lose requests nor strand
        the handles behind it.
        """
        handles = self.executor.drain() if block else self.executor.retire()
        first_err: Optional[BaseException] = None
        for handle in handles:
            reqs = handle.payload
            try:
                labels, costs, picked, rounds = handle.result()
            except BaseException as err:
                self._in_flight_reqs -= len(reqs)
                if reqs:
                    bucket = reqs[0].plan.bucket
                    self.buckets[bucket] = reqs + self.buckets.get(bucket, [])
                if first_err is None:
                    first_err = err
                continue
            for slot, req in enumerate(reqs):
                req.result = result_for_plan(
                    req.plan, labels[slot], int(costs[slot]),
                    int(picked[slot]), int(rounds[slot]),
                    self.num_samples, self.method)
                req.done = True
                self.stats.clustered += 1
                self.stats.retired += 1
                self._retired.append(req)
            self._in_flight_reqs -= len(reqs)
        if first_err is not None:
            raise first_err

    # -- Back-compat aliases (pre-engine API) ------------------------------

    def submit(self, req: ClusterRequest) -> List[ClusterRequest]:
        """Deprecated alias for :meth:`admit`."""
        return self.admit(req)

    def flush_all(self) -> List[ClusterRequest]:
        """Deprecated alias for :meth:`flush`."""
        return self.flush()


__all__ = ["ClusterRequest", "ClusterStats", "ClusterBatcher",
           "AdmissionRejected"]
