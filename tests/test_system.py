"""End-to-end behaviour tests: train driver with simulated failure/restart,
serving loop, dedup-in-the-loop training."""

import jax
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.train.fault import SimulatedFailure, StepWatchdog, suggest_cadence


@pytest.mark.slow
def test_train_failure_restart_bitwise(tmp_path):
    """Kill the run at step 10, restart from checkpoint: the completed loss
    trajectory must equal the uninterrupted run's exactly."""
    common = dict(arch="smollm-135m", smoke=True, seq_len=32,
                  global_batch=4, ckpt_every=5, dedup=False, seed=0,
                  log_every=100)
    ref = train_mod.run(steps=15, ckpt_dir=None, resume=False, fail_at=None,
                        **common)
    with pytest.raises(SimulatedFailure):
        train_mod.run(steps=15, ckpt_dir=str(tmp_path), resume=False,
                      fail_at=10, **common)
    out = train_mod.run(steps=15, ckpt_dir=str(tmp_path), resume=True,
                        fail_at=None, **common)
    # restart resumed at the last checkpoint (step 10) and matched exactly
    assert out["losses"] == ref["losses"][10:], (
        out["losses"], ref["losses"][10:])


@pytest.mark.slow
def test_train_with_dedup_stage(tmp_path):
    out = train_mod.run(arch="smollm-135m", smoke=True, steps=12,
                        ckpt_dir=None, resume=False, fail_at=None,
                        seq_len=32, global_batch=4, dedup=True, seed=1,
                        log_every=100)
    losses = out["losses"]
    assert len(losses) == 12
    # learning signal: the best late-window loss beats the first step
    assert min(losses[6:]) < losses[0], (
        "training on deduped stream must learn")


@pytest.mark.slow
def test_serve_continuous_batching():
    reqs, stats = serve_mod.run("smollm-135m", smoke=True, n_requests=5,
                                max_new=8, max_slots=3, cache_len=64)
    assert stats.prefills == 5
    assert stats.emitted_tokens >= 5
    for r in reqs:
        assert r.done


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(factor=3.0)
    for _ in range(10):
        wd.start()
        time.sleep(0.001)
        assert not wd.stop()
    wd.start()
    time.sleep(0.05)
    assert wd.stop(), "50x median step must be flagged"


def test_young_daly_cadence():
    # 1h MTBF, 30s checkpoint write, 1s steps → ~sqrt(2·3600·30)=465 steps
    c = suggest_cadence(3600, 30, 1.0)
    assert 300 < c < 700
