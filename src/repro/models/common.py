"""Shared model building blocks: params-with-specs, norms, RoPE, linears.

Parameters are plain nested dicts whose leaves are :class:`Pm` — an array
paired with its ``PartitionSpec``. ``split_params`` separates the two trees;
the spec tree is what ``launch.dryrun`` feeds to ``jax.jit``'s
``in_shardings``. Single-sourcing array+spec at init time keeps the sharding
annotations from drifting out of sync with the structure.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class Pm:
    """A parameter leaf: array + partition spec."""
    value: Any
    spec: P


def is_pm(x) -> bool:
    return isinstance(x, Pm)


def split_params(tree):
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_pm)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_pm)
    return params, specs


class KeyGen:
    """Stateful PRNG splitter for init code."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def dense_init(key, shape, dtype, in_axis_size=None, scale=1.0):
    """Truncated-normal fan-in init."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = scale / np.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def head_rms_norm(x, scale, eps: float = 1e-5):
    """RMSNorm over the head dim (qwen3 qk-norm). x (..., hd)."""
    return rms_norm(x, scale, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float):
    """x (B, S, H, hd); positions (B, S) or (S,)."""
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# Cross-shard matmul reduction precision. 'f32': partial dots accumulate and
# all-reduce in f32 (default, safest). 'bf16': dot outputs are bf16, so the
# tensor-parallel all-reduce moves half the bytes — the H2 hillclimb lever
# (Megatron-style bf16 reduce; MXU still accumulates f32 internally within a
# shard). Set via set_matmul_reduce_dtype() before lowering.
_MATMUL_REDUCE_DTYPE = "f32"


def set_matmul_reduce_dtype(mode: str):
    global _MATMUL_REDUCE_DTYPE
    assert mode in ("f32", "bf16"), mode
    _MATMUL_REDUCE_DTYPE = mode


def linear(x, w):
    """Matmul with f32 accumulation (bf16-safe) or bf16 cross-shard reduce."""
    pref = (jnp.bfloat16 if _MATMUL_REDUCE_DTYPE == "bf16"
            and x.dtype == jnp.bfloat16 else jnp.float32)
    return jax.lax.dot_general(
        x, w,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=pref,
    ).astype(x.dtype)


def constrain(x, plan, *logical):
    """Activation sharding constraint if a mesh is active (no-op otherwise)."""
    if plan is None or not plan.active:
        return x
    return jax.lax.with_sharding_constraint(x, plan.P(*logical))


__all__ = [
    "Pm", "is_pm", "split_params", "KeyGen", "dense_init",
    "rms_norm", "head_rms_norm", "rope_frequencies", "apply_rope",
    "linear", "constrain",
]
