"""Serving latency/throughput: full-bucket vs deadline flush policies.

The question this answers: what does the ``max_wait`` deadline policy cost
in throughput, and what does it buy in tail latency? A stream of small
clustering queries is driven through :class:`ClusterBatcher` twice —

* **full-bucket** — buckets flush only when they fill ``max_batch`` slots
  (plus the end-of-stream drain). This is the PR 1 behaviour: maximum
  padding efficiency, but a request whose bucket never fills waits for the
  entire stream.
* **deadline** — ``poll()`` after every admit flushes any bucket whose
  oldest request has waited past ``max_wait``; partial buckets pad to the
  next power-of-two sub-batch, so the compile budget stays
  O(#buckets · log max_batch).

Per-request latency = admit → retire on the engine clock. Both passes run
twice: the first warms the jit caches (the serving steady state), the
second measures. Results are asserted bit-identical to the per-graph
engine on a sample of requests.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py \
          [--graphs 200] [--max-batch 16] [--max-wait 0.05] [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import build_graph, correlation_cluster
from repro.core.graph import random_arboric
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest


def make_requests(num_graphs: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(num_graphs):
        n = int(rng.integers(8, 96))
        edges, _ = random_arboric(n, int(rng.integers(1, 4)), rng)
        reqs.append((uid, build_graph(n, edges)))
    return reqs

def drive(reqs, max_batch: int, max_wait, num_samples: int,
          arrival_gap: float = 0.0):
    """One serving pass; returns (wall_seconds, per-request waits, stats).

    ``arrival_gap`` spaces admissions in time (a Poisson-ish open-loop
    stream approximated by a fixed gap): with it, a bucket that fills
    slowly *ages*, which is exactly the situation the deadline policy
    exists for — the full-bucket policy makes those requests wait for the
    end-of-stream drain.
    """
    batcher = ClusterBatcher(max_batch=max_batch, max_wait=max_wait,
                             num_samples=num_samples)
    waits = {}

    def account(done):
        now = batcher.clock()
        for r in done:
            waits[r.uid] = now - r.admitted_at

    t0 = time.perf_counter()
    for uid, g in reqs:
        if arrival_gap:
            time.sleep(arrival_gap)
        account(batcher.admit(
            ClusterRequest(uid=uid, graph=g, key=jax.random.PRNGKey(uid))))
        account(batcher.poll())
    account(batcher.flush())
    dt = time.perf_counter() - t0
    assert len(waits) == len(reqs), "requests lost in the engine"
    return dt, np.array([waits[uid] for uid, _ in reqs]), batcher.stats


def pct(x, q):
    return float(np.percentile(x, q))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="deadline budget in seconds")
    ap.add_argument("--num-samples", type=int, default=1)
    ap.add_argument("--arrival-ms", type=float, default=2.0,
                    help="inter-arrival gap of the simulated request stream")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fewer graphs, correctness focus")
    args = ap.parse_args()
    n_graphs = 32 if args.smoke else args.graphs
    # Keep the arrival gap in smoke mode: without it the stream outruns
    # max_wait, no deadline flush ever fires, and the CI step would not
    # exercise the partial-flush machinery at all.
    arrival_gap = args.arrival_ms / 1e3

    reqs = make_requests(n_graphs)
    print(f"workload: {n_graphs} graphs, max_batch={args.max_batch}, "
          f"max_wait={args.max_wait * 1e3:.0f}ms, "
          f"arrival gap={arrival_gap * 1e3:.1f}ms")

    # Warm every pow2 sub-batch program the workload can hit (deadline
    # flushes run partial buckets, and flush grouping is timing-dependent,
    # so per-policy warm passes alone leave compile spikes in the tail).
    warmer = ClusterBatcher(max_batch=args.max_batch,
                            num_samples=args.num_samples)
    t0 = time.perf_counter()
    compiled = warmer.warmup(g for _, g in reqs)
    print(f"warmup: {compiled} bucket programs compiled in "
          f"{time.perf_counter() - t0:.1f}s")

    results = {}
    for label, max_wait in [("full-bucket", None),
                            ("deadline", args.max_wait)]:
        drive(reqs, args.max_batch, max_wait, args.num_samples)  # warm pass
        dt, waits, stats = drive(reqs, args.max_batch, max_wait,
                                 args.num_samples, arrival_gap=arrival_gap)
        results[label] = (dt, waits, stats)
        print(f"[{label:11s}] {n_graphs / dt:8.1f} graphs/s   "
              f"wait p50={pct(waits, 50) * 1e3:7.1f}ms  "
              f"p99={pct(waits, 99) * 1e3:7.1f}ms  "
              f"max={waits.max() * 1e3:7.1f}ms   "
              f"flushes={stats.flushes} (deadline={stats.deadline_flushes}) "
              f"padded_slots={stats.padded_slots}")
        if label == "deadline":
            assert stats.deadline_flushes > 0, (
                "deadline policy never fired — the comparison below would "
                "be two full-bucket runs; raise --arrival-ms or lower "
                "--max-wait")

    # Bit-exactness spot check against the per-graph engine.
    sample = reqs[:: max(1, len(reqs) // 8)]
    batcher = ClusterBatcher(max_batch=args.max_batch,
                             max_wait=args.max_wait,
                             num_samples=args.num_samples)
    done = {}
    for uid, g in sample:
        for r in batcher.admit(ClusterRequest(uid=uid, graph=g,
                                              key=jax.random.PRNGKey(uid))):
            done[r.uid] = r
        for r in batcher.poll():
            done[r.uid] = r
    for r in batcher.flush():
        done[r.uid] = r
    for uid, g in sample:
        ref = correlation_cluster(g, key=jax.random.PRNGKey(uid),
                                  num_samples=args.num_samples)
        assert (done[uid].result.labels == ref.labels).all()
        assert done[uid].result.cost == ref.cost
    print(f"bit-exactness: {len(sample)} sampled requests match the "
          "per-graph engine under the deadline policy")

    dt_full, w_full, _ = results["full-bucket"]
    dt_dead, w_dead, _ = results["deadline"]
    print(f"\nsummary: deadline policy holds p99 wait at "
          f"{pct(w_dead, 99) * 1e3:.1f}ms vs {pct(w_full, 99) * 1e3:.1f}ms "
          f"full-bucket, at {dt_full / dt_dead * 100:.0f}% of full-bucket "
          "throughput")


if __name__ == "__main__":
    main()
