"""Fault tolerance & elasticity for the training loop.

This module is the control-plane half of the story; the data plane
(checkpoint format, deterministic data sharding) lives in
``train/checkpoint.py`` and ``data/pipeline.py``.

Design (written for the 1000+ node target, exercised at laptop scale by
``tests/test_fault.py`` and ``examples/train_smollm.py``):

* **Failure model** — a host (and its chips) can vanish at any step; the
  SPMD program then fails collectively (all-reduce timeout). Recovery =
  restart from the last checkpoint. Since the data pipeline is a pure
  function of the step cursor, restarts are *bitwise* continuations
  (tested).
* **Checkpoint cadence** — ``every_steps`` balances lost-work (mean loss =
  cadence/2 × step_time × P(failure)) against write bandwidth;
  ``suggest_cadence`` implements the standard Young/Daly approximation
  √(2·MTBF·write_time).
* **Elastic re-mesh** — a restart may come up with a different device
  count; ``restore_checkpoint(..., shardings=new)`` re-lays-out the saved
  (unsharded) arrays onto the new mesh. Global batch and the step cursor
  are mesh-independent, so training semantics are unchanged.
* **Straggler mitigation** — deterministic sharding means any replacement
  host can compute its shard without coordination. For transient
  stragglers the launcher uses bounded-staleness step pacing: the watchdog
  (:class:`StepWatchdog`) flags steps exceeding ``k×`` the trailing median
  so the orchestrator can pre-emptively restart the slow host — on TPU
  pods, degraded-but-alive hosts are detected by step-time skew, not
  timeouts.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional


def suggest_cadence(mtbf_s: float, ckpt_write_s: float,
                    step_s: float) -> int:
    """Young/Daly optimal checkpoint interval, in steps."""
    interval_s = math.sqrt(2.0 * mtbf_s * ckpt_write_s)
    return max(1, int(interval_s / max(step_s, 1e-9)))


@dataclasses.dataclass
class StepWatchdog:
    """Flags straggler steps: > ``factor`` × trailing-median step time."""
    factor: float = 2.0
    window: int = 32
    _times: List[float] = dataclasses.field(default_factory=list)
    _last: Optional[float] = None

    def start(self):
        self._last = time.monotonic()

    def stop(self) -> bool:
        """Record a step; returns True if this step was a straggler."""
        assert self._last is not None, "start() not called"
        dt = time.monotonic() - self._last
        self._last = None
        straggler = False
        if len(self._times) >= 8:
            med = sorted(self._times[-self.window:])[
                len(self._times[-self.window:]) // 2]
            straggler = dt > self.factor * med
        self._times.append(dt)
        return straggler

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        t = sorted(self._times[-self.window:])
        return t[len(t) // 2]


class SimulatedFailure(RuntimeError):
    """Raised by the test harness to emulate a mid-run host loss."""


__all__ = ["suggest_cadence", "StepWatchdog", "SimulatedFailure"]
