"""Serving latency/throughput: scheduling policies × bucket executors.

Three questions answered, machine-readably (``BENCH_serve.json``):

* **Policy** — what does each scheduling policy cost in throughput and buy
  in tail latency? A stream of small clustering queries is driven through
  :class:`ClusterBatcher` under the full-bucket policy (buckets flush only
  when they fill ``max_batch``), the deadline policy (``poll()`` flushes
  any bucket whose oldest request waited past ``max_wait``), and — when
  ``--policy`` selects them — the adaptive and coalescing policies from
  ``repro.serve.scheduler``. Every pass emits its per-bucket flush-latency
  telemetry (p50/p99 wall + assemble, plus per-request build stats when
  rows are prebuilt at admission — the PR 8 ``pack`` split) so scheduling
  quality is tracked across PRs.
* **Starvation** (the coalescing acceptance scenario) — a skewed
  two-bucket arrival stream on a *virtual* clock: a hot bucket fills
  constantly while a cold bucket trickles. Under the full-bucket policy
  the cold requests wait for the end-of-stream drain; the coalescing
  policy promotes them into hot flushes and bounds their p99 wait; the
  cost-aware policy may reject individual steals but must stay inside the
  deadline bound. The comparison is deterministic (virtual time) and
  asserted.
* **Pad-hostile stream** (the cost-model acceptance scenario; runs on
  ``--policy cost`` passes) — hot deadline flushes land exactly on a pow2
  boundary, so every age-only steal doubles the sub-batch; the cost-aware
  policy prices the inflation and rejects, producing strictly fewer
  ``padded_slots`` at the same latency bound (virtual clock, asserted).
* **Shape-churn eviction** (``--policy cost`` passes) — a parade of fresh
  bucket shapes churns a deliberately small compiled-program cache while
  one hot shape keeps flushing: the cost policy's ``on_retire`` shape
  heat pins the hot shape, so hint-driven eviction recompiles no more
  than blind LRU (asserted; compile/eviction counts emitted).
* **Repeat traffic** (the result-cache acceptance scenario) — a
  zipf-skewed stream over a small unique pool, same engine with the
  content-addressed result cache on vs off. Every repeat of an already
  clustered (graph, key) retires at admission (or rides an identical
  in-flight request as a single-flight subscriber); hit rate and
  graphs/s speedup are asserted, and every served result — hit,
  subscriber, or cold — is checked bit-identical to the per-graph
  engine.
* **Pack split** (the admission-time packing acceptance scenario) —
  identical engines with ``prebuild_rows`` on vs off on a pack-bound
  small-bucket stream. Asserted: flush-time assemble p50 ≤ 0.5× the
  legacy flush repack p50, flush-path graphs/s ≥ 1.1×, and — through a
  deterministic coalescing leg — every result of a promoted (stolen)
  prebuilt flush bit-identical to the per-graph engine. Emitted as
  ``pack_split`` in the JSON.
* **Mixed-method trace** (the PR 10 method-registry acceptance scenario;
  always runs) — requests alternating ``method='pivot'`` /
  ``method='precluster'`` through one engine under the cost policy. Each
  method flushes through its own ``(method, R, W)`` queue (telemetry keys
  asserted for both), cross-method steals are refused by construction,
  and every result is asserted bit-identical to the per-graph engine of
  its own method. Emitted as ``mixed_method``. The headline policy
  passes take a ``--method`` axis so CI can smoke each registered bucket
  program end to end.
* **Executor / adaptive window** — what does pipelined execution buy, and
  does the adaptive in-flight window match a hand-tuned static
  ``max_in_flight``? Closed-loop steady-state comparisons, interleaved so
  background-load drift hits every engine equally; best-of-N reported.
  These engines run with the result cache *off*: the closed loop replays
  one request set, which a content-addressed cache would short-circuit,
  measuring the cache instead of the executor.

Per-request latency = admit → retire on the engine clock. Policy passes run
twice: the first warms the jit caches (the serving steady state), the
second measures.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py \
          [--graphs 200] [--max-batch 16] [--max-wait 0.05] \
          [--policy deadline] [--executor sync] [--method pivot] \
          [--smoke] [--json BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import build_graph, correlation_cluster, program_cache_info
from repro.core.graph import path, random_arboric
from repro.core.programs import registered_methods
from repro.serve.cluster_batcher import (
    AdmissionRejected,
    ClusterBatcher,
    ClusterRequest,
)
from repro.serve.engine import serve_all
from repro.serve.scheduler import POLICY_NAMES
from repro.util import VirtualClock


def make_requests(num_graphs: int, seed: int = 0, n_lo: int = 8,
                  n_hi: int = 96, lam_lo: int = 1, lam_hi: int = 3):
    """(uid, graph, λ) stream. λ rides along like batch_bench's ``lams``:
    real clients (dedup bands, LSH shards) know their arboricity bound, and
    passing it keeps admission off the degeneracy-peeling slow path."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(num_graphs):
        n = int(rng.integers(n_lo, n_hi))
        edges, lam = random_arboric(n, int(rng.integers(lam_lo, lam_hi + 1)),
                                    rng)
        reqs.append((uid, build_graph(n, edges), lam))
    return reqs


def drive(reqs, max_batch: int, max_wait, num_samples: int,
          executor: str = "sync", arrival_gap: float = 0.0, batcher=None,
          policy=None, method: str = "pivot"):
    """One serving pass; returns (wall_seconds, per-request waits, stats).

    ``arrival_gap`` spaces admissions in time (a Poisson-ish open-loop
    stream approximated by a fixed gap): with it, a bucket that fills
    slowly *ages*, which is exactly the situation the deadline policy
    exists for — the full-bucket policy makes those requests wait for the
    end-of-stream drain. Pass a long-lived ``batcher`` to measure the
    steady state (warm pools and caches) instead of a cold engine.
    Admissions refused by a backpressure window are retried after a
    harvest, like the ``serve_all`` reference loop.
    """
    if batcher is None:
        batcher = ClusterBatcher(max_batch=max_batch, max_wait=max_wait,
                                 num_samples=num_samples, executor=executor,
                                 policy=policy, method=method)
    waits = {}

    def account(done):
        now = batcher.clock()
        for r in done:
            waits[r.uid] = now - r.admitted_at

    t0 = time.perf_counter()
    for uid, g, lam in reqs:
        if arrival_gap:
            time.sleep(arrival_gap)
        req = ClusterRequest(uid=uid, graph=g, key=jax.random.PRNGKey(uid),
                             lam=lam)
        while True:
            try:
                account(batcher.admit(req))
                break
            except AdmissionRejected:
                done = batcher.retire()
                account(done)
                if not done:
                    # No progress: sleep like serve_all's reject_backoff —
                    # a zero-backoff spin would burn the very host cores
                    # the steady-state comparison measures.
                    time.sleep(0.0005)
        account(batcher.poll())
    account(batcher.flush())
    dt = time.perf_counter() - t0
    assert len(waits) == len(reqs), "requests lost in the engine"
    return dt, np.array([waits[uid] for uid, *_ in reqs]), batcher.stats


def steady_throughput(reqs, engines, repeat: int = 5):
    """Steady-state closed-loop graphs/s per named engine, interleaved.

    Long-lived engines (so pools, jit caches and — for the pipelined path
    — the extra in-flight staging generations are all warm, as in real
    serving). Passes alternate between engines (a, b, a, ...) so
    background-load drift on a shared host degrades every engine's sample
    set equally; best-of-N per engine is reported.
    """
    best = {name: None for name in engines}
    for name, engine in engines.items():        # warm pass per engine
        drive(reqs, engine.max_batch, None, engine.num_samples,
              batcher=engine)
    for _ in range(repeat):
        for name, engine in engines.items():
            dt, _, _ = drive(reqs, engine.max_batch, None,
                             engine.num_samples, batcher=engine)
            best[name] = dt if best[name] is None else min(best[name], dt)
    return {name: len(reqs) / t for name, t in best.items()}


def starvation_comparison(smoke: bool, max_batch: int = 16,
                          gap: float = 0.002):
    """Skewed two-bucket stream on a virtual clock: full vs coalesce vs
    cost-aware coalesce.

    A hot ``(32, 4)`` bucket receives almost every arrival; a cold
    ``(8, 4)`` bucket gets one request every ``cold_every`` arrivals and
    never fills ``max_batch``. Waits are measured in *virtual* seconds, so
    the comparison is deterministic: under the full-bucket policy cold
    requests survive to the end-of-stream drain (p99 wait grows with the
    stream), under the coalescing policy (deadline ``10·gap``, aggressive
    ``steal_wait``) the hot bucket's partial deadline flushes have spare
    room and the cold requests are promoted into them — their p99 wait is
    bounded by the hot flush cadence, not the stream length. The
    cost-aware policy may *reject* individual steals (priced against real
    flush telemetry), but a rejected request still flushes on its own
    ``max_wait`` deadline, so its p99 must stay within the coalesce-style
    bound — asserted against ``max_wait`` plus one poll tick.
    """
    n_hot = 64 if smoke else 240
    cold_every = 16
    max_wait = 10 * gap

    def build_stream():
        # Fresh rng per pass: all policies must see the *identical* stream
        # or the asserted A/B would compare two different workloads.
        rng = np.random.default_rng(7)
        stream = []
        uid = 0
        for i in range(n_hot):
            if i % cold_every == 0:
                stream.append((uid, build_graph(6, path(6)), True))
                uid += 1
            n = int(rng.integers(17, 30))
            stream.append((uid, build_graph(n, path(n)), False))
            uid += 1
        return stream

    from repro.serve.scheduler import (CoalescingPolicy,
                                       CostAwareCoalescingPolicy)

    results = {}
    for policy in ("full", "coalesce", "cost"):
        clock = VirtualClock()
        if policy == "coalesce":
            pol = CoalescingPolicy(max_batch, max_wait=max_wait,
                                   steal_wait=gap / 2)
        elif policy == "cost":
            pol = CostAwareCoalescingPolicy(max_batch, max_wait=max_wait,
                                            steal_wait=gap / 2)
        else:
            pol = policy
        batcher = ClusterBatcher(max_batch=max_batch, policy=pol,
                                 clock=clock)
        waits, is_cold = {}, {}
        stream = build_stream()

        def account(done, now):
            for r in done:
                waits[r.uid] = now - r.admitted_at

        for uid, g, cold in stream:
            is_cold[uid] = cold
            clock.advance(gap)
            account(batcher.admit(
                ClusterRequest(uid=uid, graph=g,
                               key=jax.random.PRNGKey(uid))), clock.t)
            account(batcher.poll(), clock.t)
        account(batcher.flush(), clock.t)
        cold_waits = np.array([w for uid, w in waits.items() if is_cold[uid]])
        hot_waits = np.array([w for uid, w in waits.items()
                              if not is_cold[uid]])
        results[policy] = {
            "cold_p99_ms": pct(cold_waits, 99) * 1e3,
            "cold_max_ms": float(cold_waits.max()) * 1e3,
            "hot_p99_ms": pct(hot_waits, 99) * 1e3,
            "coalesced_flushes": batcher.stats.coalesced_flushes,
            "stolen_requests": batcher.stats.stolen_requests,
        }
        if policy == "cost":
            results[policy].update(batcher.policy.cost_stats())
        print(f"[starve:{policy:8s}] cold p99={results[policy]['cold_p99_ms']:8.1f}ms "
              f"max={results[policy]['cold_max_ms']:8.1f}ms   "
              f"hot p99={results[policy]['hot_p99_ms']:6.1f}ms   "
              f"stolen={batcher.stats.stolen_requests}")
    assert results["coalesce"]["stolen_requests"] > 0, \
        "coalescing policy never stole — the scenario is broken"
    assert results["coalesce"]["cold_p99_ms"] < results["full"]["cold_p99_ms"], (
        "coalescing must bound the starved bucket's p99 wait below the "
        "full-bucket policy's end-of-stream drain")
    # The cost-aware policy's rejections must never void the latency
    # contract: every cold request is bounded by its own deadline (plus
    # one poll tick, since polls ride the gap-spaced admit loop), while
    # the end-of-stream drain under full-bucket grows with the stream.
    cost_bound_ms = (max_wait + 2 * gap) * 1e3
    assert results["cost"]["cold_max_ms"] <= cost_bound_ms + 1e-6, (
        f"cost-aware coalescing exceeded the deadline bound: "
        f"{results['cost']['cold_max_ms']:.1f}ms > {cost_bound_ms:.1f}ms")
    assert results["cost"]["cold_p99_ms"] < results["full"]["cold_p99_ms"]
    return results


def pad_hostile_comparison(smoke: bool, max_batch: int = 16,
                           gap: float = 0.002):
    """Pow2-boundary mixed stream on a virtual clock: age-only coalescing
    vs the cost-aware policy (the tentpole acceptance scenario).

    Each window admits exactly 8 hot ``(32, 4)`` requests (a deadline
    flush of 8 packs into ``g_pad = 8`` with zero empty group slots) plus
    one starving cold ``(8, 4)`` request. Age-only coalescing promotes the
    cold request into every hot deadline flush — inflating the sub-batch
    to ``g_pad = 16`` and paying 7 empty entries per flush. The cost-aware
    policy prices that inflation (a pessimistic ``service_floor_s`` makes
    the pricing independent of host timing noise: floor cost ≥ 50 ms of
    device time vs ≤ ``max_wait`` = 20 ms of slack saved) and rejects the
    steal; the cold request rides its *own* deadline at ``g_pad = 1`` with
    zero padding. Asserted: strictly fewer ``padded_slots`` under the cost
    policy, with the cold p99 still inside the deadline bound.
    """
    from repro.serve.costmodel import FlushCostModel
    from repro.serve.scheduler import (CoalescingPolicy,
                                       CostAwareCoalescingPolicy)

    n_windows = 6 if smoke else 14
    max_wait = 10 * gap
    hot_per_window = 8

    def build_window(rng, uid):
        window = []
        for j in range(hot_per_window):
            n = int(rng.integers(17, 30))
            window.append((uid, build_graph(n, path(n)), False))
            uid += 1
            if j == 3:          # cold trickles in mid-window
                window.append((uid, build_graph(6, path(6)), True))
                uid += 1
        return window, uid

    results = {}
    for policy in ("coalesce", "cost"):
        clock = VirtualClock()
        if policy == "coalesce":
            pol = CoalescingPolicy(max_batch, max_wait=max_wait,
                                   steal_wait=gap / 2)
        else:
            pol = CostAwareCoalescingPolicy(
                max_batch, max_wait=max_wait, steal_wait=gap / 2,
                cost_model=FlushCostModel(service_floor_s=0.05))
        batcher = ClusterBatcher(max_batch=max_batch, policy=pol,
                                 clock=clock)
        waits, is_cold = {}, {}
        rng = np.random.default_rng(11)     # identical stream per arm
        uid = 0

        def account(done, now):
            for r in done:
                waits[r.uid] = now - r.admitted_at

        for _ in range(n_windows):
            window, uid = build_window(rng, uid)
            for w_uid, g, cold in window:
                is_cold[w_uid] = cold
                clock.advance(gap)
                account(batcher.admit(
                    ClusterRequest(uid=w_uid, graph=g,
                                   key=jax.random.PRNGKey(w_uid))), clock.t)
                account(batcher.poll(), clock.t)
            # Idle tail of the window: the oldest hot request crosses
            # max_wait here, so the deadline flush carries exactly the 8
            # hot requests — a pow2 boundary every steal would double.
            clock.advance(3 * gap)
            account(batcher.poll(), clock.t)
        account(batcher.flush(), clock.t)
        cold_waits = np.array([w for uid, w in waits.items() if is_cold[uid]])
        results[policy] = {
            "padded_slots": batcher.stats.padded_slots,
            "stolen_requests": batcher.stats.stolen_requests,
            "cold_p99_ms": pct(cold_waits, 99) * 1e3,
            "cold_max_ms": float(cold_waits.max()) * 1e3,
        }
        if policy == "cost":
            results[policy].update(batcher.policy.cost_stats())
        print(f"[pad-hostile:{policy:8s}] padded_slots="
              f"{results[policy]['padded_slots']:4d}  "
              f"stolen={results[policy]['stolen_requests']:3d}  "
              f"cold p99={results[policy]['cold_p99_ms']:6.1f}ms")
    assert results["coalesce"]["stolen_requests"] > 0, \
        "age-only coalescing never stole — the pad-hostile stream is broken"
    assert results["cost"]["steals_rejected"] > 0, \
        "cost model never rejected a steal on the pad-hostile stream"
    assert results["cost"]["padded_slots"] < results["coalesce"]["padded_slots"], (
        "cost-aware coalescing must produce strictly fewer padded slots "
        f"than age-only on the pad-hostile stream "
        f"({results['cost']['padded_slots']} vs "
        f"{results['coalesce']['padded_slots']})")
    cost_bound_ms = (max_wait + 2 * gap) * 1e3
    assert results["cost"]["cold_max_ms"] <= cost_bound_ms + 1e-6, (
        "rejected steals must still retire on their own deadline")
    return results


def eviction_churn_comparison(smoke: bool):
    """Shape churn through a small program cache: blind LRU vs the
    scheduler's heat-driven ``touch``/``pin`` eviction hints.

    One hot bucket shape flushes three times per sweep while a parade of
    *fresh* cold shapes (distinct ``(B, R, W)`` programs, never repeated)
    churns through a deliberately small compiled-program cache. Under
    blind LRU the cold parade evicts the hot shape's program between
    visits, so the hot shape recompiles every sweep; the cost policy's
    ``on_retire`` heat tracking pins the hot shape, which survives the
    churn. First-time compiles are identical in both arms (same
    workload), so the compile-count difference is exactly the recompiles
    — asserted: hinted ≤ blind. The hinted arm runs *first* so any cache
    residue between arms favours the blind baseline.
    """
    from repro.core.executor import (program_cache_info, program_cache_unpin,
                                     set_program_cache_capacity)
    from repro.serve.costmodel import ShapeHeat
    from repro.serve.scheduler import (CostAwareCoalescingPolicy,
                                       DeadlinePolicy)

    capacity = 4
    sweeps = 3 if smoke else 4
    cold_ns = (9, 17, 33, 65)           # R = 16 / 32 / 64 / 128
    max_wait = 0.01
    prev = set_program_cache_capacity(capacity)

    def reset_cache():
        # Bounce the capacity to evict (almost) everything, so each arm
        # starts from the same near-empty cache; drop any leftover pins.
        for bucket in program_cache_info()["pinned"]:
            program_cache_unpin(tuple(bucket))
        set_program_cache_capacity(1)
        set_program_cache_capacity(capacity)

    def drive(policy) -> dict:
        reset_cache()
        clock = VirtualClock()
        batcher = ClusterBatcher(max_batch=8, policy=policy, clock=clock)
        hot = build_graph(6, path(6))                    # bucket (8, 4)
        uid = 0
        info0 = program_cache_info()
        for sweep in range(sweeps):
            for _ in range(3):                           # hot keeps coming
                batcher.admit(ClusterRequest(uid=uid, graph=hot,
                                             key=jax.random.PRNGKey(uid)))
                uid += 1
                clock.advance(2 * max_wait)
                batcher.poll()
            for n in cold_ns:                            # fresh cold shapes:
                count = 1 << sweep                       # new pow2 B per sweep
                for _ in range(count):
                    batcher.admit(ClusterRequest(
                        uid=uid, graph=build_graph(n, path(n)),
                        key=jax.random.PRNGKey(uid)))
                    uid += 1
                clock.advance(2 * max_wait)
                batcher.poll()
        batcher.flush()
        info1 = program_cache_info()
        return {
            "compiles": info1["compiles"] - info0["compiles"],
            "evictions": info1["evictions"] - info0["evictions"],
            "pinned": [list(b) for b in info1["pinned"]],
        }

    try:
        hinted = drive(CostAwareCoalescingPolicy(
            8, max_wait=max_wait, steal_wait=max_wait,
            heat=ShapeHeat(window=32, max_pinned=1, min_heat=3)))
        for bucket in program_cache_info()["pinned"]:
            program_cache_unpin(tuple(bucket))
        blind = drive(DeadlinePolicy(8, max_wait=max_wait))
    finally:
        for bucket in program_cache_info()["pinned"]:
            program_cache_unpin(tuple(bucket))
        set_program_cache_capacity(prev)
    print(f"[churn:hinted ] compiles={hinted['compiles']:3d} "
          f"evictions={hinted['evictions']:3d} pinned={hinted['pinned']}")
    print(f"[churn:blind  ] compiles={blind['compiles']:3d} "
          f"evictions={blind['evictions']:3d}")
    assert blind["evictions"] > 0, \
        "churn never evicted — the cache is not under pressure"
    assert hinted["compiles"] <= blind["compiles"], (
        "hint-driven eviction must not recompile more than blind LRU "
        f"({hinted['compiles']} vs {blind['compiles']})")
    return {"hinted": hinted, "blind": blind, "capacity": capacity}


def repeat_traffic_comparison(smoke: bool, max_batch: int = 16,
                              executor: str = "sync"):
    """Zipf repeat traffic: content-addressed result cache + single-flight
    coalescing vs the identical engine with the cache off.

    A stream of ``n_stream`` requests drawn zipf-skewed (``p ∝ 1/rank^s``,
    explicit bounded pmf — ``rng.zipf`` has an unbounded tail) from
    ``n_unique`` (graph, key) pairs. Deduplicated serving traffic looks
    exactly like this: a few hot similarity shards dominate the stream.
    With the cache on, the first occurrence of each pair flushes cold and
    every later one either retires at admission (cache hit) or subscribes
    to the in-flight primary; with it off, every request packs and
    flushes. Both arms run the deadline policy on the real clock — full
    buckets never fill under duplicate-heavy traffic (the duplicates
    subscribe instead of queueing), so primaries must flush on a deadline
    for repeats to find a *completed* winner.

    The cache-off arm runs first, so any residual warmth (jit programs,
    allocator state) favours the baseline. Asserted: zero hits with the
    cache off, hit rate > 0.5 and ≥ 1.5× graphs/s with it on, and every
    retired result — hit, subscriber, or cold — bit-identical to the
    per-graph engine.
    """
    n_unique = 24 if smoke else 48
    n_stream = 192 if smoke else 768
    zipf_s = 1.2
    max_wait = 0.002

    pool = make_requests(n_unique, seed=17, n_lo=24, n_hi=64)
    ranks = np.arange(1, n_unique + 1, dtype=np.float64)
    pmf = ranks ** -zipf_s
    pmf /= pmf.sum()
    stream = np.random.default_rng(23).choice(n_unique, size=n_stream,
                                              p=pmf)
    refs = {int(idx): correlation_cluster(
                pool[idx][1], key=jax.random.PRNGKey(1000 + int(idx)),
                lam=pool[idx][2])
            for idx in set(stream.tolist())}

    # Shared jit warmup: bucket programs live in the process-global cache,
    # so one warm engine covers both arms identically.
    ClusterBatcher(max_batch=max_batch,
                   executor=executor).warmup(g for _, g, _ in pool)

    results = {}
    for arm, cache_on in (("no_cache", False), ("cache", True)):
        batcher = ClusterBatcher(max_batch=max_batch, max_wait=max_wait,
                                 executor=executor, result_cache=cache_on)
        reqs = [ClusterRequest(uid=pos, graph=pool[idx][1],
                               key=jax.random.PRNGKey(1000 + int(idx)),
                               lam=pool[idx][2])
                for pos, idx in enumerate(stream)]
        t0 = time.perf_counter()
        done = {r.uid: r for r in serve_all(batcher, reqs)}
        dt = time.perf_counter() - t0
        assert len(done) == n_stream, "requests lost in the engine"
        for pos, idx in enumerate(stream):
            ref = refs[int(idx)]
            assert (done[pos].result.labels == ref.labels).all(), \
                "cached/subscribed result diverged from the cold engine"
            assert done[pos].result.cost == ref.cost
        stats = batcher.stats
        results[arm] = {
            "gps": n_stream / dt,
            "wall_s": dt,
            "flushes": stats.flushes,
            "cache_hits": stats.cache_hits,
            "subscribed": stats.subscribed,
            "hit_rate": stats.cache_hits / n_stream,
        }
        if stats.result_cache is not None:
            rc = stats.result_cache
            results[arm]["result_cache"] = {
                "hits": rc.hits, "misses": rc.misses,
                "evictions": rc.evictions, "collisions": rc.collisions,
                "entries": rc.entries, "bytes": rc.bytes,
            }
        print(f"[repeat:{arm:9s}] {results[arm]['gps']:8.1f} graphs/s   "
              f"flushes={stats.flushes:4d}  hits={stats.cache_hits:4d}  "
              f"subscribed={stats.subscribed:3d}")
    hit_rate = results["cache"]["hit_rate"]
    speedup = results["cache"]["gps"] / results["no_cache"]["gps"]
    results.update(speedup=speedup, zipf_s=zipf_s,
                   n_unique=n_unique, n_stream=n_stream)
    assert results["no_cache"]["cache_hits"] == 0, \
        "cache-off arm recorded hits — the baseline is not cache-free"
    assert hit_rate > 0.5, (
        f"repeat-traffic hit rate {hit_rate:.2f} <= 0.5 — primaries are "
        "not completing before their repeats arrive (deadline too long?)")
    assert speedup >= 1.5, (
        f"result cache bought only {speedup:.2f}x over the cache-off arm "
        "on zipf repeat traffic (expected >= 1.5x)")
    print(f"[repeat] hit rate={hit_rate:.2f}  "
          f"cache speedup={speedup:.2f}x over cache-off")
    return results


def pack_split_comparison(smoke: bool, max_batch: int = 16):
    """Admission-time packing split (the PR 8 acceptance scenario).

    Two identical engines on the same pack-bound small-bucket stream
    (n ∈ [8, 24): host packing dwarfs the device program at these
    shapes): ``prebuild_rows=True`` (rows built once at admission,
    flushes only assemble) vs ``prebuild_rows=False`` (the pre-split
    engine: every flush re-derives every graph's ELL rows). Both run the
    closed steady-state loop of :func:`steady_throughput`, so jit caches,
    pools and staging are warm and the flush-latency telemetry holds the
    full pass history.

    Two asserted ratios:

    * **assemble p50** — the host time left on the flush critical path.
      With prebuilt rows a flush copies finished rows into staging; the
      legacy arm's "assemble" is the whole per-graph repack. Asserted
      ≤ 0.5× (measured ≈ 0.1–0.2×).
    * **flush-path graphs/s** — graphs retired per second spent *in the
      flush path* (bucket assembly + device + harvest; measured on the
      real clock as the pass wall minus the admission time, where an
      admit that triggered an inline full-bucket flush is charged the
      running mean of pure-admission walls). This is the engine's
      sustainable retire rate when admissions ride the arrival stream —
      the serving regime the split targets, where per-request builds
      land in inter-arrival gaps instead of on the flush path. Asserted
      ≥ 1.1× (measured ≈ 2×).

    End-to-end closed-loop graphs/s for both arms is emitted un-asserted
    for transparency: with zero inter-arrival idle the build work has
    nowhere to hide and the arms bracket a ~1× wash — the split moves
    host work off the flush path, it does not delete it.

    A second leg re-runs the starvation shape (hot path-graph bucket, a
    trickle of cold small graphs, coalescing policy on a virtual clock)
    through both arms and asserts every retired result bit-identical to
    the per-graph engine — with ``stolen_requests > 0`` in both arms, so
    the prebuilt path is exercised *through shape promotion* (stolen
    rows relayouted by ``PackedRows.promote`` into the hot flush).
    """
    n_graphs = 96 if smoke else 256
    # Best-of-2 sampling: the per-graph key folding and two-key rank
    # dispatches are exactly the per-request costs the split moves to
    # admission, so k=2 is where the flush path has the most to lose to
    # a legacy repack (and the asserted ratios their widest margin).
    num_samples = 2
    reqs = make_requests(n_graphs, seed=13, n_lo=8, n_hi=24,
                         lam_lo=1, lam_hi=2)
    ClusterBatcher(max_batch=max_batch, num_samples=num_samples).warmup(
        g for _, g, _ in reqs)
    engines = {
        "legacy": ClusterBatcher(max_batch=max_batch, result_cache=False,
                                 num_samples=num_samples,
                                 prebuild_rows=False),
        "prebuild": ClusterBatcher(max_batch=max_batch, result_cache=False,
                                   num_samples=num_samples),
    }

    def pass_once(eng):
        """One closed-loop pass; returns (pass_wall, flush_path_seconds).

        The full-bucket policy flushes inline inside ``admit`` when a
        bucket fills, so flush-path time is the pass wall minus the
        admission walls: a non-flushing admit is pure admission (plan,
        and on the prebuild arm the row build); a flushing admit is
        charged the running mean of the pure ones and contributes the
        rest to the flush path.
        """
        retired = 0
        admit_s = 0.0
        admits = 0
        t_pass = time.perf_counter()
        for uid, g, lam in reqs:
            req = ClusterRequest(uid=uid, graph=g,
                                 key=jax.random.PRNGKey(uid), lam=lam)
            flushes0 = eng.stats.flushes
            t0 = time.perf_counter()
            retired += len(eng.admit(req))
            dt = time.perf_counter() - t0
            if eng.stats.flushes == flushes0:
                admit_s += dt
                admits += 1
            elif admits:
                admit_s += admit_s / admits
        retired += len(eng.flush())
        wall = time.perf_counter() - t_pass
        assert retired == len(reqs), "requests lost in the engine"
        return wall, max(1e-9, wall - admit_s)

    repeat = 3 if smoke else 5
    best = {name: (None, None) for name in engines}
    for eng in engines.values():                     # warm pass per arm
        pass_once(eng)
    for _ in range(repeat):                          # interleaved best-of-N
        for name, eng in engines.items():
            wall, flushpath = pass_once(eng)
            bw, bf = best[name]
            best[name] = (wall if bw is None else min(bw, wall),
                          flushpath if bf is None else min(bf, flushpath))

    results = {}
    for name, eng in engines.items():
        tele = eng.stats.latency
        assemble = tele.samples("assemble")
        results[name] = {
            "gps_e2e": n_graphs / best[name][0],
            "flushpath_gps": n_graphs / best[name][1],
            "assemble_p50_ms": pct(assemble, 50) * 1e3,
            "assemble_p99_ms": pct(assemble, 99) * 1e3,
            "flushes": tele.total_flushes,
            "builds": tele.total_builds,
            "build_p50_ms": pct(tele.samples("build"), 50) * 1e3
            if tele.total_builds else None,
        }
        r = results[name]
        build = (f"build p50={r['build_p50_ms']:.3f}ms  "
                 if r["build_p50_ms"] is not None else "")
        print(f"[pack:{name:8s}] flush-path {r['flushpath_gps']:8.1f} g/s   "
              f"e2e {r['gps_e2e']:8.1f} g/s   "
              f"assemble p50={r['assemble_p50_ms']:.3f}ms  {build}"
              f"flushes={r['flushes']}")
    assert results["legacy"]["builds"] == 0, \
        "legacy arm recorded admission builds — it is not the pre-split arm"
    assert results["prebuild"]["builds"] > 0, \
        "prebuild arm recorded no admission builds"
    assemble_ratio = (results["prebuild"]["assemble_p50_ms"]
                      / results["legacy"]["assemble_p50_ms"])
    flushpath_ratio = (results["prebuild"]["flushpath_gps"]
                       / results["legacy"]["flushpath_gps"])
    results.update(assemble_ratio=assemble_ratio,
                   flushpath_ratio=flushpath_ratio)
    assert assemble_ratio <= 0.5, (
        f"prebuilt assembly p50 is {assemble_ratio:.2f}x the legacy flush "
        "pack p50 (expected <= 0.5x) — the flush path is still rebuilding "
        "rows")
    assert flushpath_ratio >= 1.1, (
        f"prebuilt rows bought only {flushpath_ratio:.2f}x flush-path "
        "throughput over the legacy repack (expected >= 1.1x)")
    print(f"[pack] assemble p50 ratio={assemble_ratio:.2f}x  "
          f"flush-path speedup={flushpath_ratio:.2f}x")

    # Bit-exactness through promotion: the starvation shape forces the
    # coalescing policy to steal cold requests into hot flushes, so the
    # prebuild arm assembles *promoted* PackedRows. Virtual clock =
    # deterministic steal schedule, identical across arms.
    from repro.serve.scheduler import CoalescingPolicy

    # Same shape as starvation_comparison: the hot bucket's fill time
    # (max_batch · gap) must exceed the deadline or every flush is full
    # and steals never find spare room.
    n_hot = 64 if smoke else 144
    cold_every = 16
    gap = 0.002
    stolen = {}
    for name, prebuild in (("legacy", False), ("prebuild", True)):
        rng = np.random.default_rng(29)
        clock = VirtualClock()
        batcher = ClusterBatcher(
            max_batch=max_batch, clock=clock, result_cache=False,
            prebuild_rows=prebuild,
            policy=CoalescingPolicy(max_batch, max_wait=10 * gap,
                                    steal_wait=gap / 2))
        done = {}

        def account(rs):
            for r in rs:
                done[r.uid] = r.result
        uid = 0
        graphs = {}
        for i in range(n_hot):
            if i % cold_every == 0:
                graphs[uid] = build_graph(6, path(6))
            else:
                n = int(rng.integers(17, 30))
                graphs[uid] = build_graph(n, path(n))
            clock.advance(gap)
            account(batcher.admit(ClusterRequest(
                uid=uid, graph=graphs[uid], key=jax.random.PRNGKey(uid))))
            account(batcher.poll())
            uid += 1
        account(batcher.flush())
        assert len(done) == n_hot, "requests lost in the engine"
        assert batcher.stats.stolen_requests > 0, (
            f"{name} arm stole nothing — the promotion path was not "
            "exercised")
        stolen[name] = batcher.stats.stolen_requests
        for uid, g in graphs.items():
            ref = correlation_cluster(g, key=jax.random.PRNGKey(uid))
            assert (done[uid].labels == ref.labels).all() \
                and done[uid].cost == ref.cost, (
                f"{name} arm diverged from the per-graph engine on "
                f"request {uid} (coalesced/promoted flush)")
    assert stolen["legacy"] == stolen["prebuild"], \
        "the two arms saw different steal schedules — virtual clock broken"
    print(f"[pack] promotion bit-exactness: {n_hot} requests x 2 arms "
          f"match the per-graph engine ({stolen['prebuild']} stolen)")
    results["promotion_check"] = {"requests": n_hot,
                                  "stolen_requests": stolen["prebuild"]}
    return results


def mixed_method_comparison(smoke: bool, max_batch: int = 16,
                            executor: str = "sync"):
    """One engine serving both registered bucket programs in one trace,
    cost policy active (the PR 10 acceptance scenario).

    Requests alternate ``method='pivot'`` / ``method='precluster'`` over
    assorted shapes through a single :class:`ClusterBatcher` under the
    cost-aware coalescing policy, so the per-``(method, R, W)`` queues,
    the cross-method steal refusal, and the method-tagged program-cache
    probes are all exercised together. Asserted: every retired result is
    bit-identical to the per-graph engine *of its own method* — a
    coalesced flush that mixed programs would break this immediately —
    and the flush-latency telemetry carries method-prefixed bucket keys
    for both methods (proving the queues never merged).
    """
    n = 48 if smoke else 128
    methods = ("pivot", "precluster")
    reqs = make_requests(n, seed=31, n_lo=8, n_hi=64)
    engine = ClusterBatcher(max_batch=max_batch, max_wait=0.005,
                            policy="cost", executor=executor)
    creqs = [ClusterRequest(uid=uid, graph=g, lam=lam,
                            key=jax.random.PRNGKey(uid),
                            method=methods[uid % 2])
             for uid, g, lam in reqs]
    t0 = time.perf_counter()
    done = {r.uid: r for r in serve_all(engine, creqs)}
    dt = time.perf_counter() - t0
    assert len(done) == n, "requests lost in the mixed-method engine"
    for uid, g, lam in reqs:
        m = methods[uid % 2]
        ref = correlation_cluster(g, key=jax.random.PRNGKey(uid), lam=lam,
                                  method=m)
        assert done[uid].result.method == m
        assert (done[uid].result.labels == ref.labels).all() \
            and done[uid].result.cost == ref.cost, (
            f"mixed-method engine diverged from the per-graph {m!r} "
            f"engine on request {uid}")
    stats = engine.stats
    tele_methods = {key.split(":", 1)[0]
                    for key in stats.latency.summary()}
    assert set(methods) <= tele_methods, (
        f"telemetry saw methods {sorted(tele_methods)}; both methods must "
        "flush through their own queues")
    engine.close()
    block = {
        "n_requests": n,
        "gps": n / dt,
        "flushes": stats.flushes,
        "coalesced_flushes": stats.coalesced_flushes,
        "stolen_requests": stats.stolen_requests,
        "buckets_seen": stats.buckets_seen,
        "methods": sorted(tele_methods),
    }
    block.update(engine.policy.cost_stats())
    print(f"[mixed-method] {block['gps']:8.1f} graphs/s   "
          f"flushes={block['flushes']}  stolen={block['stolen_requests']}  "
          f"queues={block['buckets_seen']}  "
          f"bit-exact per method: {n} requests")
    return block


def pct(x, q):
    return float(np.percentile(x, q))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="deadline budget in seconds")
    ap.add_argument("--num-samples", type=int, default=1)
    ap.add_argument("--arrival-ms", type=float, default=2.0,
                    help="inter-arrival gap of the simulated request stream")
    ap.add_argument("--policy", choices=list(POLICY_NAMES),
                    default="deadline",
                    help="scheduling policy for the headline policy pass")
    ap.add_argument("--executor", choices=["sync", "async", "sharded"],
                    default="sync",
                    help="bucket executor for the policy passes")
    ap.add_argument("--method", choices=list(registered_methods()),
                    default="pivot",
                    help="bucket program for the headline policy passes "
                         "(the mixed-method scenario always runs both)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable results path ('' to skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fewer graphs, correctness focus")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep kernel block shapes per bucket tier during "
                         "warmup and emit the tuning block")
    args = ap.parse_args()
    n_graphs = 32 if args.smoke else args.graphs
    # Keep the arrival gap in smoke mode: without it the stream outruns
    # max_wait, no deadline flush ever fires, and the CI step would not
    # exercise the partial-flush machinery at all.
    arrival_gap = args.arrival_ms / 1e3

    reqs = make_requests(n_graphs)
    print(f"workload: {n_graphs} graphs, max_batch={args.max_batch}, "
          f"max_wait={args.max_wait * 1e3:.0f}ms, "
          f"arrival gap={arrival_gap * 1e3:.1f}ms, "
          f"policy={args.policy}, executor={args.executor}, "
          f"method={args.method}")

    # Warm every pow2 sub-batch program the workload can hit (deadline
    # flushes run partial buckets, and flush grouping is timing-dependent,
    # so per-policy warm passes alone leave compile spikes in the tail).
    warmer = ClusterBatcher(max_batch=args.max_batch,
                            num_samples=args.num_samples,
                            executor=args.executor, method=args.method)
    t0 = time.perf_counter()
    compiled = warmer.warmup((g for _, g, _ in reqs),
                             autotune=args.autotune,
                             repeats=2 if args.smoke else 3)
    print(f"warmup: {compiled} bucket programs compiled in "
          f"{time.perf_counter() - t0:.1f}s")
    tuning_block = {"enabled": bool(args.autotune)}
    if args.autotune:
        tuning_block.update(warmer.stats.tuning or {})
        cache_info = tuning_block.get("sweeps"), tuning_block.get("hits")
        print(f"autotune: sweeps={cache_info[0]} cache hits={cache_info[1]} "
              f"({len(tuning_block.get('sweep_log', []))} sweep records)")

    # Policy comparison: full-bucket and deadline always (the cross-PR
    # baseline pair), plus the selected --policy when it is neither.
    policy_runs = ["full", "deadline"]
    if args.policy not in policy_runs:
        policy_runs.append(args.policy)
    results = {}
    for policy in policy_runs:
        max_wait = None if policy == "full" else args.max_wait
        drive(reqs, args.max_batch, max_wait, args.num_samples,
              executor=args.executor, policy=policy,
              method=args.method)                             # warm pass
        dt, waits, stats = drive(reqs, args.max_batch, max_wait,
                                 args.num_samples, executor=args.executor,
                                 policy=policy, arrival_gap=arrival_gap,
                                 method=args.method)
        results[policy] = (dt, waits, stats)
        extra = ""
        if stats.stolen_requests:
            extra = f" stolen={stats.stolen_requests}"
        if stats.rejected:
            extra += f" rejected={stats.rejected}"
        print(f"[{policy:9s}] {n_graphs / dt:8.1f} graphs/s   "
              f"wait p50={pct(waits, 50) * 1e3:7.1f}ms  "
              f"p99={pct(waits, 99) * 1e3:7.1f}ms  "
              f"max={waits.max() * 1e3:7.1f}ms   "
              f"flushes={stats.flushes} (deadline={stats.deadline_flushes})"
              f"{extra}")
        if policy == "deadline":
            assert stats.deadline_flushes > 0, (
                "deadline policy never fired — the comparison below would "
                "be two full-bucket runs; raise --arrival-ms or lower "
                "--max-wait")

    # Starvation: the coalescing acceptance scenario (virtual clock,
    # deterministic, asserted) — now three-armed with the cost policy.
    starvation = starvation_comparison(args.smoke)

    # Pad-hostile stream: the cost-model acceptance scenario — strictly
    # fewer padded slots than age-only coalescing, deadline bound intact.
    # Both cost-model scenarios are policy-independent A/Bs that build
    # their own engines, so run them only on the --policy cost passes
    # instead of repeating them across the whole CI smoke matrix.
    pad_hostile = pad_hostile_comparison(args.smoke) \
        if args.policy == "cost" else None

    # Pack split: the admission-time packing acceptance scenario —
    # asserted assemble-p50 and flush-path ratios plus bit-exactness
    # through promoted (coalesced) prebuilt flushes.
    pack_split = pack_split_comparison(args.smoke, max_batch=args.max_batch)

    # Executor comparison: closed-loop steady state, sync vs pipelined
    # (vs the selected executor when it is neither). The async win is the
    # host packing bucket i+1 while bucket i computes and transfers, so it
    # runs on the compute-heavy tier (n∈[100,250], λ≤4) where a flush's
    # device program is comparable to its host-side packing — on the small
    # tier the device is <15% of a flush cycle and there is nothing to
    # pipeline into. The warm drive pass inside steady_throughput compiles
    # exactly the shapes the closed loop hits.
    comp_reqs = make_requests(64 if args.smoke else 160, seed=1,
                              n_lo=100, n_hi=250, lam_lo=2, lam_hi=4)
    exec_names = ["sync", "async"]
    if args.executor not in exec_names:
        exec_names.append(args.executor)
    # Cache off: the closed loop replays the same request set, which the
    # content-addressed cache would short-circuit after the first pass —
    # the comparison would measure the cache, not the executor.
    engines = {name: ClusterBatcher(max_batch=args.max_batch,
                                    num_samples=args.num_samples,
                                    executor=name, result_cache=False)
               for name in exec_names}
    comparison = steady_throughput(comp_reqs, engines,
                                   repeat=3 if args.smoke else 6)
    for name in exec_names:
        print(f"[executor:{name:8s}] {comparison[name]:8.1f} graphs/s "
              "steady-state (closed loop, full buckets, heavy tier)")
    async_speedup = comparison["async"] / comparison["sync"]
    print(f"[executor] async pipelining: {async_speedup:.2f}x over sync")

    # Adaptive in-flight window vs a hand-tuned static max_in_flight: same
    # closed loop, pipelined executor, interleaved best-of-N. The adaptive
    # window replaces the static knob, so steady-state throughput should
    # match or beat it.
    window_engines = {
        "static": ClusterBatcher(max_batch=args.max_batch,
                                 num_samples=args.num_samples,
                                 executor="async", max_in_flight=4,
                                 result_cache=False),
        "adaptive": ClusterBatcher(max_batch=args.max_batch,
                                   num_samples=args.num_samples,
                                   executor="async", policy="adaptive",
                                   result_cache=False),
    }
    window_cmp = steady_throughput(comp_reqs, window_engines,
                                   repeat=3 if args.smoke else 6)
    adaptive_ratio = window_cmp["adaptive"] / window_cmp["static"]
    print(f"[in-flight] static(4)={window_cmp['static']:8.1f} g/s   "
          f"adaptive={window_cmp['adaptive']:8.1f} g/s   "
          f"ratio={adaptive_ratio:.2f}x")

    # Repeat traffic: the result-cache acceptance scenario (real clock,
    # asserted hit rate + speedup + bit-exactness).
    repeat_traffic = repeat_traffic_comparison(args.smoke,
                                               max_batch=args.max_batch,
                                               executor=args.executor)

    # Bit-exactness spot check against the per-graph engine, under the
    # selected policy.
    sample = reqs[:: max(1, len(reqs) // 8)]
    batcher = ClusterBatcher(max_batch=args.max_batch,
                             max_wait=args.max_wait,
                             num_samples=args.num_samples,
                             executor=args.executor, policy=args.policy,
                             method=args.method)
    sample_reqs = [ClusterRequest(uid=uid, graph=g,
                                  key=jax.random.PRNGKey(uid), lam=lam)
                   for uid, g, lam in sample]
    done = {r.uid: r for r in serve_all(batcher, sample_reqs)}
    for uid, g, lam in sample:
        ref = correlation_cluster(g, key=jax.random.PRNGKey(uid), lam=lam,
                                  num_samples=args.num_samples,
                                  method=args.method)
        assert (done[uid].result.labels == ref.labels).all()
        assert done[uid].result.cost == ref.cost
    print(f"bit-exactness: {len(sample)} sampled requests match the "
          f"per-graph engine under the {args.policy!r} policy "
          f"({args.executor} executor, {args.method!r} method)")

    # Mixed-method trace: both registered bucket programs through one
    # engine under the cost policy, asserted bit-exact per method.
    mixed_method = mixed_method_comparison(args.smoke,
                                           max_batch=args.max_batch,
                                           executor=args.executor)

    # Shape-churn eviction: scheduler heat hints vs blind LRU (runs last —
    # it squeezes the global program cache, which would otherwise force
    # recompiles into the timed passes above; cost passes only, like the
    # pad-hostile scenario).
    eviction_churn = eviction_churn_comparison(args.smoke) \
        if args.policy == "cost" else None

    dt_full, w_full, s_full = results["full"]
    dt_dead, w_dead, s_dead = results["deadline"]
    print(f"\nsummary: deadline policy holds p99 wait at "
          f"{pct(w_dead, 99) * 1e3:.1f}ms vs {pct(w_full, 99) * 1e3:.1f}ms "
          f"full-bucket, at {dt_full / dt_dead * 100:.0f}% of full-bucket "
          "throughput")

    if args.json:
        def policy_payload(dt, waits, stats):
            return {
                "gps": n_graphs / dt,
                "wait_p50_ms": pct(waits, 50) * 1e3,
                "wait_p99_ms": pct(waits, 99) * 1e3,
                "wait_max_ms": float(waits.max()) * 1e3,
                "flushes": stats.flushes,
                "deadline_flushes": stats.deadline_flushes,
                "coalesced_flushes": stats.coalesced_flushes,
                "stolen_requests": stats.stolen_requests,
                "padded_slots": stats.padded_slots,
                "rejected": stats.rejected,
                "in_flight_peak": stats.in_flight_peak,
                "flush_latency": stats.latency.summary(),
            }
        policies_payload = {
            "full_bucket": policy_payload(*results["full"]),
            "deadline": policy_payload(*results["deadline"]),
        }
        for policy in policy_runs:
            if policy not in ("full", "deadline"):
                policies_payload[policy] = policy_payload(*results[policy])
        payload = {
            "bench": "serve",
            "policy": args.policy,
            "executor": args.executor,
            "method": args.method,
            "smoke": bool(args.smoke),
            "n_graphs": n_graphs,
            "max_batch": args.max_batch,
            "max_wait_ms": args.max_wait * 1e3,
            "arrival_gap_ms": arrival_gap * 1e3,
            "warmup_programs": compiled,
            "policies": policies_payload,
            "starvation": starvation,
            "pack_split": pack_split,
            "executor_steady_gps": comparison,
            "async_speedup_vs_sync": async_speedup,
            "inflight_window_gps": window_cmp,
            "adaptive_vs_static_ratio": adaptive_ratio,
            "repeat_traffic": repeat_traffic,
            "mixed_method": mixed_method,
            "tuning": tuning_block,
            "program_cache": program_cache_info(),
        }
        # Host metadata + tuning-cache state: makes the perf trajectory
        # comparable across machines.
        from repro.kernels.autotune import host_provenance
        payload["provenance"] = host_provenance()
        if pad_hostile is not None:
            payload["pad_hostile"] = pad_hostile
        if eviction_churn is not None:
            payload["eviction_churn"] = eviction_churn
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
