"""Data-pipeline integration: near-dedup a corpus with Algorithm 4 + PIVOT.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""

from repro.data.dedup import dedup_corpus, dedup_quality
from repro.data.synthetic import synthetic_corpus, token_stream


def main():
    corpus = synthetic_corpus(n_docs=200, dup_fraction=0.4, mutate_p=0.05,
                              seed=0)
    res = dedup_corpus(corpus, threshold=0.45)
    q = dedup_quality(res, corpus)
    print(f"similarity graph edges: {res.n_edges}")
    print(f"clusters: {q['clusters']}  kept: {q['kept_fraction']:.1%} of docs")
    print(f"pairs precision {q['pairs_precision']:.3f} / "
          f"recall {q['pairs_recall']:.3f}")
    stream = token_stream(corpus, keep=res.keep)
    print(f"training stream: {len(stream)} tokens after dedup "
          f"(vs {len(token_stream(corpus))} raw)")


if __name__ == "__main__":
    main()
