"""Per-arch smoke tests (deliverable (f)) + sequence-mixer oracles.

Each assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs; decode
consistency (decode_step ≡ longer prefill) is asserted for every family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, get_smoke, supports_shape
from repro.models import RunConfig, build_model

RC = RunConfig(attn_impl="naive", loss_chunk=16, ssd_chunk=8,
               rwkv_impl="scan", moe_capacity=64.0)


def _batch(cfg, key, b, s):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_loss(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg, rc=RC, param_dtype=jnp.float32)
    params, specs = m.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    b, s = 2, 24
    batch = _batch(cfg, jax.random.PRNGKey(1), b, s)
    hidden = m.forward(params, batch)
    assert hidden.shape == (b, s, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all()), f"{arch}: NaN in hidden"
    loss = m.loss(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    # random-init loss should be near ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(
        cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_decode_consistency(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg, rc=RC, param_dtype=jnp.float32)
    params, _ = m.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                              cfg.vocab_size)
    batch_s = _batch(cfg, jax.random.PRNGKey(3), b, s)
    batch_s["tokens"] = toks[:, :s]
    batch_s1 = dict(batch_s)
    batch_s1["tokens"] = toks
    ref, _ = m.prefill(params, batch_s1, cache_len=s + 1,
                       cache_dtype=jnp.float32)
    _, caches = m.prefill(params, batch_s, cache_len=s + 1,
                          cache_dtype=jnp.float32)
    dec, caches2 = m.decode_step(params, toks[:, s], caches, jnp.int32(s))
    rel = float(jnp.max(jnp.abs(ref - dec))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 1e-3, f"{arch}: decode mismatch rel={rel}"
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_train_step(arch):
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import StepConfig, init_train_state, make_train_step
    cfg = get_smoke(arch)
    m = build_model(cfg, rc=RC, param_dtype=jnp.float32)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    sc = StepConfig(accum_steps=1)
    state = init_train_state(m, jax.random.PRNGKey(0), oc, sc)
    step = jax.jit(make_train_step(m, oc, sc))
    batch = _batch(cfg, jax.random.PRNGKey(4), 2, 16)
    l0 = None
    for _ in range(3):
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        if l0 is None:
            l0 = float(metrics["loss"])
    assert float(metrics["loss"]) < l0, f"{arch}: loss not decreasing"


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                             num_kv_heads=8, d_ff=2048, vocab_size=51865),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "granite-3-2b": dict(num_layers=40, d_model=2048, num_heads=32,
                             num_kv_heads=8, d_ff=8192, vocab_size=49155),
        "stablelm-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                             num_kv_heads=8, d_ff=13824, vocab_size=100352),
        "smollm-135m": dict(num_layers=30, d_model=576, num_heads=9,
                            num_kv_heads=3, d_ff=1536, vocab_size=49152),
        "olmoe-1b-7b": dict(num_layers=16, d_model=2048, num_heads=16,
                            num_kv_heads=16, vocab_size=50304,
                            num_experts=64, experts_per_tok=8),
        "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                            num_kv_heads=8, d_ff=32768, vocab_size=131072,
                            num_experts=8, experts_per_tok=2),
        "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64),
        "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                           vocab_size=65536, rwkv=True),
        "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192,
                                     num_heads=64, num_kv_heads=8,
                                     d_ff=28672, vocab_size=128256),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_shape_grid_and_skips():
    """40 cells; long_500k applies only to sub-quadratic archs."""
    from repro.configs import all_cells
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = [a for a, s, ok, _ in cells if s == "long_500k" and ok]
    assert set(runnable_long) == {"zamba2-2.7b", "rwkv6-1.6b"}


def test_param_counts_in_band():
    """Analytic param counts land near the advertised sizes."""
    bands = {
        "qwen3-8b": (6e9, 10e9),
        "granite-3-2b": (2e9, 3.5e9),
        "stablelm-12b": (10e9, 14e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "olmoe-1b-7b": (5e9, 8e9),
        "grok-1-314b": (250e9, 340e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "zamba2-2.7b": (2e9, 3.6e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_mamba_and_rwkv_chunked_vs_scan():
    from repro.models.rwkv import rwkv_chunked, rwkv_scan
    from repro.models.ssm import ssd_chunked, ssd_step
    key = jax.random.PRNGKey(0)
    B, T, H, P, N = 2, 50, 2, 8, 4
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, T, H, P))
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    bv = jax.random.normal(ks[2], (B, T, N))
    cv = jax.random.normal(ks[3], (B, T, N))
    s = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(T):
        s, y = ssd_step(s, x[:, t], jnp.exp(a_log[:, t]), bv[:, t], cv[:, t])
        ys.append(y)
    y_ref = jnp.stack(ys, 1)
    y, s_f = ssd_chunked(x, a_log, bv, cv, chunk=16)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
    assert float(jnp.max(jnp.abs(s_f - s))) < 1e-4

    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    logw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, T, H, N)), -8, 1))
    u = jax.random.normal(ks[4], (H, N))
    s0 = jax.random.normal(ks[5], (B, H, N, N))
    o_ref, sf_ref = rwkv_scan(r, k, v, logw, u, s0)
    o, sf = rwkv_chunked(r, k, v, logw, u, s0, chunk=16)
    assert float(jnp.max(jnp.abs(o - o_ref))) < 1e-3
    assert float(jnp.max(jnp.abs(sf - sf_ref))) < 1e-3


def test_moe_sort_equals_einsum_and_oracle():
    from repro.models.common import KeyGen, split_params
    from repro.models.mlp import _router, init_moe, moe_einsum, moe_sort
    from repro.models.sharding import ShardingPlan
    cfg = get_smoke("olmoe-1b-7b")
    p_pm = init_moe(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32,
                    ShardingPlan.null())
    p, _ = split_params(p_pm)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.d_model))
    gates, idx = _router(p, x, cfg)
    y_ref = np.zeros((12, cfg.d_model), np.float32)
    for t in range(12):
        for j in range(cfg.experts_per_tok):
            e = int(idx[t, j])
            h = jax.nn.silu(x[t] @ p["wg"][e]) * (x[t] @ p["wi"][e])
            y_ref[t] += float(gates[t, j]) * np.asarray(h @ p["wo"][e])
    for fn in (moe_sort, moe_einsum):
        y = fn(p, x, cfg, capacity_factor=100.0)
        assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4, fn.__name__
