"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import build_graph, random_permutation_ranks
from repro.core.graph import random_arboric, star
from repro.core.mis import neighbor_min_ranks
from repro.kernels import ops, ref
from repro.kernels.neighbor_min import ell_from_graph, neighbor_min_ell, pad_state


# --- neighbor_min ----------------------------------------------------------

@pytest.mark.parametrize("n,lam", [(17, 1), (64, 2), (257, 3), (1000, 5)])
def test_neighbor_min_matches_oracle(n, lam, rng):
    edges, _ = random_arboric(n, lam, rng)
    g = build_graph(n, edges)
    key = jax.random.PRNGKey(n)
    ranks = random_permutation_ranks(n, key)
    active = jax.random.bernoulli(key, 0.6, (n,))
    oracle = neighbor_min_ranks(g, ranks, active)
    kern = ops.neighbor_min(g, ranks, active)
    assert (np.asarray(oracle) == np.asarray(kern)).all()


@pytest.mark.parametrize("block_rows", [32, 128, 512])
def test_neighbor_min_block_sweep(block_rows, rng):
    edges, _ = random_arboric(300, 4, rng)
    g = build_graph(300, edges)
    ranks = random_permutation_ranks(300, jax.random.PRNGKey(0))
    active = jnp.ones((300,), bool)
    ell = ell_from_graph(g)
    rp, ap = pad_state(ranks, active)
    out = neighbor_min_ell(ell, rp, ap, block_rows=block_rows)
    expect = ref.neighbor_min_ref(ell, rp, ap)
    assert (np.asarray(out) == np.asarray(expect)).all()


def test_neighbor_min_star_highdeg(rng):
    """Width = n−1 row (hub) exercises the wide-ELL path."""
    g = build_graph(64, star(64))
    ranks = random_permutation_ranks(64, jax.random.PRNGKey(1))
    active = jnp.ones((64,), bool)
    oracle = neighbor_min_ranks(g, ranks, active)
    kern = ops.neighbor_min(g, ranks, active)
    assert (np.asarray(oracle) == np.asarray(kern)).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 60), seed=st.integers(0, 50),
       frac=st.floats(0.0, 1.0))
def test_neighbor_min_property(n, seed, frac):
    rng = np.random.default_rng(seed)
    edges, _ = random_arboric(n, 2, rng)
    g = build_graph(n, edges)
    key = jax.random.PRNGKey(seed)
    ranks = random_permutation_ranks(n, key)
    active = jax.random.bernoulli(key, frac, (n,))
    oracle = neighbor_min_ranks(g, ranks, active)
    kern = ops.neighbor_min(g, ranks, active)
    assert (np.asarray(oracle) == np.asarray(kern)).all()


def test_ell_truncation_raises(rng):
    """Regression: width < max degree used to silently drop neighbours,
    corrupting the MIS; it must raise unless explicitly allowed."""
    g = build_graph(32, star(32))                 # hub degree 31
    with pytest.raises(ValueError, match="width"):
        ell_from_graph(g, width=4)
    # explicit opt-in still works (rows beyond width are truncated)
    ell = ell_from_graph(g, width=4, allow_truncate=True)
    assert ell.shape == (32, 4)
    # and a safe width is unchanged behaviour
    assert ell_from_graph(g, width=31).shape == (32, 31)


def test_neighbor_min_batch_matches_single(rng):
    """Batched (batch, row_block) grid ≡ per-graph kernel on each slice."""
    B, n = 5, 64
    ells, rps, aps = [], [], []
    for i in range(B):
        edges, _ = random_arboric(n, 3, rng)
        g = build_graph(n, edges)
        key = jax.random.PRNGKey(i)
        ranks = random_permutation_ranks(n, key)
        active = jax.random.bernoulli(key, 0.5, (n,))
        ell = ell_from_graph(g, width=16, allow_truncate=g.max_degree() > 16)
        rp, ap = pad_state(ranks, active)
        ells.append(ell), rps.append(rp), aps.append(ap)
    w = max(e.shape[1] for e in ells)
    ells = [jnp.pad(e, ((0, 0), (0, w - e.shape[1])), constant_values=n)
            for e in ells]
    batch_out = ops.neighbor_min_ell_batch(
        jnp.stack(ells), jnp.stack(rps), jnp.stack(aps))
    for i in range(B):
        single = ops.neighbor_min_ell(ells[i], rps[i], aps[i])
        assert (np.asarray(batch_out[i]) == np.asarray(single)).all()


@pytest.mark.parametrize("block_rows", [16, 64, 256])
def test_neighbor_min_batch_block_sweep(block_rows, rng):
    edges, _ = random_arboric(100, 2, rng)
    g = build_graph(100, edges)
    ranks = random_permutation_ranks(100, jax.random.PRNGKey(2))
    active = jnp.ones((100,), bool)
    ell = ell_from_graph(g)
    rp, ap = pad_state(ranks, active)
    out = ops.neighbor_min_ell_batch(ell[None], rp[None], ap[None],
                                     block_rows=block_rows)
    expect = ref.neighbor_min_ref(ell, rp, ap)
    assert (np.asarray(out[0]) == np.asarray(expect)).all()


def _packed_batch(n, B, rng, width=None):
    """B random (ell, ranks_p, active_p) slices of one n-vertex bucket."""
    ells, rps, aps = [], [], []
    for i in range(B):
        edges, _ = random_arboric(n, 3, rng)
        g = build_graph(n, edges)
        key = jax.random.PRNGKey(1000 + i)
        ranks = random_permutation_ranks(n, key)
        active = jax.random.bernoulli(key, 0.5, (n,))
        ells.append(ell_from_graph(g))
        rp, ap = pad_state(ranks, active)
        rps.append(rp), aps.append(ap)
    w = max(e.shape[1] for e in ells)
    ells = [jnp.pad(e, ((0, 0), (0, w - e.shape[1])), constant_values=n)
            for e in ells]
    return jnp.stack(ells), jnp.stack(rps), jnp.stack(aps)


@pytest.mark.parametrize("block_rows", [48, 512])
def test_neighbor_min_batch_block_edge_cases(block_rows, rng):
    """block_rows > n_rows (512 on R=128) and a non-dividing tile (48 on
    R=128: 2 full blocks + a 32-row remainder) — bit-identical to the
    oracle either way."""
    n = 128
    ell, rp, ap = _packed_batch(n, 3, rng)
    out = ops.neighbor_min_ell_batch(ell, rp, ap, block_rows=block_rows)
    for i in range(3):
        expect = ref.neighbor_min_ref(ell[i], rp[i], ap[i])
        assert (np.asarray(out[i]) == np.asarray(expect)).all()


@pytest.mark.parametrize("block_rows", [48, 512])
def test_label_agree_batch_block_edge_cases(block_rows, rng):
    """Same edge tiles for the cost-pass kernel, vs its numpy-style
    oracle (label_agree_ref)."""
    n = 128
    ell, _rp, _ap = _packed_batch(n, 3, rng)
    labels = jnp.asarray(rng.integers(0, n, size=(3, n)), jnp.int32)
    labels_p = jnp.concatenate(
        [labels, jnp.full((3, 1), -1, jnp.int32)], axis=1)
    out = ops.label_agree_ell_batch(ell, labels_p, block_rows=block_rows)
    for i in range(3):
        expect = ref.label_agree_ref(ell[i], labels_p[i])
        assert (np.asarray(out[i]) == np.asarray(expect)).all()


def test_label_agree_batch_default_matches_ref(rng):
    """Default block path of the cost-pass kernel vs the oracle (the other
    batch tests route through the fused program, not the kernel alone)."""
    ell, _rp, _ap = _packed_batch(64, 2, rng)
    labels = jnp.asarray(rng.integers(0, 64, size=(2, 64)), jnp.int32)
    labels_p = jnp.concatenate(
        [labels, jnp.full((2, 1), -1, jnp.int32)], axis=1)
    out = ops.label_agree_ell_batch(ell, labels_p)
    for i in range(2):
        expect = ref.label_agree_ref(ell[i], labels_p[i])
        assert (np.asarray(out[i]) == np.asarray(expect)).all()


def test_interpret_mode_resolved_once():
    """Satellite: the wrappers read one import-time interpret flag — a
    mid-process backend probe can no longer flip the jit static arg."""
    assert isinstance(ops.interpret_mode(), bool)
    prev = ops.set_interpret_mode(True)
    try:
        assert ops.interpret_mode() is True
        # Wrappers still honour the contract under an explicit override.
        ell = jnp.full((1, 8, 4), 8, jnp.int32)
        rp = jnp.full((1, 9), 2**31 - 1, jnp.int32)
        ap = jnp.zeros((1, 9), bool)
        out = ops.neighbor_min_ell_batch(ell, rp, ap)
        assert (np.asarray(out) == 2**31 - 1).all()
    finally:
        ops.set_interpret_mode(prev)
    # None re-resolves from the live backend.
    ops.set_interpret_mode(None)
    assert ops.interpret_mode() == (jax.default_backend() != "tpu")


# --- flash attention --------------------------------------------------------

SHAPES = [
    (1, 4, 4, 128, 128, 64, True, jnp.float32),
    (2, 4, 2, 128, 128, 64, True, jnp.float32),     # GQA
    (1, 8, 1, 256, 256, 64, True, jnp.bfloat16),    # MQA bf16
    (2, 4, 4, 128, 384, 64, True, jnp.float32),     # kv longer (decode-ish)
    (1, 2, 2, 192, 192, 32, False, jnp.float32),    # non-causal, ragged
    (1, 9, 3, 130, 130, 64, True, jnp.float32),     # odd sizes (padding)
    (1, 4, 4, 64, 64, 128, True, jnp.bfloat16),     # big head dim
]


@pytest.mark.parametrize("b,h,kh,sq,sk,d,causal,dtype", SHAPES)
def test_flash_attention_matches_ref(b, h, kh, sq, sk, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kh, sk, d), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    expect = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - expect.astype(jnp.float32))))
    assert err < tol, (err, tol)


def test_flash_attention_block_sweep():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 4, 256, 64))
    v = jax.random.normal(ks[2], (1, 4, 256, 64))
    expect = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 256), (256, 128)]:
        out = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk)
        assert float(jnp.max(jnp.abs(out - expect))) < 2e-5


def test_chunked_xla_attention_matches_ref():
    """The pure-XLA blocked softmax (production CPU/dry-run path) — same
    contract as the kernel."""
    from repro.models.attention import _chunked_attention, _naive_attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, sq, kh, g, hd, sk = 2, 200, 2, 2, 32, 200
    q = jax.random.normal(ks[0], (b, sq, kh, g, hd))
    k = jax.random.normal(ks[1], (b, sk, kh, hd))
    v = jax.random.normal(ks[2], (b, sk, kh, hd))
    for causal in (True, False):
        a = _chunked_attention(q, k, v, causal, q_chunk=64, kv_chunk=96)
        e = _naive_attention(q, k, v, causal)
        assert float(jnp.max(jnp.abs(a - e))) < 2e-5
