"""Serving example: continuous batching with prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_decode.py
"""

from repro.launch.serve import run


def main():
    reqs, stats = run("smollm-135m", smoke=True, n_requests=8, max_new=16,
                      max_slots=4, cache_len=96)
    print(f"prefills={stats.prefills} decode_steps={stats.decode_steps} "
          f"tokens={stats.emitted_tokens}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[:6]={r.prompt[:6].tolist()} → "
              f"out={r.out_tokens}")


if __name__ == "__main__":
    main()
