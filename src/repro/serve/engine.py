"""Unified serving-engine protocol: admit / flush / retire / stats.

JetStream structures its serving stack around a small engine API that an
outer scheduling loop drives (``prefill`` / ``generate`` / ``insert`` over
shape-static device programs); vLLM's continuous batching is the same idea
with slots. This module distils the discipline both of this repo's serving
paths share into one :class:`ClusterEngine` protocol so the token path
(:class:`repro.serve.batching.ContinuousBatcher`) and the clustering path
(:class:`repro.serve.cluster_batcher.ClusterBatcher`) stop duplicating
queue/retire bookkeeping and can be driven by the same outer loop:

* ``admit(request)`` — hand one request to the engine. The engine may run
  device work immediately (a bucket filled, a slot freed) and returns any
  requests that *retired* as a direct consequence; otherwise ``[]``.
  Engines with admission control may refuse instead: ``ClusterBatcher``
  raises ``AdmissionRejected`` (and counts ``stats.rejected``) while its
  ``max_in_flight`` backpressure bound is hit — the caller sheds load or
  retries after the next retire, rather than queueing unboundedly.
* ``flush()`` — force pending work through the device: drain partially
  filled buckets / decode remaining slots. Returns the retired requests.
  Engines with a deadline policy also expose ``poll(now)`` to flush only
  what has waited past its budget.
* ``retire()`` — drain the finished-request queue without running device
  work (requests completed by earlier ``admit``/``flush`` calls that the
  caller has not collected yet).
* ``pending()`` — number of admitted-but-unfinished requests.
* ``stats`` — an :class:`EngineStats` (or subclass) attribute with at
  least ``submitted``/``retired`` counters.

The protocol is structural (``typing.Protocol``): anything with these
members can be scheduled, no inheritance required. ``serve_all`` is the
reference outer loop — admit a stream, poll deadlines, drain at the end.
"""

from __future__ import annotations

import copy
import dataclasses
import time
from typing import Any, Iterable, List, Protocol, runtime_checkable


class AdmissionRejected(RuntimeError):
    """Raised by ``admit`` when an engine's admission policy refuses a
    request (e.g. the in-flight window is full) — the caller sheds load or
    retries after the next retire."""


@dataclasses.dataclass
class EngineStats:
    """Counters every serving engine keeps; subclasses add path-specific
    fields (padding accounting, decode-step counts, ...). ``policy`` names
    the scheduling policy driving the engine's flush/admission decisions —
    part of the protocol's stats surface so outer loops and benchmarks can
    report which scheduler produced the numbers. ``cache_hits`` counts
    requests retired straight from a content-addressed result cache
    without device work (engines without one leave it 0)."""

    submitted: int = 0
    retired: int = 0
    policy: str = ""
    cache_hits: int = 0

    def snapshot(self) -> "EngineStats":
        """Deep copy for delta accounting against a long-lived engine.

        ``dataclasses.replace(stats)`` is a *shallow* copy: mutable nested
        fields (flush-latency telemetry, live result-cache counters) alias
        the live object, so a delta computed from the "snapshot" later
        reads the current value and comes out zero. Callers that report
        per-call deltas (streaming dedup over a reused batcher) must
        snapshot through this instead.
        """
        return copy.deepcopy(self)


@runtime_checkable
class ClusterEngine(Protocol):
    """Structural protocol for slot/bucket serving engines (see module doc)."""

    stats: Any

    def admit(self, request: Any) -> List[Any]:
        """Admit one request; returns requests retired as a side effect."""
        ...

    def flush(self) -> List[Any]:
        """Force all pending work through the device; returns retired."""
        ...

    def retire(self) -> List[Any]:
        """Drain already-finished requests without running device work."""
        ...

    def pending(self) -> int:
        """Admitted-but-unfinished request count."""
        ...


def serve_all(engine: ClusterEngine, requests: Iterable[Any],
              reject_backoff: float = 0.0005,
              max_stalled_rounds: int = 100_000) -> List[Any]:
    """Reference outer loop: admit a request stream, then drain the engine.

    Engines with a deadline policy are polled after every admit (so a
    ``max_wait`` budget is honoured mid-stream, not only at end of stream)
    — this is what lets the driver exercise deadline/adaptive scheduling
    policies instead of only full-bucket flushes. Engines with admission
    control are retried: on :class:`AdmissionRejected` the loop harvests
    finished work (``retire`` + ``poll``) and re-admits, backing off
    ``reject_backoff`` seconds only when no progress was made — a stand-in
    for a front-end that would 429/shed instead. Time is always the
    *engine's own* clock — inject a virtual clock into the engine
    (``ClusterBatcher(clock=...)``) for simulations; a second clock here
    could disagree with the ``admitted_at`` stamps and silently disable
    the deadline. The backoff follows the same rule: when the engine
    carries an injected clock with an ``advance`` method (a
    ``VirtualClock``), the loop advances *that* clock by
    ``reject_backoff`` instead of sleeping — wall-clock sleep does not
    move virtual time, so under a virtual clock a rejection loop would
    otherwise spin forever with the deadline frozen. ``max_stalled_rounds``
    consecutive no-progress rejections raise ``RuntimeError`` (loudly)
    rather than spinning unbounded — that many fruitless retries means a
    stalled flush or a policy that can never admit, on any clock. Returns
    every retired request, in retirement order — each request exactly
    once.
    """
    retired: List[Any] = []
    poll = getattr(engine, "poll", None)
    clock = getattr(engine, "clock", None)
    advance = getattr(clock, "advance", None) \
        if clock is not None and clock is not time.monotonic else None
    for req in requests:
        stalled = 0
        while True:
            try:
                retired.extend(engine.admit(req))
                break
            except AdmissionRejected:
                progressed = engine.retire()
                if poll is not None:
                    progressed.extend(poll())
                retired.extend(progressed)
                if progressed:
                    stalled = 0
                    continue
                stalled += 1
                if stalled >= max_stalled_rounds:
                    pending = getattr(engine, "pending", lambda: "?")()
                    raise RuntimeError(
                        f"serve_all made no progress across {stalled} "
                        f"consecutive rejected admissions ({pending} "
                        "requests pending) — a flush is stalled or the "
                        "admission policy can never open")
                if reject_backoff:
                    if advance is not None:
                        advance(reject_backoff)   # engine time, not wall time
                    else:
                        time.sleep(reject_backoff)  # let in-flight work finish
        if poll is not None:
            retired.extend(poll())
    retired.extend(engine.flush())
    retired.extend(engine.retire())
    return retired


__all__ = ["AdmissionRejected", "EngineStats", "ClusterEngine", "serve_all"]
