"""Clustering-as-a-service demo: streaming graphs through ClusterBatcher.

Simulates the north-star serving workload — a stream of small similarity
graphs (per-band near-dup buckets) arriving one at a time. The batcher
admits each graph into its ``(R, W)`` shape bucket, flushes a bucket the
moment it fills, and drains the stragglers at end of stream. Every result
is bit-identical to running ``correlation_cluster`` on that graph alone.

Run:  PYTHONPATH=src python examples/batch_serving.py
"""

import time

import jax
import numpy as np

from repro.core import build_graph
from repro.core.graph import random_arboric
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest


def main():
    rng = np.random.default_rng(42)
    batcher = ClusterBatcher(max_batch=16, num_samples=2)

    print("streaming 100 clustering queries (max_batch=16)...")
    t0 = time.perf_counter()
    retired = 0
    for uid in range(100):
        n = int(rng.integers(8, 64))
        edges, _ = random_arboric(n, int(rng.integers(1, 4)), rng)
        req = ClusterRequest(uid=uid, graph=build_graph(n, edges),
                             key=jax.random.PRNGKey(uid))
        done = batcher.submit(req)
        for r in done:
            retired += 1
            if retired % 25 == 0:
                print(f"  uid={r.uid:3d} n={r.graph.n:3d} "
                      f"clusters={len(np.unique(r.result.labels)):3d} "
                      f"cost={r.result.cost:4d} "
                      f"bucket={r.result.info['bucket']}")
    for r in batcher.flush_all():
        retired += 1
    dt = time.perf_counter() - t0

    s = batcher.stats
    print(f"\nserved {retired} queries in {dt:.2f}s "
          f"({retired / dt:.1f} graphs/s)")
    print(f"flushes={s.flushes}  buckets_seen={s.buckets_seen}  "
          f"padded_slots={s.padded_slots}  "
          f"pad_vertex_waste={s.pad_vertex_waste}")


if __name__ == "__main__":
    main()
