"""Near-duplicate detection via MinHash + the paper's correlation clustering.

This is where the paper's algorithm is a first-class framework feature: the
data pipeline builds a sparse similarity graph over documents (positive
edge ⇔ sketch similarity ≥ τ) and runs **Algorithm 4** (degree-cap +
PIVOT, Corollary 28) to produce a 3-approximate minimum-disagreement
clustering; one representative per cluster survives into the training
stream.

Why correlation clustering and not naive connected components: CC chains
drift (A≈B≈C≈…≈Z merges unrelated Z with A); minimizing disagreements
penalizes both false merges (negative intra-pairs) and false splits
(positive cut edges), and the bounded-arboricity machinery makes it cheap —
similarity graphs of near-dedup workloads are sparse and scale-free, the
paper's own motivating regime (§1).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core import build_graph, correlation_cluster
from repro.core.api import ClusterResult
from .synthetic import Corpus

_MERSENNE = (1 << 61) - 1


def minhash_signatures(docs, num_hashes: int = 64, shingle: int = 4,
                       seed: int = 0) -> np.ndarray:
    """(n_docs, num_hashes) MinHash over token shingles."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, num_hashes, dtype=np.int64)
    b = rng.integers(0, _MERSENNE, num_hashes, dtype=np.int64)
    sigs = np.full((len(docs), num_hashes), np.iinfo(np.int64).max,
                   dtype=np.int64)
    for i, doc in enumerate(docs):
        if len(doc) < shingle:
            sh = np.array([hash(tuple(doc.tolist()))], dtype=np.int64)
        else:
            win = np.lib.stride_tricks.sliding_window_view(
                np.asarray(doc, np.int64), shingle)
            sh = (win * np.array([1, 1_000_003, 998_244_353, 911_382_323]
                                 [:shingle], np.int64)).sum(1)
        sh = np.unique(sh) % _MERSENNE
        vals = (sh[:, None] * a[None, :] + b[None, :]) % _MERSENNE
        sigs[i] = vals.min(axis=0)
    return sigs


def similarity_edges(sigs: np.ndarray, threshold: float = 0.5,
                     bands: int = 16) -> np.ndarray:
    """LSH banding → candidate pairs → exact signature similarity filter.

    Returns the positive edge list (m, 2). Banding keeps candidate
    generation near-linear (the MPC-friendly part); the final filter makes
    edges ⇔ estimated Jaccard ≥ threshold.
    """
    n, h = sigs.shape
    rows = h // bands
    buckets: dict = {}
    for band in range(bands):
        chunk = sigs[:, band * rows:(band + 1) * rows]
        for i in range(n):
            key = (band, chunk[i].tobytes())
            buckets.setdefault(key, []).append(i)
    cand = set()
    for members in buckets.values():
        if len(members) < 2:
            continue
        for ai in range(len(members)):
            for bi in range(ai + 1, len(members)):
                cand.add((members[ai], members[bi]))
    edges = []
    for u, v in cand:
        sim = float(np.mean(sigs[u] == sigs[v]))
        if sim >= threshold:
            edges.append((u, v))
    return np.asarray(sorted(edges), dtype=np.int64).reshape(-1, 2)


@dataclasses.dataclass
class DedupResult:
    keep: np.ndarray            # bool mask of representatives
    labels: np.ndarray          # cluster per doc
    clustering: ClusterResult
    n_edges: int


def dedup_corpus(corpus: Corpus, threshold: float = 0.5,
                 num_hashes: int = 64, eps: float = 2.0,
                 method: str = "pivot", distributed: bool = False,
                 seed: int = 0, num_samples: int = 4) -> DedupResult:
    """MinHash → similarity graph → Theorem 26 + PIVOT → representatives.

    ``num_samples``: best-of-k PIVOT (keep the lowest-disagreement draw).
    PIVOT is 3-approx in expectation; a single unlucky permutation can split
    true duplicate groups, so the pipeline takes the min over a few cheap
    independent draws.
    """
    sigs = minhash_signatures(corpus.docs, num_hashes=num_hashes, seed=seed)
    edges = similarity_edges(sigs, threshold=threshold)
    n = len(corpus.docs)
    g = build_graph(n, edges)
    res = correlation_cluster(g, method=method, eps=eps,
                              key=jax.random.PRNGKey(seed),
                              distributed=distributed,
                              num_samples=num_samples)
    labels = res.labels
    keep = np.zeros(n, dtype=bool)
    seen = set()
    for i in range(n):
        if labels[i] not in seen:
            seen.add(labels[i])
            keep[i] = True
    return DedupResult(keep=keep, labels=labels, clustering=res,
                       n_edges=g.m)


# ---------------------------------------------------------------------------
# Batched sharded dedup: per-band/per-component subgraphs → batch engine.
# ---------------------------------------------------------------------------


def shard_similarity_graph(n: int, edges: np.ndarray
                           ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Split the similarity graph into independent clustering subproblems.

    The LSH bands only generate candidate pairs inside shared buckets, so
    the verified similarity graph decomposes into many small connected
    components (near-dup groups rarely chain far). Each component is an
    independent correlation-clustering instance: PIVOT never merges
    vertices from different positive components, so clustering the shards
    and stitching labels is exact, and the shards are precisely the small
    same-shaped graphs the batch engine buckets together.

    Returns ``[(global_ids, local_edges), ...]`` for every component with at
    least one edge; isolated vertices stay singleton clusters implicitly.
    """
    parent = np.arange(n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:            # path compression
            parent[x], x = root, parent[x]
        return root

    for u, v in edges:
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[ru] = rv

    comp_edges: dict = {}
    for u, v in edges:
        comp_edges.setdefault(find(int(u)), []).append((int(u), int(v)))

    shards: List[Tuple[np.ndarray, np.ndarray]] = []
    for root, es in sorted(comp_edges.items()):
        es = np.asarray(es, dtype=np.int64)
        ids = np.unique(es)
        remap = {int(v): i for i, v in enumerate(ids)}
        local = np.array([[remap[int(u)], remap[int(v)]] for u, v in es],
                         dtype=np.int64)
        shards.append((ids, local))
    return shards


def dedup_corpus_batched(corpus: Corpus, threshold: float = 0.5,
                         num_hashes: int = 64, eps: float = 2.0,
                         seed: int = 0, num_samples: int = 4,
                         use_kernel: bool = False) -> DedupResult:
    """Sharded dedup through the batched multi-graph PIVOT engine.

    Same contract as :func:`dedup_corpus`, but the similarity graph is
    sharded into per-component subgraphs (see :func:`shard_similarity_graph`)
    that are clustered together through ``correlation_cluster_batch`` — the
    production path when the corpus yields millions of small near-dup
    groups rather than one giant graph.
    """
    from repro.core import correlation_cluster_batch

    sigs = minhash_signatures(corpus.docs, num_hashes=num_hashes, seed=seed)
    edges = similarity_edges(sigs, threshold=threshold)
    n = len(corpus.docs)
    shards = shard_similarity_graph(n, edges)

    labels = np.arange(n, dtype=np.int32)   # isolated docs: singletons
    total_cost = 0
    buckets: set = set()
    if shards:
        graphs = [build_graph(len(ids), local) for ids, local in shards]
        keys = [jax.random.fold_in(jax.random.PRNGKey(seed), i)
                for i in range(len(shards))]
        results = correlation_cluster_batch(graphs, keys=keys, eps=eps,
                                            num_samples=num_samples,
                                            use_kernel=use_kernel)
        for (ids, _), res in zip(shards, results):
            labels[ids] = ids[res.labels]   # lift local pivots to doc ids
            total_cost += res.cost
            buckets.add(res.info["bucket"])

    keep = np.zeros(n, dtype=bool)
    seen = set()
    for i in range(n):
        if labels[i] not in seen:
            seen.add(labels[i])
            keep[i] = True
    clustering = ClusterResult(
        labels=labels, cost=total_cost, method="pivot_batch",
        info={"n_shards": len(shards), "n_buckets": len(buckets),
              "buckets": sorted(buckets), "num_samples": num_samples})
    return DedupResult(keep=keep, labels=labels, clustering=clustering,
                       n_edges=len(edges))


def dedup_corpus_streaming(corpus: Corpus, threshold: float = 0.5,
                           num_hashes: int = 64, eps: float = 2.0,
                           seed: int = 0, num_samples: int = 4,
                           use_kernel: bool = False, max_batch: int = 32,
                           max_wait: Optional[float] = None,
                           batcher=None) -> DedupResult:
    """Streaming dedup: feed similarity-graph shards through the serving
    engine incrementally instead of one monolithic batch call.

    Same contract (and bit-identical labels/cost) as
    :func:`dedup_corpus_batched` — per-shard PRNG keys are a function of the
    shard index only, so how shards are grouped into flushes cannot change
    any result. What changes is the *execution discipline*: shards are
    admitted one at a time into a
    :class:`repro.serve.cluster_batcher.ClusterBatcher` (full-bucket
    flushes, plus ``max_wait`` deadline flushes when set) and labels are
    stitched as requests retire — the shape a production pipeline takes
    when near-dup groups arrive as a stream rather than a corpus snapshot.

    Pass ``batcher`` to reuse a long-lived engine (and its compiled bucket
    programs and buffer pool) across corpora.
    """
    from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest

    sigs = minhash_signatures(corpus.docs, num_hashes=num_hashes, seed=seed)
    edges = similarity_edges(sigs, threshold=threshold)
    n = len(corpus.docs)
    shards = shard_similarity_graph(n, edges)

    if batcher is None:
        batcher = ClusterBatcher(max_batch=max_batch, eps=eps,
                                 num_samples=num_samples,
                                 use_kernel=use_kernel, max_wait=max_wait)
    else:
        # A reused engine must actually implement the parameters this call
        # promises — a mismatch would silently break the bit-identical
        # contract with dedup_corpus_batched.
        want = dict(num_samples=max(1, num_samples), eps=eps,
                    use_kernel=use_kernel, method="pivot")
        got = dict(num_samples=batcher.num_samples, eps=batcher.eps,
                   use_kernel=batcher.use_kernel, method=batcher.method)
        if got != want:
            raise ValueError(
                f"reused batcher config {got} does not match the requested "
                f"dedup parameters {want}")
    # Delta baseline vs engine lifetime. Must be a *deep* snapshot:
    # dataclasses.replace copies shallowly, so the mutable nested fields
    # (latency telemetry, live result-cache counters) would alias the live
    # stats object and every delta computed from them would read 0.
    stats0 = batcher.stats.snapshot()

    labels = np.arange(n, dtype=np.int32)   # isolated docs: singletons
    total_cost = 0
    buckets: set = set()
    shard_ids = {i: ids for i, (ids, _) in enumerate(shards)}

    def stitch(retired) -> None:
        nonlocal total_cost
        for req in retired:
            ids = shard_ids[req.uid]
            labels[ids] = ids[req.result.labels]   # lift local pivots
            total_cost += req.result.cost
            buckets.add(req.result.info["bucket"])

    for i, (ids, local) in enumerate(shards):
        req = ClusterRequest(uid=i, graph=build_graph(len(ids), local),
                             key=jax.random.fold_in(jax.random.PRNGKey(seed),
                                                    i))
        stitch(batcher.admit(req))
        stitch(batcher.poll())
    stitch(batcher.flush())

    keep = np.zeros(n, dtype=bool)
    seen = set()
    for i in range(n):
        if labels[i] not in seen:
            seen.add(labels[i])
            keep[i] = True
    stats1 = batcher.stats
    info = {"n_shards": len(shards), "n_buckets": len(buckets),
            "buckets": sorted(buckets), "num_samples": num_samples,
            # deltas, so a long-lived reused batcher reports this call's
            # serving work rather than its lifetime totals
            "flushes": stats1.flushes - stats0.flushes,
            "deadline_flushes": (stats1.deadline_flushes
                                 - stats0.deadline_flushes),
            "padded_slots": stats1.padded_slots - stats0.padded_slots,
            # nested-telemetry delta — reads 0 under a shallow snapshot
            "flush_samples": (stats1.latency.total_flushes
                              - stats0.latency.total_flushes),
            # repeat shards (same content, same fold_in key) served from
            # the result cache — nonzero when a reused batcher sees the
            # same corpus again
            "cache_hits": stats1.cache_hits - stats0.cache_hits}
    clustering = ClusterResult(
        labels=labels, cost=total_cost, method="pivot_stream", info=info)
    return DedupResult(keep=keep, labels=labels, clustering=clustering,
                       n_edges=len(edges))


def dedup_quality(result: DedupResult, corpus: Corpus) -> dict:
    """Planted-cluster recall/precision of the dedup decisions."""
    dup_of = corpus.duplicate_of
    n = len(dup_of)
    # ground-truth cluster id = source doc (or self)
    gt = np.where(dup_of >= 0, dup_of, np.arange(n))
    tp = fp = fn = 0
    labels = result.labels
    for i in range(n):
        for j in range(i + 1, n):
            same_gt = gt[i] == gt[j]
            same_pred = labels[i] == labels[j]
            tp += same_gt and same_pred
            fp += (not same_gt) and same_pred
            fn += same_gt and (not same_pred)
    prec = tp / max(1, tp + fp)
    rec = tp / max(1, tp + fn)
    return {
        "pairs_precision": prec,
        "pairs_recall": rec,
        "kept_fraction": float(result.keep.mean()),
        "clusters": int(len(np.unique(labels))),
        "cost": result.clustering.cost,
    }


__all__ = ["minhash_signatures", "similarity_edges", "DedupResult",
           "dedup_corpus", "dedup_corpus_batched", "dedup_corpus_streaming",
           "shard_similarity_graph", "dedup_quality"]
