"""Architecture & shape configuration system.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``) registered under its public id; ``--arch``
selects it by name. ``smoke()`` on each module returns a reduced config of
the same family for CPU tests. Shapes are global (:data:`SHAPES`) with
per-arch applicability (see :func:`supports_shape`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim (olmoe: 1024)

    # SSM / hybrid (zamba2-style)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0               # shared attn block applied every k layers

    # RWKV6
    rwkv: bool = False
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # Encoder–decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0              # stub frontend output length (frames)

    # VLM (llama-3.2-vision)
    cross_attn_every: int = 0         # every k-th layer is gated cross-attn
    num_image_tokens: int = 0         # stub patch-embedding length

    # Numerics / scale policy
    vocab_round: int = 256            # pad vocab so TP divides it

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_round)

    @property
    def attention_free(self) -> bool:
        return self.rwkv

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM/hybrid/linear-attn)."""
        return self.rwkv or self.ssm_state > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = v * d                       # embedding
        if not self.tie_embeddings:
            total += v * d                  # lm head

        def attn_params():
            return d * n_q + 2 * d * n_kv + n_q * d + (
                2 * hd if self.qk_norm else 0)

        def dense_mlp(ff):
            return 3 * d * ff               # SwiGLU: wi, wg, wo

        blocks = 0
        if self.family == "encdec":
            enc = self.encoder_layers * (attn_params() + dense_mlp(f) + 2 * d)
            dec = self.num_layers * (2 * attn_params() + dense_mlp(f) + 3 * d)
            blocks = enc + dec
        elif self.family == "moe":
            ff = self.moe_d_ff or f
            per = attn_params() + d * self.num_experts + (
                self.num_experts * 3 * d * ff) + 2 * d
            blocks = self.num_layers * per
        elif self.family == "ssm":            # rwkv6
            per_tm = d * d * 4 + d * self.rwkv_decay_lora * 2 + 4 * d
            per_cm = 2 * d * f + d * f * 0 + d * d  # k,v(r) proj
            blocks = self.num_layers * (per_tm + per_cm + 2 * d)
        elif self.family == "hybrid":         # zamba2
            d_in = self.ssm_expand * d
            heads = d_in // self.ssm_head_dim
            per_mamba = d * (2 * d_in + 2 * self.ssm_state + heads) + (
                d_in * d) + heads + d_in * 4 + 2 * d
            shared_attn = attn_params() + dense_mlp(f) + 2 * d
            n_attn = self.num_layers // max(1, self.attn_every)
            blocks = self.num_layers * per_mamba + shared_attn  # weights shared
            blocks += n_attn * 0
        else:                                  # dense / vlm
            per = attn_params() + dense_mlp(f) + 2 * d
            blocks = self.num_layers * per
            if self.cross_attn_every:
                n_cross = self.num_layers // self.cross_attn_every
                blocks += n_cross * (attn_params() + 2 * d + 1)
        return total + blocks


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (see DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch — 512k dense decode is "
                       "quadratic; skipped per assignment")
    return True, ""
