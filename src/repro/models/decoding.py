"""Prefill and single-token decode for every architecture family.

Caches are Pm trees (array + PartitionSpec) mirroring the scanned parameter
stacks that consume them:

  dense/moe — k/v (L, B, S, KH, hd)
  vlm       — self k/v (G, k−1, B, S, KH, hd) + cross k/v (G, B, Timg, KH, hd)
  hybrid    — Mamba conv/ssm states (G, k, …) + shared-attn k/v (G, B, S, …)
  ssm       — RWKV token-shift carries + wkv state (L, …)
  encdec    — decoder self k/v (L, B, S, …) + cross k/v (L, B, Tenc, …)

KV caches carry the plan's ``seq_kv`` sharding — on the decode shapes that
is the 'model' axis (plus the freed 'data' axes for long_500k), which is
what makes a 1.7 TB 32k×128 cache of the 90B model fit (≈6.6 GB/chip) and
turns the softmax reduction into the flash-decoding LSE-combine collective
in the lowered HLO.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .attention import attention, decode_attention
from .common import Pm, constrain, rms_norm
from .mlp import mlp, moe
from .sharding import ShardingPlan
from .transformer import RunConfig, encode, lm_head


def _kv(plan, shape, dtype=jnp.bfloat16):
    """KV cache leaf with conflict-free (batch, seq, kv-head) sharding.

    A NamedSharding may use each mesh axis once; when both ``kv`` heads and
    the ``seq_kv`` dim want 'model' (e.g. olmoe's 16 kv heads), the head dim
    wins and the overlapping axis is dropped from the sequence shard.
    """
    def _axes(v):
        return () if v is None else ((v,) if isinstance(v, str) else tuple(v))

    batch_ax = plan.axes.get("batch")
    kv_ax = plan.axes.get("kv")
    used = set(_axes(batch_ax)) | set(_axes(kv_ax))
    seq = tuple(a for a in _axes(plan.axes.get("seq_kv")) if a not in used)
    seq_ax = seq if len(seq) > 1 else (seq[0] if seq else None)
    from jax.sharding import PartitionSpec as P
    spec = P(*([None] * (len(shape) - 4)), batch_ax, seq_ax, kv_ax, None)
    return Pm(jnp.zeros(shape, dtype), spec)


def init_caches(cfg: ModelConfig, batch: int, seq_len: int,
                plan: ShardingPlan | None = None, dtype=jnp.bfloat16):
    """Pm tree of empty caches sized for ``seq_len`` decode."""
    plan = plan or ShardingPlan.null()
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    c: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe"):
        shp = (cfg.num_layers, batch, seq_len, kh, hd)
        c["k"], c["v"] = _kv(plan, shp, dtype), _kv(plan, shp, dtype)
    elif cfg.family == "vlm":
        g = cfg.num_layers // cfg.cross_attn_every
        shp = (g, cfg.cross_attn_every - 1, batch, seq_len, kh, hd)
        c["k"], c["v"] = _kv(plan, shp, dtype), _kv(plan, shp, dtype)
        xshp = (g, batch, cfg.num_image_tokens, kh, hd)
        c["xk"] = Pm(jnp.zeros(xshp, dtype),
                     plan.P(None, "batch", None, "kv", None))
        c["xv"] = Pm(jnp.zeros(xshp, dtype),
                     plan.P(None, "batch", None, "kv", None))
    elif cfg.family == "hybrid":
        g = cfg.num_layers // cfg.attn_every
        k = cfg.attn_every
        d_in, h, n = ssm_mod.ssm_dims(cfg)
        c["conv"] = Pm(
            jnp.zeros((g, k, batch, ssm_mod.CONV_K - 1, d_in), dtype),
            plan.P(None, None, "batch", None, "ff"))
        c["ssm"] = Pm(
            jnp.zeros((g, k, batch, h, n, cfg.ssm_head_dim), jnp.float32),
            plan.P(None, None, "batch", None, None, None))
        shp = (g, batch, seq_len, kh, hd)
        c["ak"], c["av"] = _kv(plan, shp, dtype), _kv(plan, shp, dtype)
    elif cfg.family == "ssm":
        h, n = rwkv_mod.rwkv_dims(cfg)
        lyr = cfg.num_layers
        c["tm_prev"] = Pm(jnp.zeros((lyr, batch, 1, cfg.d_model), dtype),
                          plan.P(None, "batch", None, None))
        c["cm_prev"] = Pm(jnp.zeros((lyr, batch, 1, cfg.d_model), dtype),
                          plan.P(None, "batch", None, None))
        c["state"] = Pm(jnp.zeros((lyr, batch, h, n, n), jnp.float32),
                        plan.P(None, "batch", None, None, None))
    elif cfg.family == "encdec":
        shp = (cfg.num_layers, batch, seq_len, kh, hd)
        c["k"], c["v"] = _kv(plan, shp, dtype), _kv(plan, shp, dtype)
        xshp = (cfg.num_layers, batch, cfg.encoder_seq, kh, hd)
        c["xk"] = Pm(jnp.zeros(xshp, dtype),
                     plan.P(None, "batch", None, "kv", None))
        c["xv"] = Pm(jnp.zeros(xshp, dtype),
                     plan.P(None, "batch", None, "kv", None))
    else:
        raise ValueError(cfg.family)
    return c


# ---------------------------------------------------------------------------
# Prefill: full forward that also emits caches (padded to cache_len).
# ---------------------------------------------------------------------------


def _pad_seq(kv, cache_len):
    b, s, kh, hd = kv.shape
    if s == cache_len:
        return kv
    return jnp.pad(kv, ((0, 0), (0, cache_len - s), (0, 0), (0, 0)))


def prefill(params, cfg: ModelConfig, plan, rc: RunConfig, batch,
            cache_len: int | None = None, cache_dtype=jnp.bfloat16):
    """Run the prompt; return (last-token logits (B, Vpad), caches)."""
    plan = plan or ShardingPlan.null()
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, plan, "batch", None, None)
    positions = jnp.arange(s, dtype=jnp.int32)
    caches: Dict[str, Any] = {}

    def attn_with_kv(p, x_, causal=True):
        z = rms_norm(x_, p["ln1"], cfg.norm_eps)
        out = attention(p["attn"], cfg, plan, z, positions, causal=causal,
                        impl=rc.attn_impl, return_kv=True)
        return out

    if cfg.family in ("dense", "moe"):
        def body(x_, p):
            out = attn_with_kv(p, x_)
            x_ = x_ + out.out
            z = rms_norm(x_, p["ln2"], cfg.norm_eps)
            if cfg.num_experts:
                x_ = x_ + moe(p["moe"], z, cfg, impl=rc.moe_impl,
                              capacity_factor=rc.moe_capacity,
                              token_chunk=rc.moe_token_chunk, plan=plan,
                              mesh=rc.mesh)
            else:
                x_ = x_ + mlp(p["mlp"], z)
            x_ = constrain(x_, plan, "batch", None, None)
            kv = (_pad_seq(out.k.astype(cache_dtype), cache_len),
                  _pad_seq(out.v.astype(cache_dtype), cache_len))
            return x_, kv

        def f(carry, p):
            return body(carry, p)
        x, (ks, vs) = jax.lax.scan(f, x, params["blocks"])
        caches["k"], caches["v"] = ks, vs

    elif cfg.family == "ssm":
        h, n = rwkv_mod.rwkv_dims(cfg)
        zero_prev = jnp.zeros((b, 1, cfg.d_model), x.dtype)
        zero_state = jnp.zeros((b, h, n, n), jnp.float32)

        def f(carry, p):
            z = rms_norm(carry, p["ln1"], cfg.norm_eps)
            o, tm_c, st = rwkv_mod.rwkv_time_mix(p["tm"], cfg, z, zero_prev,
                                                 zero_state, impl=rc.rwkv_impl)
            carry = carry + o
            z = rms_norm(carry, p["ln2"], cfg.norm_eps)
            o, cm_c = rwkv_mod.rwkv_channel_mix(p["cm"], cfg, z, zero_prev)
            carry = carry + o
            carry = constrain(carry, plan, "batch", None, None)
            return carry, (tm_c.astype(cache_dtype),
                           cm_c.astype(cache_dtype), st)

        x, (tms, cms, sts) = jax.lax.scan(f, x, params["blocks"])
        caches.update(tm_prev=tms, cm_prev=cms, state=sts)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(x_, p):
            def mamba_body(x2, p2):
                z = rms_norm(x2, p2["ln"], cfg.norm_eps)
                o, mc = ssm_mod.mamba_block(p2["mamba"], cfg, z,
                                            chunk=rc.ssd_chunk)
                return x2 + o, (mc.conv.astype(cache_dtype), mc.ssm)
            x_, (convs, ssms) = jax.lax.scan(mamba_body, x_, p)
            out = attn_with_kv(shared, x_)
            x_ = x_ + out.out
            x_ = x_ + mlp(shared["mlp"], rms_norm(x_, shared["ln2"],
                                                  cfg.norm_eps))
            x_ = constrain(x_, plan, "batch", None, None)
            return x_, (convs, ssms,
                        _pad_seq(out.k.astype(cache_dtype), cache_len),
                        _pad_seq(out.v.astype(cache_dtype), cache_len))

        x, (convs, ssms, aks, avs) = jax.lax.scan(group, x,
                                                  params["mamba_groups"])
        caches.update(conv=convs, ssm=ssms, ak=aks, av=avs)

    elif cfg.family == "vlm":
        img = batch["image_embeds"].astype(x.dtype)

        def group(x_, p):
            def self_body(x2, p2):
                out = attn_with_kv(p2, x2)
                x2 = x2 + out.out
                x2 = x2 + mlp(p2["mlp"], rms_norm(x2, p2["ln2"], cfg.norm_eps))
                x2 = constrain(x2, plan, "batch", None, None)
                return x2, (_pad_seq(out.k.astype(cache_dtype), cache_len),
                            _pad_seq(out.v.astype(cache_dtype), cache_len))
            x_, (ks, vs) = jax.lax.scan(self_body, x_, p["self"])
            pc = p["cross"]
            z = rms_norm(x_, pc["ln1"], cfg.norm_eps)
            out = attention(pc["xattn"], cfg, plan, z, None, kv_x=img,
                            causal=False, impl=rc.attn_impl, return_kv=True)
            x_ = x_ + out.out
            x_ = x_ + mlp(pc["mlp"], rms_norm(x_, pc["ln2"], cfg.norm_eps))
            x_ = constrain(x_, plan, "batch", None, None)
            return x_, (ks, vs, out.k.astype(cache_dtype),
                        out.v.astype(cache_dtype))

        stacked = {"self": params["self_groups"], "cross": params["cross_layers"]}
        x, (ks, vs, xks, xvs) = jax.lax.scan(group, x, stacked)
        caches.update(k=ks, v=vs, xk=xks, xv=xvs)

    elif cfg.family == "encdec":
        enc = encode(params, cfg, plan, rc, batch)

        def f(carry, p):
            out = attn_with_kv(p, carry)
            carry = carry + out.out
            z = rms_norm(carry, p["ln_x"], cfg.norm_eps)
            xout = attention(p["xattn"], cfg, plan, z, None, kv_x=enc,
                             causal=False, impl=rc.attn_impl, return_kv=True)
            carry = carry + xout.out
            carry = carry + mlp(p["mlp"], rms_norm(carry, p["ln2"],
                                                   cfg.norm_eps))
            carry = constrain(carry, plan, "batch", None, None)
            return carry, (_pad_seq(out.k.astype(cache_dtype), cache_len),
                           _pad_seq(out.v.astype(cache_dtype), cache_len),
                           xout.k.astype(cache_dtype),
                           xout.v.astype(cache_dtype))

        x, (ks, vs, xks, xvs) = jax.lax.scan(f, x, params["blocks"])
        caches.update(k=ks, v=vs, xk=xks, xv=xvs)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    last = x[:, -1:]
    logits = jax.lax.dot_general(
        last.astype(jnp.float32), lm_head(params, cfg).astype(jnp.float32),
        (((2,), (0,)), ((), ())))[:, 0]
    return logits, caches


# ---------------------------------------------------------------------------
# Decode: one token through all layers, updating caches.
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ModelConfig, plan, rc: RunConfig, token, caches,
                pos):
    """token (B,) int32; pos scalar int32. Returns (logits (B, Vpad), caches).

    KV-cache stacks are threaded through the layer scan as *carry* and
    updated with ``dynamic_update_slice`` at the layer index — XLA aliases
    while-loop carries in place, so the (multi-GB) caches are not double-
    buffered the way a scan ys-output would be (observed 2× cache temp on
    the 90B 32k cell before this layout).
    """
    plan = plan or ShardingPlan.null()
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)  # (B,1,D)
    new: Dict[str, Any] = {}

    def self_decode(p, x_, ck, cv):
        z = rms_norm(x_, p["ln1"], cfg.norm_eps)
        out = decode_attention(p["attn"], cfg, plan, z, pos, ck, cv)
        return x_ + out.out, out.k, out.v

    def idx(stack, l):
        return jax.lax.dynamic_index_in_dim(stack, l, 0, keepdims=False)

    def upd(stack, sl, l):
        return jax.lax.dynamic_update_index_in_dim(
            stack, sl.astype(stack.dtype), l, 0)

    if cfg.family in ("dense", "moe"):
        nl = cfg.num_layers

        def f(carry, inp):
            x_, ck_all, cv_all = carry
            p, l = inp
            x_, nk, nv = self_decode(p, x_, idx(ck_all, l), idx(cv_all, l))
            z = rms_norm(x_, p["ln2"], cfg.norm_eps)
            if cfg.num_experts:
                x_ = x_ + moe(p["moe"], z, cfg, impl=rc.moe_impl,
                              capacity_factor=rc.moe_capacity,
                              token_chunk=rc.moe_token_chunk, plan=plan,
                              mesh=rc.mesh)
            else:
                x_ = x_ + mlp(p["mlp"], z)
            return (x_, upd(ck_all, nk, l), upd(cv_all, nv, l)), None

        (x, ks, vs), _ = jax.lax.scan(
            f, (x, caches["k"], caches["v"]),
            (params["blocks"], jnp.arange(nl)))
        new.update(k=ks, v=vs)

    elif cfg.family == "ssm":
        def f(carry, inp):
            p, tm_prev, cm_prev, state = inp
            z = rms_norm(carry, p["ln1"], cfg.norm_eps)
            o, tm_c, st = rwkv_mod.rwkv_time_mix(
                p["tm"], cfg, z, tm_prev.astype(z.dtype), state, impl="scan")
            carry = carry + o
            z = rms_norm(carry, p["ln2"], cfg.norm_eps)
            o, cm_c = rwkv_mod.rwkv_channel_mix(p["cm"], cfg, z,
                                                cm_prev.astype(z.dtype))
            carry = carry + o
            return carry, (tm_c.astype(tm_prev.dtype),
                           cm_c.astype(cm_prev.dtype), st)

        x, (tms, cms, sts) = jax.lax.scan(
            f, x, (params["blocks"], caches["tm_prev"], caches["cm_prev"],
                   caches["state"]))
        new.update(tm_prev=tms, cm_prev=cms, state=sts)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        ng = cfg.num_layers // cfg.attn_every

        def group(carry, inp):
            x_, ak_all, av_all = carry
            p, conv, ssm, g = inp

            def mamba_body(x2, inp2):
                p2, cv_, ss_ = inp2
                z = rms_norm(x2, p2["ln"], cfg.norm_eps)
                o, mc = ssm_mod.mamba_step(
                    p2["mamba"], cfg, z,
                    ssm_mod.MambaCache(conv=cv_.astype(z.dtype), ssm=ss_))
                return x2 + o, (mc.conv.astype(cv_.dtype), mc.ssm)

            x_, (convs, ssms) = jax.lax.scan(mamba_body, x_, (p, conv, ssm))
            z = rms_norm(x_, shared["ln1"], cfg.norm_eps)
            out = decode_attention(shared["attn"], cfg, plan, z, pos,
                                   idx(ak_all, g), idx(av_all, g))
            x_ = x_ + out.out
            x_ = x_ + mlp(shared["mlp"],
                          rms_norm(x_, shared["ln2"], cfg.norm_eps))
            return (x_, upd(ak_all, out.k, g), upd(av_all, out.v, g)), (
                convs, ssms)

        (x, aks, avs), (convs, ssms) = jax.lax.scan(
            group, (x, caches["ak"], caches["av"]),
            (params["mamba_groups"], caches["conv"], caches["ssm"],
             jnp.arange(ng)))
        new.update(conv=convs, ssm=ssms, ak=aks, av=avs)

    elif cfg.family == "vlm":
        ng = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1

        def group(carry, inp):
            x_, ck_all, cv_all = carry
            p, xk, xv, g = inp

            def self_body(carry2, inp2):
                x2, ckg, cvg = carry2      # ckg (n_self, B, S, KH, hd)
                p2, j = inp2
                x2, nk, nv = self_decode(p2, x2, idx(ckg, j), idx(cvg, j))
                x2 = x2 + mlp(p2["mlp"], rms_norm(x2, p2["ln2"],
                                                  cfg.norm_eps))
                return (x2, upd(ckg, nk, j), upd(cvg, nv, j)), None

            (x_, ckg, cvg), _ = jax.lax.scan(
                self_body, (x_, idx(ck_all, g), idx(cv_all, g)),
                (p["self"], jnp.arange(n_self)))
            pc = p["cross"]
            z = rms_norm(x_, pc["ln1"], cfg.norm_eps)
            out = decode_attention(pc["xattn"], cfg, plan, z, pos, xk, xv,
                                   update_cache=False, rope_on_q=False,
                                   mask_to_pos=False)
            x_ = x_ + out.out
            x_ = x_ + mlp(pc["mlp"], rms_norm(x_, pc["ln2"], cfg.norm_eps))
            return (x_, upd(ck_all, ckg, g), upd(cv_all, cvg, g)), None

        stacked = {"self": params["self_groups"],
                   "cross": params["cross_layers"]}
        (x, ks, vs), _ = jax.lax.scan(
            group, (x, caches["k"], caches["v"]),
            (stacked, caches["xk"], caches["xv"], jnp.arange(ng)))
        new.update(k=ks, v=vs, xk=caches["xk"], xv=caches["xv"])

    elif cfg.family == "encdec":
        nl = cfg.num_layers

        def f(carry, inp):
            x_, ck_all, cv_all = carry
            p, xk, xv, l = inp
            x_, nk, nv = self_decode(p, x_, idx(ck_all, l), idx(cv_all, l))
            z = rms_norm(x_, p["ln_x"], cfg.norm_eps)
            out = decode_attention(p["xattn"], cfg, plan, z, pos, xk, xv,
                                   update_cache=False, rope_on_q=False,
                                   mask_to_pos=False)
            x_ = x_ + out.out
            x_ = x_ + mlp(p["mlp"], rms_norm(x_, p["ln2"], cfg.norm_eps))
            return (x_, upd(ck_all, nk, l), upd(cv_all, nv, l)), None

        (x, ks, vs), _ = jax.lax.scan(
            f, (x, caches["k"], caches["v"]),
            (params["blocks"], caches["xk"], caches["xv"], jnp.arange(nl)))
        new.update(k=ks, v=vs, xk=caches["xk"], xv=caches["xv"])
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jax.lax.dot_general(
        x.astype(jnp.float32), lm_head(params, cfg).astype(jnp.float32),
        (((2,), (0,)), ((), ())))[:, 0]
    return logits, new


__all__ = ["init_caches", "prefill", "decode_step"]
