"""grok-1-314b [moe]: 64L, d=6144, 48H (GQA kv=8), ff=32768, vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=32768, moe_d_ff=32768, vocab_size=131072, head_dim=128,
        num_experts=8, experts_per_tok=2, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, moe_d_ff=128, vocab_size=512, head_dim=16,
        num_experts=4, experts_per_tok=2, vocab_round=64,
    )
