"""MLP blocks: SwiGLU dense and Mixture-of-Experts.

MoE ships two dispatch implementations with identical semantics:

* ``moe_impl='einsum'`` — classic one-hot dispatch/combine einsums
  (ParallelPIVOT-era MapReduce style: dense masks of shape (T, E, C)).
  Simple, GSPMD-friendly — but the dispatch matmuls cost O(T·E·C·d) MXU
  FLOPs, which for olmoe (64 experts) *exceeds* the expert FLOPs ~2.7×.
* ``moe_impl='sort'``  — gather/scatter dispatch: assignments are sorted by
  expert, tokens are *gathered* into (E, C, d) expert batches and results
  scatter-added back. Only the expert matmuls hit the MXU; dispatch is
  pure data movement. This is the beyond-paper optimization measured in
  EXPERIMENTS.md §Perf (compute-term drop on the MoE cells).

Both respect capacity ``C = ceil(T/E · k · capacity_factor)`` with dropped
overflow tokens (standard; combine weights renormalized over kept experts).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.compat import shard_map as _shard_map
from repro.configs.base import ModelConfig
from .common import Pm, constrain, dense_init, linear


def init_mlp(cfg: ModelConfig, kg, dtype, plan, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": Pm(dense_init(kg(), (d, f), dtype), plan.P("embed", "ff")),
        "wg": Pm(dense_init(kg(), (d, f), dtype), plan.P("embed", "ff")),
        "wo": Pm(dense_init(kg(), (f, d), dtype), plan.P("ff", "embed")),
    }


def mlp(params, x):
    h = jax.nn.silu(linear(x, params["wg"])) * linear(x, params["wi"])
    return linear(h, params["wo"])


def init_moe(cfg: ModelConfig, kg, dtype, plan):
    d = cfg.d_model
    e = cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    return {
        "router": Pm(dense_init(kg(), (d, e), jnp.float32),
                     plan.P("embed", None)),
        "wi": Pm(dense_init(kg(), (e, d, f), dtype),
                 plan.P("experts", "expert_embed", "expert_ff")),
        "wg": Pm(dense_init(kg(), (e, d, f), dtype),
                 plan.P("experts", "expert_embed", "expert_ff")),
        "wo": Pm(dense_init(kg(), (e, f, d), dtype),
                 plan.P("experts", "expert_ff", "expert_embed")),
    }


def _router(params, x, cfg: ModelConfig):
    """Top-k routing. x (T, d) → gates (T, k), experts (T, k)."""
    logits = linear(x.astype(jnp.float32), params["router"])  # (T, E)
    k = cfg.experts_per_tok
    gates, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx


def _capacity(t: int, cfg: ModelConfig, factor: float) -> int:
    c = int(t * cfg.experts_per_tok * factor / cfg.num_experts) + 1
    c = max(4, min(t, c))
    return ((c + 31) // 32) * 32  # divisible by any batch-shard span


def _experts_ffn(params, xin):
    """xin (E, C, d) → (E, C, d), batched expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, params["wg"])) * (
        jnp.einsum("ecd,edf->ecf", xin, params["wi"]))
    return jnp.einsum("ecf,efd->ecd", h, params["wo"])


def moe_einsum(params, x, cfg: ModelConfig, capacity_factor: float = 1.25,
               plan=None):
    """One-hot dispatch/combine MoE. x (T, d)."""
    t, d = x.shape
    e = cfg.num_experts
    c = _capacity(t, cfg, capacity_factor)
    gates, idx = _router(params, x, cfg)                  # (T, k)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)    # (T, k, E)
    # Position of each (token, expert) assignment in the expert queue.
    pos = jnp.cumsum(onehot.reshape(t * cfg.experts_per_tok, e), axis=0
                     ).reshape(t, cfg.experts_per_tok, e) - 1.0
    keep = (pos < c) & (onehot > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)
    dispatch = jnp.einsum("tke,tkec->tec", onehot * keep, pos_oh)  # (T,E,C)
    combine = jnp.einsum("tk,tke,tkec->tec", gates, onehot * keep, pos_oh)

    xin = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), x)
    if plan is not None and plan.axes.get("moe_c") is not None:
        xin = constrain(xin, plan, "experts", "moe_c", None)
    out = _experts_ffn(params, xin)
    return jnp.einsum("tec,ecd->td", combine.astype(out.dtype), out)


def moe_sort(params, x, cfg: ModelConfig, capacity_factor: float = 1.25,
             plan=None):
    """Gather/scatter dispatch MoE (no one-hot matmuls). x (T, d)."""
    t, d = x.shape
    e = cfg.num_experts
    k = cfg.experts_per_tok
    c = _capacity(t, cfg, capacity_factor)
    gates, idx = _router(params, x, cfg)                  # (T, k)

    flat_e = idx.reshape(-1)                              # (T*k,)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]
    # Rank within expert: global position − start offset of that expert.
    counts = jnp.zeros((e,), jnp.int32).at[e_sorted].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - starts[e_sorted]
    valid = rank < c
    slot = jnp.where(valid, rank, 0)

    # Gather tokens into expert batches (scatter into (E, C, d)).
    xin = jnp.zeros((e, c, d), x.dtype)
    xin = xin.at[e_sorted, slot].add(
        jnp.where(valid[:, None], x[tok_sorted], 0).astype(x.dtype))
    # Optional (off by default — measured WORSE): forcing the expert batch
    # onto (experts, data-sharded capacity) makes the token scatter itself
    # cross-shard and quadrupled collective bytes on grok-1 (§Perf H2
    # iter 3, refuted hypothesis). Enable via plan axes["moe_c"].
    if plan is not None and plan.axes.get("moe_c") is not None:
        xin = constrain(xin, plan, "experts", "moe_c", None)
    out = _experts_ffn(params, xin)                       # (E, C, d)
    if plan is not None and plan.axes.get("moe_c") is not None:
        out = constrain(out, plan, "experts", "moe_c", None)

    # Scatter-combine back to tokens.
    vals = out[e_sorted, slot] * (g_sorted * valid)[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), out.dtype).at[tok_sorted].add(vals)
    return y


def moe(params, x, cfg: ModelConfig, impl: str = "sort",
        capacity_factor: float = 1.25, token_chunk: int = 65_536,
        plan=None, mesh=None):
    """x (B, S, d) → (B, S, d).

    ``impl``: 'sort' (gather/scatter dispatch), 'einsum' (one-hot masks),
    'ep_local' (shard_map expert parallelism — see moe_ep_local).

    Long-sequence batches are scanned through the expert layer in
    ``token_chunk`` slices: the dispatch buffers scale with the chunk, not
    the full (batch × seq) token count — without this, olmoe's 64-expert
    dispatch at 32k-prefill materializes ~43 GB of (E, C, d) buffers.
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    t = xt.shape[0]
    if impl == "ep_local":
        if mesh is None or plan is None or plan.axes.get("experts") is None:
            fn = moe_sort          # graceful fallback (smoke/1-device)
        else:
            y = moe_ep_local(params, xt, cfg, capacity_factor, plan, mesh)
            return y.reshape(b, s, d).astype(x.dtype)
    if impl == "einsum":
        fn = moe_einsum
    else:
        fn = moe_sort
    if t <= token_chunk:
        y = fn(params, xt, cfg, capacity_factor, plan=plan)
    else:
        pad = (-t) % token_chunk
        if pad:
            xt = jnp.pad(xt, ((0, pad), (0, 0)))
        nc = (t + pad) // token_chunk
        xc = xt.reshape(nc, token_chunk, d)

        @jax.checkpoint
        def step(_, xi):
            return None, fn(params, xi, cfg, capacity_factor, plan=plan)

        _, yc = jax.lax.scan(step, None, xc)
        y = yc.reshape(-1, d)[:t]
    return y.reshape(b, s, d).astype(x.dtype)


__all__ = ["init_mlp", "mlp", "init_moe", "moe", "moe_einsum", "moe_sort"]


# ---------------------------------------------------------------------------
# ep_local: shard_map expert parallelism without cross-shard dispatch.
# ---------------------------------------------------------------------------


def moe_ep_local(params, x, cfg: ModelConfig, capacity_factor: float,
                 plan, mesh):  # noqa: D401
    """Expert parallelism with *local* dispatch + one psum combine.

    Layout: activations are replicated over 'model' (standard TP layout), so
    every model column of a data row already holds the tokens — no token
    movement is needed at all. Each model shard owns E/|model| experts,
    gathers its assigned tokens from the local activation slab, runs its
    experts, and contributes a partial (T_loc, d) output; one bf16 psum over
    'model' completes the combine. GSPMD never sees the dispatch (it is
    shard-local jnp), eliminating the partial-activation all-reduces that
    dominate the capacity-dispatch path (§Perf H1/H2: 11.5 TiB → ~0.4 TiB
    on olmoe train_4k).

    Requirements: plan.axes['experts'] is a mesh axis dividing E, and
    x's token dim divides the batch axes. Per-(data-shard × expert)
    capacity = T_loc·k·cf/E (drop semantics are per data shard).
    """
    from jax.sharding import PartitionSpec as P

    t, d = x.shape
    e = cfg.num_experts
    k = cfg.experts_per_tok
    model_ax = plan.axes.get("experts")
    batch_ax = plan.axes.get("batch")
    assert model_ax is not None, "ep_local needs expert-parallel plan"
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = axis_sizes[model_ax]
    e_loc = e // msize
    batch_axes = ((batch_ax,) if isinstance(batch_ax, str)
                  else tuple(batch_ax or ()))

    def _dispatch_chunk(x_loc, router, wi, wg, wo, m):
        t_loc = x_loc.shape[0]
        c = max(4, int(t_loc * k * capacity_factor / e) + 1)
        logits = jax.lax.dot_general(
            x_loc.astype(jnp.float32), router,
            (((1,), (0,)), ((), ())))
        gates, idx = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gates, axis=-1)
        # Assignments owned by this shard: experts [m·e_loc, (m+1)·e_loc).
        flat_e = idx.reshape(-1) - m * e_loc
        flat_g = gates.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t_loc), k)
        mine = (flat_e >= 0) & (flat_e < e_loc)
        e_mine = jnp.where(mine, flat_e, e_loc)       # spill row e_loc
        order = jnp.argsort(e_mine, stable=True)
        e_sorted = e_mine[order]
        tok_sorted = flat_tok[order]
        g_sorted = flat_g[order]
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[e_sorted].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(t_loc * k) - starts[e_sorted]
        valid = (e_sorted < e_loc) & (rank < c)
        slot = jnp.where(valid, rank, 0)
        row = jnp.where(valid, e_sorted, e_loc)
        xin = jnp.zeros((e_loc + 1, c, d), x_loc.dtype)
        xin = xin.at[row, slot].add(
            jnp.where(valid[:, None], x_loc[tok_sorted], 0
                      ).astype(x_loc.dtype))
        xin = xin[:e_loc]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg)) * (
            jnp.einsum("ecd,edf->ecf", xin, wi))
        out = jnp.einsum("ecf,efd->ecd", h, wo)        # (E_loc, C, d)
        out = jnp.concatenate(
            [out, jnp.zeros((1, c, d), out.dtype)], axis=0)
        vals = out[row, slot] * (g_sorted * valid)[:, None].astype(out.dtype)
        return jnp.zeros((t_loc, d), out.dtype).at[tok_sorted].add(vals)

    def body(x_loc, router, wi, wg, wo):
        m = jax.lax.axis_index(model_ax)
        t_loc = x_loc.shape[0]
        chunk = min(8192, t_loc)
        if t_loc % chunk:
            chunk = t_loc
        if t_loc == chunk:
            y_part = _dispatch_chunk(x_loc, router, wi, wg, wo, m)
        else:
            xc = x_loc.reshape(t_loc // chunk, chunk, d)

            @jax.checkpoint
            def step(_, xi):
                return None, _dispatch_chunk(xi, router, wi, wg, wo, m)

            _, yc = jax.lax.scan(step, None, xc)
            y_part = yc.reshape(t_loc, d)
        return jax.lax.psum(y_part, model_ax)

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_ax, None), P(None, None),
                  P(model_ax, None, None), P(model_ax, None, None),
                  P(model_ax, None, None)),
        out_specs=P(batch_ax, None),
    )(x, params["router"], params["wi"], params["wg"], params["wo"])
