"""Batch engine throughput: graphs/sec and compile counts vs a per-graph loop.

The serving regime this measures: a stream of many *small* clustering
queries of assorted shapes (near-dup buckets, LSH bands, per-shard
similarity graphs). The per-graph engine retraces/recompiles its while-loop
for every new ``(n, m)`` shape; the batch engine compiles one program per
``(B, R, W)`` shape bucket and amortizes it over every graph that ever
lands in the bucket. ``--executor`` picks how buckets reach the device:
``sync`` (block per bucket), ``async`` (all buckets dispatched before any
harvest — packing overlaps device execution), ``sharded`` (each bucket
data-parallel across all local devices). ``--policy`` picks the scheduling
policy for the serving-style pass (the same workload streamed through
``ClusterBatcher`` + ``serve_all``), whose per-bucket flush-latency
telemetry is emitted alongside the one-shot numbers.

``--method`` picks the registered bucket program the loop/batch/serve
passes run (``pivot`` default, ``precluster`` for the constant-round
agreement program); independent of that axis, a ``method_quality`` pass
always compares the two programs' disagreement costs at matched
wall-clock (the faster method earns a best-of-k budget) plus device round
counts, emitted as the ``method_quality`` block of the JSON.

Run:  PYTHONPATH=src python benchmarks/batch_bench.py \
          [--graphs 96] [--repeat 3] [--executor sync] [--policy full] \
          [--method pivot] [--json BENCH_batch.json]

Reported (and written machine-readably to ``--json`` for cross-PR perf
tracking):
  * graphs/sec of the per-graph ``correlation_cluster`` loop
  * graphs/sec of ``correlation_cluster_batch`` (same graphs, same keys —
    output is bit-identical, which is also asserted)
  * p50/p99 over the steady-state repeats
  * graphs/sec of the serving pass under ``--policy`` + its flush-latency
    telemetry (p50/p99 wall + assemble per bucket shape; since the PR 8
    admission-time packing split the pre-split ``pack_*`` fields are
    renamed ``assemble_*`` and per-request ``build_*`` stats ride along,
    plus ``host_pack`` wall fractions of both streams over the serve wall)
  * compile counts: per-graph MIS programs vs batch bucket programs, plus
    the bounded program-cache state (size/capacity/evictions)
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import build_graph, correlation_cluster, correlation_cluster_batch
from repro.core import batch as batch_mod
from repro.core import make_executor, program_cache_info
from repro.core.graph import random_arboric
from repro.core.mis import _greedy_mis_parallel_impl
from repro.core.programs import registered_methods
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
from repro.serve.engine import serve_all
from repro.serve.scheduler import POLICY_NAMES


def make_workload(num_graphs: int, seed: int = 0):
    """Assorted small graphs: sizes 8..96, arboricity 1..3, distinct keys."""
    rng = np.random.default_rng(seed)
    graphs, keys, lams = [], [], []
    for i in range(num_graphs):
        n = int(rng.integers(8, 96))
        lam = int(rng.integers(1, 4))
        edges, _ = random_arboric(n, lam, rng)
        graphs.append(build_graph(n, edges))
        keys.append(jax.random.PRNGKey(i))
        lams.append(lam)
    return graphs, keys, lams


def bench_loop(graphs, keys, lams, method: str = "pivot"):
    t0 = time.perf_counter()
    results = [correlation_cluster(g, key=k, lam=lam, method=method)
               for g, k, lam in zip(graphs, keys, lams)]
    return time.perf_counter() - t0, results


def bench_batch(graphs, keys, lams, executor, method: str = "pivot",
                num_samples: int = 1):
    t0 = time.perf_counter()
    results = correlation_cluster_batch(graphs, keys=keys, lams=lams,
                                        executor=executor, method=method,
                                        num_samples=num_samples)
    return time.perf_counter() - t0, results


def bench_method_quality(graphs, keys, lams, executor,
                         max_matched_k: int = 16) -> dict:
    """Clustering quality per registered method at matched wall-clock.

    PIVOT is a 3-approx in expectation; the constant-round precluster
    program trades quality for O(1) rounds-loop trips. A raw cost
    comparison at one sample each would hide that trade, so the faster
    method is granted a best-of-k budget: ``k_matched = floor(pivot_wall /
    precluster_wall)`` (clamped to [1, max_matched_k]) extra samples, the
    budget equalizing the two methods' steady-state walls. Emits total
    disagreement costs, the cost ratio vs PIVOT at 1 sample and at the
    matched budget, and mean device round counts per method — the
    ``method_quality`` block of ``BENCH_batch.json``.
    """
    walls, runs = {}, {}
    for method in ("pivot", "precluster"):
        bench_batch(graphs, keys, lams, executor, method=method)   # warm
        walls[method], runs[method] = bench_batch(graphs, keys, lams,
                                                  executor, method=method)
    k_matched = max(1, min(max_matched_k,
                           int(walls["pivot"] // max(walls["precluster"],
                                                     1e-9))))
    if k_matched > 1:
        bench_batch(graphs, keys, lams, executor, method="precluster",
                    num_samples=k_matched)                          # warm
        wall_m, res_m = bench_batch(graphs, keys, lams, executor,
                                    method="precluster",
                                    num_samples=k_matched)
    else:
        wall_m, res_m = walls["precluster"], runs["precluster"]
    cost_pivot = sum(r.cost for r in runs["pivot"])
    cost_pre = sum(r.cost for r in runs["precluster"])
    cost_pre_m = sum(r.cost for r in res_m)
    block = {
        "n_graphs": len(graphs),
        "matched_samples": k_matched,
        "per_method": {
            "pivot": {
                "wall_s": walls["pivot"],
                "total_cost": cost_pivot,
                "mean_rounds": float(np.mean(
                    [r.info["depth"] for r in runs["pivot"]])),
            },
            "precluster": {
                "wall_s": walls["precluster"],
                "total_cost": cost_pre,
                "mean_rounds": float(np.mean(
                    [r.info["depth"] for r in runs["precluster"]])),
                "matched_wall_s": wall_m,
                "matched_total_cost": cost_pre_m,
            },
        },
        # >1 means precluster leaves more disagreements than PIVOT.
        "cost_ratio_vs_pivot": cost_pre / max(1, cost_pivot),
        "cost_ratio_vs_pivot_matched": cost_pre_m / max(1, cost_pivot),
    }
    return block


def bench_serve_policy(graphs, lams, policy: str, executor: str,
                       method: str = "pivot"):
    """Stream the workload through the serving engine under a policy.

    Same graphs/keys as the one-shot passes (so results are asserted
    bit-identical to the per-graph loop), driven by ``serve_all``. Returns
    ``(wall_seconds, {uid: request}, batcher)`` — the batcher, not just
    its stats, so the JSON can also emit the cost policy's steal-pricing
    counters alongside the flush-latency telemetry.
    """
    max_wait = None if policy == "full" else 0.05
    batcher = ClusterBatcher(max_batch=32, policy=policy, max_wait=max_wait,
                             executor=executor, method=method)
    reqs = [ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i),
                           lam=lam)
            for i, (g, lam) in enumerate(zip(graphs, lams))]
    t0 = time.perf_counter()
    retired = serve_all(batcher, reqs)
    dt = time.perf_counter() - t0
    return dt, {r.uid: r for r in retired}, batcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=96)
    ap.add_argument("--repeat", type=int, default=3,
                    help="steady-state repeats after the cold pass")
    ap.add_argument("--executor", choices=["sync", "async", "sharded"],
                    default="sync")
    ap.add_argument("--policy", choices=list(POLICY_NAMES), default="full",
                    help="scheduling policy for the serving-style pass")
    ap.add_argument("--method", choices=list(registered_methods()),
                    default="pivot",
                    help="registered bucket program for the loop/batch/"
                         "serve passes (the method_quality block always "
                         "compares pivot vs precluster)")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep kernel block shapes per bucket tier "
                         "(after the cold/steady passes, so those stay "
                         "cold) and emit the tuning block")
    ap.add_argument("--json", default="BENCH_batch.json",
                    help="machine-readable results path ('' to skip)")
    args = ap.parse_args()

    graphs, keys, lams = make_workload(args.graphs)
    n_graphs = len(graphs)
    # One executor instance across passes — what a serving process would do.
    executor = make_executor(args.executor)

    # --- cold pass: fresh shapes, compiles included (the serving scenario) --
    mis_cache0 = int(_greedy_mis_parallel_impl._cache_size())
    t_loop, loop_res = bench_loop(graphs, keys, lams, method=args.method)
    mis_compiles = int(_greedy_mis_parallel_impl._cache_size()) - mis_cache0

    batch_cache0 = batch_mod.program_cache_size()
    t_batch, batch_res = bench_batch(graphs, keys, lams, executor,
                                     method=args.method)
    batch_compiles = batch_mod.program_cache_size() - batch_cache0
    buckets = sorted({r.info["bucket"] for r in batch_res})

    for a, b in zip(loop_res, batch_res):
        assert (a.labels == b.labels).all() and a.cost == b.cost, \
            "batch output diverged from the per-graph engine"

    print(f"workload: {n_graphs} graphs, {len(buckets)} buckets {buckets}, "
          f"executor={args.executor} method={args.method}")
    print(f"[cold]   per-graph loop: {t_loop:8.2f}s  "
          f"{n_graphs / t_loop:8.1f} graphs/s  "
          f"({mis_compiles} MIS compiles)")
    print(f"[cold]   batch engine:   {t_batch:8.2f}s  "
          f"{n_graphs / t_batch:8.1f} graphs/s  "
          f"({batch_compiles} bucket compiles)")
    print(f"[cold]   speedup: {t_loop / t_batch:.1f}x   "
          f"compile ratio: {mis_compiles}/{batch_compiles} "
          "(graphs-shapes vs buckets)")

    # --- steady state: every shape already compiled --------------------------
    loop_times = [bench_loop(graphs, keys, lams, method=args.method)[0]
                  for _ in range(args.repeat)]
    batch_times = [bench_batch(graphs, keys, lams, executor,
                               method=args.method)[0]
                   for _ in range(args.repeat)]
    t_loop_w, t_batch_w = min(loop_times), min(batch_times)
    print(f"[steady] per-graph loop: {t_loop_w:8.2f}s  "
          f"{n_graphs / t_loop_w:8.1f} graphs/s")
    print(f"[steady] batch engine:   {t_batch_w:8.2f}s  "
          f"{n_graphs / t_batch_w:8.1f} graphs/s")
    print(f"[steady] speedup: {t_loop_w / t_batch_w:.1f}x")

    assert batch_compiles <= len(buckets) + 1, (
        "bucket contract violated: compiles must track buckets, not graphs")

    # --- autotune pass: sweep kernel block shapes over the real buckets ----
    # Runs after the cold/steady passes so those numbers stay untuned and
    # comparable across PRs; the tuning block reports the per-tier winners
    # and the measured default-vs-tuned kernel speedup.
    tuning_block = {"enabled": bool(args.autotune)}
    if args.autotune:
        t0 = time.perf_counter()
        warmer = ClusterBatcher(max_batch=32, executor=args.executor,
                                method=args.method)
        warmer.warmup(graphs, autotune=True)
        tuning_block.update(warmer.stats.tuning or {})
        tuning_block["sweep_wall_s"] = time.perf_counter() - t0
        for rec in tuning_block.get("sweep_log", []):
            print(f"[tuning] {rec['kernel']:12s} "
                  f"{rec['R']}x{rec['W']} B={rec['batch']:4d} "
                  f"winner={rec['winner']:4d} "
                  f"default={rec['default_ms']:7.2f}ms "
                  f"tuned={rec['winner_ms']:7.2f}ms "
                  f"speedup={rec['speedup_vs_default']:.2f}x")

    # --- method quality: disagreement cost per method at matched wall ------
    method_quality = bench_method_quality(graphs, keys, lams, executor)
    mq_pre = method_quality["per_method"]["precluster"]
    print(f"[quality] precluster/pivot cost ratio: "
          f"{method_quality['cost_ratio_vs_pivot']:.3f} (1 sample), "
          f"{method_quality['cost_ratio_vs_pivot_matched']:.3f} "
          f"(best-of-{method_quality['matched_samples']} matched wall); "
          f"rounds pivot="
          f"{method_quality['per_method']['pivot']['mean_rounds']:.1f} "
          f"precluster={mq_pre['mean_rounds']:.1f}")

    # --- serving pass: same workload through the scheduler-driven engine ----
    bench_serve_policy(graphs, lams, args.policy, args.executor,
                       method=args.method)  # warm
    t_serve, served, serve_batcher = bench_serve_policy(
        graphs, lams, args.policy, args.executor, method=args.method)
    serve_stats = serve_batcher.stats
    for uid, a in enumerate(loop_res):
        b = served[uid].result
        assert (a.labels == b.labels).all() and a.cost == b.cost, \
            "serving-policy output diverged from the per-graph engine"
    print(f"[serve]  policy={args.policy:9s} {n_graphs / t_serve:8.1f} "
          f"graphs/s  flushes={serve_stats.flushes} "
          f"(deadline={serve_stats.deadline_flushes}, "
          f"stolen={serve_stats.stolen_requests})")
    print(f"[serve]  host packing: build "
          f"{serve_stats.latency.total_build_s / t_serve * 100:5.1f}% of "
          f"wall (admission)  assemble "
          f"{serve_stats.latency.total_assemble_s / t_serve * 100:5.1f}% "
          "(flush path)")

    if args.json:
        payload = {
            "bench": "batch",
            "executor": args.executor,
            "policy": args.policy,
            "method": args.method,
            "n_graphs": n_graphs,
            "n_buckets": len(buckets),
            "cold": {
                "loop_s": t_loop,
                "batch_s": t_batch,
                "loop_gps": n_graphs / t_loop,
                "batch_gps": n_graphs / t_batch,
                "speedup": t_loop / t_batch,
                "mis_compiles": mis_compiles,
                "batch_compiles": batch_compiles,
            },
            "steady": {
                "loop_gps": n_graphs / t_loop_w,
                "batch_gps": n_graphs / t_batch_w,
                "speedup": t_loop_w / t_batch_w,
                "batch_s_p50": float(np.percentile(batch_times, 50)),
                "batch_s_p99": float(np.percentile(batch_times, 99)),
            },
        }
        serve_payload = {
            "policy": args.policy,
            "gps": n_graphs / t_serve,
            "flushes": serve_stats.flushes,
            "deadline_flushes": serve_stats.deadline_flushes,
            "coalesced_flushes": serve_stats.coalesced_flushes,
            "stolen_requests": serve_stats.stolen_requests,
            "padded_slots": serve_stats.padded_slots,
            "flush_latency": serve_stats.latency.summary(),
            # The two host packing streams of the admission-time split as
            # fractions of the serve wall: build = per-request row builds
            # at admission, assemble = per-bucket staging assembly on the
            # flush path (the only packing cost left there).
            "host_pack": {
                "build_wall_s": serve_stats.latency.total_build_s,
                "assemble_wall_s": serve_stats.latency.total_assemble_s,
                "build_frac": serve_stats.latency.total_build_s / t_serve,
                "assemble_frac":
                    serve_stats.latency.total_assemble_s / t_serve,
            },
            # Result-cache counters ride along for cross-PR tracking even
            # though this workload is all-unique (hits stay 0 here; the
            # repeat-traffic scenario in serve_bench exercises them).
            "cache_hits": serve_stats.cache_hits,
            "subscribed": serve_stats.subscribed,
        }
        if serve_stats.result_cache is not None:
            rc = serve_stats.result_cache
            serve_payload["result_cache"] = {
                "hits": rc.hits, "misses": rc.misses,
                "evictions": rc.evictions, "collisions": rc.collisions,
                "insertions": rc.insertions, "entries": rc.entries,
                "bytes": rc.bytes,
            }
        cost_stats = getattr(serve_batcher.policy, "cost_stats", None)
        if cost_stats is not None:      # cost policy: steal pricing counters
            serve_payload["cost"] = cost_stats()
        payload["serve"] = serve_payload
        payload["method_quality"] = method_quality
        payload["tuning"] = tuning_block
        # Host metadata + tuning-cache state: makes the perf trajectory
        # comparable across machines.
        from repro.kernels.autotune import host_provenance
        payload["provenance"] = host_provenance()
        # program_cache now also reports lifetime compiles and the pinned
        # bucket shapes (the scheduler's eviction hints).
        payload["program_cache"] = program_cache_info()
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
