"""Quickstart: cluster a signed graph with the paper's algorithms.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import build_graph, correlation_cluster
from repro.core.graph import random_arboric


def main():
    rng = np.random.default_rng(0)
    n, lam = 2_000, 3
    edges, _ = random_arboric(n, lam, rng)
    g = build_graph(n, edges)
    print(f"graph: n={n} m={g.m} (λ ≤ {lam} by construction)")

    # Corollary 28: degree-cap (Thm 26, ε=2) + PIVOT → 3-approx in expectation
    res = correlation_cluster(g, method="pivot", lam=lam,
                              key=jax.random.PRNGKey(0))
    print(f"pivot        cost={res.cost}  high-degree singletons="
          f"{res.info['high_degree']}  depth={res.info['depth']}")

    # Same, with Algorithm 1's phase scheduling + MPC round ledger
    res = correlation_cluster(g, method="pivot_phased", lam=lam,
                              key=jax.random.PRNGKey(0))
    print(f"pivot_phased cost={res.cost}  MPC rounds="
          f"{res.info['mpc_rounds']:.0f}  ledger={res.info['ledger']}")

    # Corollary 32: deterministic O(λ²) in O(1) rounds
    res = correlation_cluster(g, method="cliques")
    print(f"cliques      cost={res.cost}")

    # Distributed engine (edge-sharded shard_map over available devices)
    res = correlation_cluster(g, method="pivot", lam=lam,
                              key=jax.random.PRNGKey(0), distributed=True)
    print(f"distributed  cost={res.cost}  rounds={res.info['depth']}")


if __name__ == "__main__":
    main()
