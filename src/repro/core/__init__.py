"""Core library: the paper's correlation-clustering algorithms in JAX.

Layout:
  graph.py       — containers + generators (COO/CSR, padded, jit-stable)
  mis.py         — randomized greedy MIS (oracle, round-parallel, capture)
  phases.py      — Algorithm 1/2/3 scheduling + MPC round ledger
  pivot.py       — PIVOT clustering engines
  degree_cap.py  — Theorem 26 / Algorithm 4 reduction
  forest.py      — λ=1 matching suite (Cor 27/31, Lemma 29)
  cliques.py     — Corollary 32 O(λ²)-approx + connected components
  arboricity.py  — degeneracy peeling bounds on λ
  cost.py        — disagreement cost, brute-force OPT, Lemma 25 transform
  dist.py        — shard_map edge-parallel engine (MPC ⇒ mesh mapping)
  plan.py        — batch-engine host side: bucketing, ELL packing, staging
  executor.py    — batch-engine device side: fused program, program-cache
                   LRU, sync/async/sharded bucket executors
  batch.py       — `correlation_cluster_batch` entry point (plan ∘ executor)
  api.py         — `correlation_cluster` public entry point
"""

from .api import ClusterResult, correlation_cluster, correlation_cluster_batch
from .arboricity import arboricity_bounds, degeneracy_parallel, degeneracy_sequential
from .batch import (
    BucketBufferPool,
    GraphPlan,
    PackedRows,
    PackStats,
    build_packed_rows,
    pack_bucket,
    plan_graph,
    promote_plan,
)
from .executor import (
    AsyncExecutor,
    BucketExecutor,
    InFlightBucket,
    ShardedExecutor,
    SyncExecutor,
    make_executor,
    program_cache_contains,
    program_cache_info,
    program_cache_pin,
    program_cache_size,
    program_cache_touch,
    program_cache_unpin,
    set_program_cache_capacity,
)
from .plan import GraphFingerprint, estimate_pack_stats, graph_fingerprint
from .cliques import clique_clustering, connected_components
from .cost import (
    brute_force_opt,
    clustering_cost,
    clustering_cost_split,
    lemma25_transform,
)
from .degree_cap import degree_capped, degree_capped_pivot, degree_threshold
from .dist import distributed_pivot, edge_shard_mesh, pow2_device_mesh
from .forest import (
    augmenting_matching_parallel,
    clustering_from_matching,
    max_matching_forest,
    maximal_matching_parallel,
    matching_size,
)
from .graph import Graph, build_graph
from .mis import (
    dependency_depth,
    greedy_mis_parallel,
    greedy_mis_sequential,
    pivot_sequential,
    random_permutation_ranks,
    random_permutation_ranks_batch,
)
from .phases import RoundLedger, algorithm1, remaining_max_degree_after_prefix
from .pivot import PivotResult, pivot

__all__ = [
    "ClusterResult",
    "correlation_cluster",
    "correlation_cluster_batch",
    "GraphPlan",
    "PackedRows",
    "PackStats",
    "BucketBufferPool",
    "plan_graph",
    "promote_plan",
    "build_packed_rows",
    "pack_bucket",
    "estimate_pack_stats",
    "GraphFingerprint",
    "graph_fingerprint",
    "BucketExecutor",
    "SyncExecutor",
    "AsyncExecutor",
    "ShardedExecutor",
    "InFlightBucket",
    "make_executor",
    "program_cache_size",
    "program_cache_info",
    "program_cache_contains",
    "program_cache_touch",
    "program_cache_pin",
    "program_cache_unpin",
    "set_program_cache_capacity",
    "Graph",
    "build_graph",
    "arboricity_bounds",
    "degeneracy_parallel",
    "degeneracy_sequential",
    "clique_clustering",
    "connected_components",
    "brute_force_opt",
    "clustering_cost",
    "clustering_cost_split",
    "lemma25_transform",
    "degree_capped",
    "degree_capped_pivot",
    "degree_threshold",
    "distributed_pivot",
    "edge_shard_mesh",
    "pow2_device_mesh",
    "augmenting_matching_parallel",
    "clustering_from_matching",
    "max_matching_forest",
    "maximal_matching_parallel",
    "matching_size",
    "dependency_depth",
    "greedy_mis_parallel",
    "greedy_mis_sequential",
    "pivot_sequential",
    "random_permutation_ranks",
    "random_permutation_ranks_batch",
    "RoundLedger",
    "algorithm1",
    "remaining_max_degree_after_prefix",
    "PivotResult",
    "pivot",
]
