"""llama-3.2-vision-90b [vlm]: 100L, d=8192, 64H (GQA kv=8), ff=28672,
vocab=128256; every 5th layer is a gated cross-attention layer over stub
image patch embeddings. [hf:meta-llama/Llama-3.2-90B-Vision; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256, head_dim=128,
        cross_attn_every=5, num_image_tokens=1024, rope_theta=5e5,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke", family="vlm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16,
        cross_attn_every=2, num_image_tokens=16, vocab_round=64,
    )
