"""Measurement-driven block-shape autotuner for the batched Pallas kernels.

The paper's per-round budget is the frame: Theorem 26 bounds each
PIVOT/MIS round by work proportional to the capped adjacency width
(``W <= 12*lambda`` after the degree cap), so the engine's whole round cost
lives in two batched ELL sweeps — ``neighbor_min_ell_batch`` and
``label_agree_ell_batch``. Every bucket program the method/objective
registry composes (:mod:`repro.core.programs`) is built from these same
two kernels: the ``'pivot'`` MIS while-loop and the ``'precluster'``
constant-round propagation both run ``neighbor_min``; the ``'disagree'``
*and* ``'minmax'`` cost passes both reduce over ``label_agree`` counts.
Tuning is therefore keyed by kernel × shape, never by method or
objective — one warmup sweep's winners are baked into every registered
program at that bucket shape, and registering a new method can never
leave it running untuned blocks. The one free knob in those sweeps is
``block_rows``: the row-tile each Pallas grid step pipelines through
VMEM. Whether a 64-row or a 512-row tile meets the
per-round budget "as fast as the hardware allows" depends on ``(R, W,
batch tier, backend)`` — none of which is known at authoring time — so
this module measures instead of assuming: sweep a small candidate set over
*real packed bucket tensors* at warmup, keep the winner, and bake it into
the compiled bucket program. Block shape may change timing, never
labels/costs/picked — the bit-exactness contract is asserted for every
candidate in ``tests/test_autotune.py``.

Persistence: :class:`TuningCache` maps ``(backend, kernel, R, W,
batch_tier)`` → winning ``block_rows`` and serializes to JSON (explicit
path or the ``REPRO_TUNING_CACHE`` env var) so tuned shapes survive across
processes — a second process warms up with zero sweep timings (hit
counters prove it). Entries are *invalidated, never trusted*: a cached
winner is honoured only when its recorded backend and ``jax.__version__``
match the running process; stale entries count in ``stale`` and fall back
to a fresh sweep.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.util import next_pow2

#: The hand-picked constant the kernels shipped with — the sweep baseline.
DEFAULT_BLOCK_ROWS = 256
#: Candidate row tiles (clamped to R per bucket before sweeping).
CANDIDATE_BLOCK_ROWS = (64, 128, 256, 512)
#: The two batched kernels on the bucket program's hot path.
KERNELS = ("neighbor_min", "label_agree")
#: Tier cap: batch axes beyond this share one tuning entry.
MAX_BATCH_TIER = 1024

_CACHE_ENV = "REPRO_TUNING_CACHE"
_FORMAT_VERSION = 1


def batch_tier(b: int) -> int:
    """Pow2 tier of a packed batch axis ``B = G_pad * k`` (capped).

    Buckets are swept and cached per tier, not per exact B: the packed
    batch axis is already pow2-padded by the executors, so tiers are what
    actually reaches the device.
    """
    return min(MAX_BATCH_TIER, next_pow2(max(1, int(b))))


def candidate_blocks(r: int,
                     candidates: Optional[Sequence[int]] = None
                     ) -> Tuple[int, ...]:
    """Candidate ``block_rows`` for a bucket of R rows: the sweep set
    clamped to R, deduplicated order-preserving, always containing the
    (clamped) default so "tuned vs default" is measured, never inferred."""
    cands = CANDIDATE_BLOCK_ROWS if candidates is None else tuple(candidates)
    out: List[int] = []
    for c in (*cands, DEFAULT_BLOCK_ROWS):
        c = max(1, min(int(c), int(r)))
        if c not in out:
            out.append(c)
    return tuple(out)


class TuningCache:
    """Persistent ``(backend, kernel, R, W, batch_tier) -> block_rows`` map.

    File format (versioned JSON)::

        {"version": 1,
         "entries": {
            "cpu/neighbor_min/128x16/b64": {
                "block_rows": 128,
                "backend": "cpu",
                "jax_version": "0.4.37",
                "timings_ms": {"64": 1.9, "128": 1.4},
                "speedup_vs_default": 1.36}}}

    Invalidation rule: an entry is honoured only when its ``backend`` and
    ``jax_version`` match the running process — anything else is counted
    as ``stale`` and treated as a miss (stale entries are ignored, never
    trusted). Counters (``hits``/``misses``/``stale``/``sweeps``) are
    process-local telemetry, not persisted.
    """

    def __init__(self, path: Optional[str] = None, autoload: bool = True):
        if path is None:
            path = os.environ.get(_CACHE_ENV) or None
        self.path = path
        self._entries: Dict[str, dict] = {}
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.sweeps = 0          # completed kernel sweeps this process
        self.sweep_log: List[dict] = []
        if self.path and autoload:
            self.load()

    @staticmethod
    def _key(backend: str, kernel: str, r: int, w: int, tier: int) -> str:
        return f"{backend}/{kernel}/{int(r)}x{int(w)}/b{int(tier)}"

    def get(self, kernel: str, r: int, w: int, tier: int,
            backend: Optional[str] = None, count: bool = True
            ) -> Optional[int]:
        """Winning ``block_rows`` or None (miss / stale). ``count=False``
        keeps hot-path resolution out of the warmup hit/miss counters."""
        backend = backend or jax.default_backend()
        entry = self._entries.get(self._key(backend, kernel, r, w, tier))
        if entry is None:
            if count:
                self.misses += 1
            return None
        if (entry.get("backend") != backend
                or entry.get("jax_version") != jax.__version__):
            if count:
                self.stale += 1
                self.misses += 1
            return None
        if count:
            self.hits += 1
        return int(entry["block_rows"])

    def put(self, kernel: str, r: int, w: int, tier: int, block_rows: int,
            backend: Optional[str] = None,
            meta: Optional[dict] = None) -> None:
        backend = backend or jax.default_backend()
        entry = {"block_rows": int(block_rows), "backend": backend,
                 "jax_version": jax.__version__}
        if meta:
            entry.update(meta)
        self._entries[self._key(backend, kernel, r, w, tier)] = entry

    def load(self) -> int:
        """Merge entries from ``path`` (missing/corrupt files are treated
        as empty — a tuning cache is an optimization, never a hard dep)."""
        if not self.path or not os.path.exists(self.path):
            return 0
        try:
            with open(self.path) as f:
                blob = json.load(f)
        except (OSError, ValueError):
            return 0
        if not isinstance(blob, dict) or blob.get("version") != _FORMAT_VERSION:
            return 0
        entries = blob.get("entries")
        if not isinstance(entries, dict):
            return 0
        loaded = 0
        for key, entry in entries.items():
            if isinstance(entry, dict) and "block_rows" in entry:
                self._entries[key] = entry
                loaded += 1
        return loaded

    def save(self) -> None:
        if not self.path:
            return
        blob = {"version": _FORMAT_VERSION, "entries": self._entries}
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)

    def info(self) -> dict:
        """Engine-side telemetry block (serialization-safe)."""
        return {
            "path": self.path,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "stale": self.stale,
            "sweeps": self.sweeps,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
        }


# Process-default cache (lazy): the executor's hot-path resolution and the
# serving warmup must read the same winners or program keys would lie.
_cache: Optional[TuningCache] = None


def tuning_cache() -> TuningCache:
    global _cache
    if _cache is None:
        _cache = TuningCache()
    return _cache


def set_tuning_cache(cache: Optional[TuningCache]) -> Optional[TuningCache]:
    """Swap the process-default cache (tests / explicit paths); returns the
    previous one. ``None`` resets to lazy env-var resolution."""
    global _cache
    prev = _cache
    _cache = cache
    return prev


def tuning_info() -> dict:
    """Default-cache counters + sweep log — the engine-side telemetry."""
    cache = tuning_cache()
    out = cache.info()
    out["sweep_log"] = list(cache.sweep_log)
    return out


def resolve_block_rows(shape) -> Optional[Tuple[int, int]]:
    """Tuned ``(neighbor_min, label_agree)`` block rows for a packed
    ``(B, R, W)`` shape, or None when the bucket tier is untuned (the
    program key then stays on the legacy default and the kernels use
    ``DEFAULT_BLOCK_ROWS``). Pure dict reads — safe on the hot path."""
    b, r, w = (int(s) for s in shape)
    tier = batch_tier(b)
    cache = tuning_cache()
    nm = cache.get("neighbor_min", r, w, tier, count=False)
    la = cache.get("label_agree", r, w, tier, count=False)
    if nm is None and la is None:
        return None
    return (nm if nm is not None else min(DEFAULT_BLOCK_ROWS, r),
            la if la is not None else min(DEFAULT_BLOCK_ROWS, r))


def sweep_bucket(ell, ranks_p, elig_p,
                 cache: Optional[TuningCache] = None,
                 candidates: Optional[Sequence[int]] = None,
                 repeats: int = 3) -> List[dict]:
    """Time both batched kernels over real packed bucket tensors across the
    clamped candidate set; record winners (and timings) in the cache.

    The measurement inputs are the *actual* packed ELL/state tensors a
    flush of this bucket would run, not synthetic shapes — sparsity
    patterns and pad rows are part of what the sweep prices. Each
    candidate is compiled (first call, untimed) then timed best-of-
    ``repeats`` with ``block_until_ready``. Returns one sweep record per
    kernel; also appended to ``cache.sweep_log``.

    One sweep serves every registered bucket program at this shape: the
    ``neighbor_min`` timing covers both the MIS loop and the precluster
    propagation (same kernel, same tensors, different trip counts), and
    the ``label_agree`` timing covers both registered cost passes — the
    ``'minmax'`` objective consumes the same per-vertex agreement counts
    the ``'disagree'`` reduction does, so its hot kernel is tuned by this
    sweep without a separate pass.
    """
    from repro.kernels import ops as _kops

    cache = cache if cache is not None else tuning_cache()
    ell = jnp.asarray(ell)
    ranks_p = jnp.asarray(ranks_p)
    active_p = jnp.asarray(elig_p)
    b, r, w = (int(s) for s in ell.shape)
    tier = batch_tier(b)
    cands = candidate_blocks(r, candidates)
    default_br = min(DEFAULT_BLOCK_ROWS, r)
    # Labels for the cost-pass kernel: contents don't affect timing (the
    # memory/grid shape does), so any valid labeling with the -1 pad
    # sentinel works.
    labels_p = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32), (b, r)),
         jnp.full((b, 1), -1, jnp.int32)], axis=1)
    runs = {
        "neighbor_min": lambda br: _kops.neighbor_min_ell_batch(
            ell, ranks_p, active_p, block_rows=br),
        "label_agree": lambda br: _kops.label_agree_ell_batch(
            ell, labels_p, block_rows=br),
    }
    records: List[dict] = []
    for kernel in KERNELS:
        fn = runs[kernel]
        timings: Dict[int, float] = {}
        for br in cands:
            jax.block_until_ready(fn(br))        # compile outside the timing
            best = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(br))
                best = min(best, time.perf_counter() - t0)
            timings[br] = best
        winner = min(cands, key=timings.__getitem__)
        speedup = timings[default_br] / max(timings[winner], 1e-12)
        record = {
            "kernel": kernel, "R": r, "W": w, "batch": b, "tier": tier,
            "candidates": list(cands),
            "timings_ms": {str(br): t * 1e3 for br, t in timings.items()},
            "winner": winner,
            "default_block_rows": default_br,
            "default_ms": timings[default_br] * 1e3,
            "winner_ms": timings[winner] * 1e3,
            "speedup_vs_default": speedup,
        }
        cache.put(kernel, r, w, tier, winner,
                  meta={"timings_ms": record["timings_ms"],
                        "speedup_vs_default": speedup})
        cache.sweeps += 1
        cache.sweep_log.append(record)
        records.append(record)
    cache.save()
    return records


def host_provenance() -> dict:
    """Host/runtime metadata stamped into benchmark JSONs so the perf
    trajectory is comparable across machines, plus the tuning-cache state
    (the invalidation key — backend + jax version — lives here too)."""
    import platform

    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "tuning_cache": tuning_cache().info(),
    }


__all__ = [
    "DEFAULT_BLOCK_ROWS",
    "CANDIDATE_BLOCK_ROWS",
    "KERNELS",
    "TuningCache",
    "batch_tier",
    "candidate_blocks",
    "tuning_cache",
    "set_tuning_cache",
    "tuning_info",
    "resolve_block_rows",
    "sweep_bucket",
    "host_provenance",
]
