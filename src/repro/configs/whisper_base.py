"""whisper-base [audio]: 6L enc + 6L dec, d=512, 8H (kv=8), ff=2048,
vocab=51865. Enc-dec; conv audio frontend is a STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec",
        num_layers=6, encoder_layers=6, encoder_seq=1500,
        d_model=512, num_heads=8, num_kv_heads=8, d_ff=2048,
        vocab_size=51865, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke", family="encdec",
        num_layers=2, encoder_layers=2, encoder_seq=32,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=512, rope_theta=1e4, vocab_round=64,
    )
