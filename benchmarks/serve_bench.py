"""Serving latency/throughput: flush policies × bucket executors.

Two questions answered, machine-readably (``BENCH_serve.json``):

* **Policy** — what does the ``max_wait`` deadline policy cost in
  throughput and buy in tail latency? A stream of small clustering queries
  is driven through :class:`ClusterBatcher` under the full-bucket policy
  (buckets flush only when they fill ``max_batch``) and the deadline
  policy (``poll()`` flushes any bucket whose oldest request waited past
  ``max_wait``, padded to a pow2 sub-batch).
* **Executor** — what does pipelined execution buy? The same closed-loop
  stream is pushed through the ``sync`` executor (block per flush) and the
  ``async`` executor (dispatch and keep packing — host packs bucket i+1
  while bucket i computes), plus ``--executor sharded`` to span all local
  devices per flush. Results are asserted bit-identical to the per-graph
  engine in every configuration.

Per-request latency = admit → retire on the engine clock. Policy passes run
twice: the first warms the jit caches (the serving steady state), the
second measures.

The executor comparison is a *steady-state* measurement: one long-lived
batcher per executor (buffer pools and jit caches fully warm — a fresh
engine per pass would charge the async path its pipelined buffer
generations again on every pass), with repeat passes interleaved
(sync, async, sync, ...) so background-load drift on a shared host hits
every executor equally; best-of-N per executor is reported.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py \
          [--graphs 200] [--max-batch 16] [--max-wait 0.05] \
          [--executor sync] [--smoke] [--json BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import build_graph, correlation_cluster, program_cache_info
from repro.core.graph import random_arboric
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest


def make_requests(num_graphs: int, seed: int = 0, n_lo: int = 8,
                  n_hi: int = 96, lam_lo: int = 1, lam_hi: int = 3):
    """(uid, graph, λ) stream. λ rides along like batch_bench's ``lams``:
    real clients (dedup bands, LSH shards) know their arboricity bound, and
    passing it keeps admission off the degeneracy-peeling slow path."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(num_graphs):
        n = int(rng.integers(n_lo, n_hi))
        edges, lam = random_arboric(n, int(rng.integers(lam_lo, lam_hi + 1)),
                                    rng)
        reqs.append((uid, build_graph(n, edges), lam))
    return reqs


def drive(reqs, max_batch: int, max_wait, num_samples: int,
          executor: str = "sync", arrival_gap: float = 0.0, batcher=None):
    """One serving pass; returns (wall_seconds, per-request waits, stats).

    ``arrival_gap`` spaces admissions in time (a Poisson-ish open-loop
    stream approximated by a fixed gap): with it, a bucket that fills
    slowly *ages*, which is exactly the situation the deadline policy
    exists for — the full-bucket policy makes those requests wait for the
    end-of-stream drain. Pass a long-lived ``batcher`` to measure the
    steady state (warm pools and caches) instead of a cold engine.
    """
    if batcher is None:
        batcher = ClusterBatcher(max_batch=max_batch, max_wait=max_wait,
                                 num_samples=num_samples, executor=executor)
    waits = {}

    def account(done):
        now = batcher.clock()
        for r in done:
            waits[r.uid] = now - r.admitted_at

    t0 = time.perf_counter()
    for uid, g, lam in reqs:
        if arrival_gap:
            time.sleep(arrival_gap)
        account(batcher.admit(
            ClusterRequest(uid=uid, graph=g, key=jax.random.PRNGKey(uid),
                           lam=lam)))
        account(batcher.poll())
    account(batcher.flush())
    dt = time.perf_counter() - t0
    assert len(waits) == len(reqs), "requests lost in the engine"
    return dt, np.array([waits[uid] for uid, *_ in reqs]), batcher.stats


def steady_throughput(reqs, max_batch: int, num_samples: int,
                      executors, repeat: int = 5):
    """Steady-state closed-loop graphs/s per executor, interleaved.

    One long-lived batcher per executor (so pools, jit caches and — for
    the pipelined path — the extra in-flight staging generations are all
    warm, as in real serving). Passes alternate between executors
    (sync, async, sync, ...) so background-load drift on a shared host
    degrades every executor's sample set equally; best-of-N per executor
    is reported.
    """
    engines = {name: ClusterBatcher(max_batch=max_batch,
                                    num_samples=num_samples, executor=name)
               for name in executors}
    best = {name: None for name in executors}
    for name in executors:                      # warm pass per executor
        drive(reqs, max_batch, None, num_samples, batcher=engines[name])
    for _ in range(repeat):
        for name in executors:
            dt, _, _ = drive(reqs, max_batch, None, num_samples,
                             batcher=engines[name])
            best[name] = dt if best[name] is None else min(best[name], dt)
    return {name: len(reqs) / t for name, t in best.items()}


def pct(x, q):
    return float(np.percentile(x, q))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="deadline budget in seconds")
    ap.add_argument("--num-samples", type=int, default=1)
    ap.add_argument("--arrival-ms", type=float, default=2.0,
                    help="inter-arrival gap of the simulated request stream")
    ap.add_argument("--executor", choices=["sync", "async", "sharded"],
                    default="sync",
                    help="bucket executor for the policy passes")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable results path ('' to skip)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: fewer graphs, correctness focus")
    args = ap.parse_args()
    n_graphs = 32 if args.smoke else args.graphs
    # Keep the arrival gap in smoke mode: without it the stream outruns
    # max_wait, no deadline flush ever fires, and the CI step would not
    # exercise the partial-flush machinery at all.
    arrival_gap = args.arrival_ms / 1e3

    reqs = make_requests(n_graphs)
    print(f"workload: {n_graphs} graphs, max_batch={args.max_batch}, "
          f"max_wait={args.max_wait * 1e3:.0f}ms, "
          f"arrival gap={arrival_gap * 1e3:.1f}ms, "
          f"executor={args.executor}")

    # Warm every pow2 sub-batch program the workload can hit (deadline
    # flushes run partial buckets, and flush grouping is timing-dependent,
    # so per-policy warm passes alone leave compile spikes in the tail).
    warmer = ClusterBatcher(max_batch=args.max_batch,
                            num_samples=args.num_samples,
                            executor=args.executor)
    t0 = time.perf_counter()
    compiled = warmer.warmup(g for _, g, _ in reqs)
    print(f"warmup: {compiled} bucket programs compiled in "
          f"{time.perf_counter() - t0:.1f}s")

    results = {}
    for label, max_wait in [("full-bucket", None),
                            ("deadline", args.max_wait)]:
        drive(reqs, args.max_batch, max_wait, args.num_samples,
              executor=args.executor)                         # warm pass
        dt, waits, stats = drive(reqs, args.max_batch, max_wait,
                                 args.num_samples, executor=args.executor,
                                 arrival_gap=arrival_gap)
        results[label] = (dt, waits, stats)
        print(f"[{label:11s}] {n_graphs / dt:8.1f} graphs/s   "
              f"wait p50={pct(waits, 50) * 1e3:7.1f}ms  "
              f"p99={pct(waits, 99) * 1e3:7.1f}ms  "
              f"max={waits.max() * 1e3:7.1f}ms   "
              f"flushes={stats.flushes} (deadline={stats.deadline_flushes}) "
              f"padded_slots={stats.padded_slots}")
        if label == "deadline":
            assert stats.deadline_flushes > 0, (
                "deadline policy never fired — the comparison below would "
                "be two full-bucket runs; raise --arrival-ms or lower "
                "--max-wait")

    # Executor comparison: closed-loop steady state, sync vs pipelined
    # (vs the selected executor when it is neither). The async win is the
    # host packing bucket i+1 while bucket i computes and transfers, so it
    # runs on the compute-heavy tier (n∈[100,250], λ≤4) where a flush's
    # device program is comparable to its host-side packing — on the small
    # tier the device is <15% of a flush cycle and there is nothing to
    # pipeline into. The warm drive pass inside steady_throughput compiles
    # exactly the shapes the closed loop hits.
    comp_reqs = make_requests(64 if args.smoke else 160, seed=1,
                              n_lo=100, n_hi=250, lam_lo=2, lam_hi=4)
    exec_names = ["sync", "async"]
    if args.executor not in exec_names:
        exec_names.append(args.executor)
    comparison = steady_throughput(comp_reqs, args.max_batch,
                                   args.num_samples, exec_names,
                                   repeat=3 if args.smoke else 6)
    for name in exec_names:
        print(f"[executor:{name:8s}] {comparison[name]:8.1f} graphs/s "
              "steady-state (closed loop, full buckets, heavy tier)")
    async_speedup = comparison["async"] / comparison["sync"]
    print(f"[executor] async pipelining: {async_speedup:.2f}x over sync")

    # Bit-exactness spot check against the per-graph engine.
    sample = reqs[:: max(1, len(reqs) // 8)]
    batcher = ClusterBatcher(max_batch=args.max_batch,
                             max_wait=args.max_wait,
                             num_samples=args.num_samples,
                             executor=args.executor)
    done = {}
    for uid, g, lam in sample:
        for r in batcher.admit(ClusterRequest(uid=uid, graph=g,
                                              key=jax.random.PRNGKey(uid),
                                              lam=lam)):
            done[r.uid] = r
        for r in batcher.poll():
            done[r.uid] = r
    for r in batcher.flush():
        done[r.uid] = r
    for uid, g, lam in sample:
        ref = correlation_cluster(g, key=jax.random.PRNGKey(uid), lam=lam,
                                  num_samples=args.num_samples)
        assert (done[uid].result.labels == ref.labels).all()
        assert done[uid].result.cost == ref.cost
    print(f"bit-exactness: {len(sample)} sampled requests match the "
          f"per-graph engine under the deadline policy "
          f"({args.executor} executor)")

    dt_full, w_full, s_full = results["full-bucket"]
    dt_dead, w_dead, s_dead = results["deadline"]
    print(f"\nsummary: deadline policy holds p99 wait at "
          f"{pct(w_dead, 99) * 1e3:.1f}ms vs {pct(w_full, 99) * 1e3:.1f}ms "
          f"full-bucket, at {dt_full / dt_dead * 100:.0f}% of full-bucket "
          "throughput")

    if args.json:
        def policy_payload(dt, waits, stats):
            return {
                "gps": n_graphs / dt,
                "wait_p50_ms": pct(waits, 50) * 1e3,
                "wait_p99_ms": pct(waits, 99) * 1e3,
                "wait_max_ms": float(waits.max()) * 1e3,
                "flushes": stats.flushes,
                "deadline_flushes": stats.deadline_flushes,
                "padded_slots": stats.padded_slots,
                "rejected": stats.rejected,
                "in_flight_peak": stats.in_flight_peak,
            }
        payload = {
            "bench": "serve",
            "executor": args.executor,
            "smoke": bool(args.smoke),
            "n_graphs": n_graphs,
            "max_batch": args.max_batch,
            "max_wait_ms": args.max_wait * 1e3,
            "arrival_gap_ms": arrival_gap * 1e3,
            "warmup_programs": compiled,
            "policies": {
                "full_bucket": policy_payload(dt_full, w_full, s_full),
                "deadline": policy_payload(dt_dead, w_dead, s_dead),
            },
            "executor_steady_gps": comparison,
            "async_speedup_vs_sync": async_speedup,
            "program_cache": program_cache_info(),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
