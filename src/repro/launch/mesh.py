"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — the dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


__all__ = ["make_production_mesh", "make_smoke_mesh"]
