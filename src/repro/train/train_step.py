"""Train step: microbatched grad accumulation + AdamW, one jit program.

``accum_steps > 1`` reshapes the global batch (B, S) → (A, B/A, S) and scans
microbatches, accumulating fp32 grads. The per-microbatch reduction keeps
the reduce-scatter of gradients inside the scan body, which XLA overlaps
with the next microbatch's compute (async collectives — the dry-run HLO
shows `all-reduce-start`/`-done` pairs spanning compute).

Optional int8 error-feedback gradient compression (`compress_cross_pod`)
quantizes gradient leaves before the cross-pod reduction and carries the
quantization error to the next step — the standard 4× ICI-traffic trick for
multi-pod DP (see train/compression.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from .compression import compress_decompress
from .optimizer import OptConfig, OptState, opt_init, opt_update


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    err: Any | None        # error-feedback residuals (compression) or None


@dataclasses.dataclass(frozen=True)
class StepConfig:
    accum_steps: int = 1
    compress_cross_pod: bool = False
    accum_dtype: str = "float32"     # grad accumulator (bf16 for >=90B)


def init_train_state(model: Model, key, oc: OptConfig,
                     sc: StepConfig | None = None) -> TrainState:
    params, _ = model.init(key)
    sc = sc or StepConfig()
    err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
           if sc.compress_cross_pod else None)
    return TrainState(params=params, opt=opt_init(params, oc), err=err)


def abstract_train_state(model: Model, oc: OptConfig,
                         sc: StepConfig | None = None):
    """(ShapeDtypeStruct TrainState, spec TrainState) for the dry-run."""
    from jax.sharding import PartitionSpec as P
    from .optimizer import opt_state_specs

    params, specs = model.abstract_params()
    sc = sc or StepConfig()
    sdt = jnp.dtype(oc.state_dtype)
    sds = lambda p, dt: jax.ShapeDtypeStruct(p.shape, dt)
    opt = OptState(
        mu=jax.tree.map(lambda p: sds(p, sdt), params),
        nu=jax.tree.map(lambda p: sds(p, sdt), params),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )
    err = (jax.tree.map(lambda p: sds(p, jnp.float32), params)
           if sc.compress_cross_pod else None)
    state = TrainState(params=params, opt=opt, err=err)
    state_specs = TrainState(params=specs, opt=opt_state_specs(specs),
                             err=specs if sc.compress_cross_pod else None)
    return state, state_specs


def make_train_step(model: Model, oc: OptConfig,
                    sc: StepConfig | None = None):
    """Returns train_step(state, batch) → (state, metrics)."""
    sc = sc or StepConfig()
    accum = sc.accum_steps

    def loss_of(params, mb):
        return model.loss(params, mb)

    def train_step(state: TrainState, batch):
        params = state.params

        if accum == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])
            mbs = jax.tree.map(reshape, batch)

            adt = jnp.dtype(sc.accum_dtype)

            def micro(acc, mb):
                loss_i, g = jax.value_and_grad(loss_of)(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(adt), acc[0], g
                ), acc[1] + loss_i
                return acc, None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)),
                                           mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum

        err = state.err
        if sc.compress_cross_pod:
            grads, err = compress_decompress(grads, err)

        params2, opt2, metrics = opt_update(grads, state.opt, params, oc)
        metrics["loss"] = loss
        return TrainState(params=params2, opt=opt2, err=err), metrics

    return train_step


__all__ = ["TrainState", "StepConfig", "init_train_state",
           "abstract_train_state", "make_train_step"]
