"""Device-side execution layer of the batch engine: programs + executors.

The "how it runs" half of the plan/executor split (packing and bucketing
live in :mod:`repro.core.plan`). Three pieces:

**The fused bucket programs** — one jit program per ``(B, R, W)`` bucket
shape × registered ``(method, objective)`` combination, composed from the
method registry in :mod:`repro.core.programs`: the method's rounds body
(MIS ``lax.while_loop`` for ``'pivot'``, straight-line constant-round
agreement for ``'precluster'``), the objective's cost pass
(``'disagree'`` / ``'minmax'``) and the shared best-of-k argmin run
entirely on device, so only winning labels / costs / sample indices cross
back to the host. Every batch entry is independent of every other, which
is what makes both async overlap and data-parallel sharding
semantics-preserving. (:func:`_batch_pivot_cost_impl` survives as the
pre-registry name of the pivot × disagree composition.)

**The compiled-program cache** — :func:`run_bucket_program` resolves each
``(shape, k, kernel, donation, mesh, method, objective)`` request through
a bounded LRU of jit instances. Methods sharing one *program family*
(``'pivot'`` / ``'pivot_raw'``) share compiled programs, and the legacy
pivot × disagree keys are preserved verbatim so the refactor cannot
fragment a warmed cache. Long-lived servers seeing many bucket shapes hold at
most :func:`program_cache_capacity` compiled programs; evictions and
compiles are counted (:func:`program_cache_info`) instead of growing
memory without limit. The LRU takes *hints* from layers that know more
than the access order: :func:`program_cache_contains` is a non-mutating
probe (the serving cost model prices the compile a candidate flush shape
would pay), :func:`program_cache_touch` refreshes a bucket shape's recency
and :func:`program_cache_pin` / :func:`program_cache_unpin` protect a hot
bucket shape's programs from eviction while cold shapes churn through the
cache (the scheduler's ``on_retire`` heat tracking drives these). Pins are
preferences, not leaks: capacity stays a hard bound — when every resident
program is pinned the LRU victim is evicted anyway.

**Bucket executors** — the :class:`BucketExecutor` protocol decouples the
serving layer from *how* a packed bucket reaches the device:

* :class:`SyncExecutor` — the classic path: dispatch, block, fetch. One
  bucket at a time, results available the moment ``submit`` returns.
* :class:`AsyncExecutor` — non-blocking dispatch returning
  :class:`InFlightBucket` handles; the caller packs/flushes the next
  bucket while the previous one computes and transfers (JAX async
  dispatch). ``retire()`` harvests completed handles without blocking;
  ``drain()`` blocks for everything outstanding.
* :class:`ShardedExecutor` — data-parallel ``shard_map`` over the pow2
  group axis across the local device mesh
  (:func:`repro.core.dist.pow2_device_mesh`), so one flush spans all local
  devices: the MPC "more machines" axis. Group padding is raised to the
  device count so the batch axis splits evenly; padded entries are inert.

All three executors satisfy the same bit-exactness contract as the
per-graph engine — for matching keys, labels / costs / picked sample
indices are identical — because the program they run is the same per-entry
computation (asserted for every executor in ``tests/test_executor.py``).
"""

from __future__ import annotations

import time
import warnings
from collections import OrderedDict, deque
from functools import partial
from typing import Any, Callable, Deque, List, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.util import next_pow2

from .programs import IN_MIS, REMOVED, UNDECIDED, _gather_rows, \
    bucket_impl, method_spec, objective_spec

# ---------------------------------------------------------------------------
# Fused device programs: rounds body + cost pass + best-of-k argmin, composed
# from the method/objective registries in repro.core.programs.
# ---------------------------------------------------------------------------


def _batch_pivot_cost_impl(ell, ranks_p, elig_p, m_edges, k: int,
                           use_kernel: bool,
                           block_rows: Optional[Tuple[int, int]] = None):
    """Pre-registry name of the pivot × disagree bucket program.

    Kept as a thin wrapper over :func:`repro.core.programs.bucket_impl`
    (same signature, bit-identical outputs) for callers that imported the
    fused pipeline directly before the method registry existed.
    """
    return bucket_impl(ell, ranks_p, elig_p, m_edges, k=k,
                       use_kernel=use_kernel, block_rows=block_rows,
                       program="pivot", objective="disagree")


# ---------------------------------------------------------------------------
# Bounded LRU of compiled bucket programs.
# ---------------------------------------------------------------------------

_DEFAULT_CACHE_CAPACITY = 256

_program_cache: "OrderedDict[tuple, Callable]" = OrderedDict()
_program_cache_capacity = _DEFAULT_CACHE_CAPACITY
_program_cache_evictions = 0
_program_cache_compiles = 0
# Pinned (R, W) bucket shapes → pin count. Refcounted because pins are
# process-global while pinners (engines' heat trackers) are not: two
# engines sharing a hot shape must not have one engine's teardown strip
# the other's eviction protection.
_program_cache_pins: dict = {}


def _mesh_cache_key(mesh: Optional[Mesh]):
    return None if mesh is None else tuple(d.id for d in mesh.devices.flat)


def _program_key(shape, k: int, use_kernel: bool, donate: bool,
                 mesh: Optional[Mesh],
                 block_rows: Optional[Tuple[int, int]] = None,
                 program: str = "pivot",
                 objective: str = "disagree") -> tuple:
    """The cache key for one compiled bucket program — single definition so
    :func:`run_bucket_program` and the :func:`program_cache_contains` probe
    can never disagree about identity. ``block_rows`` is the *resolved*
    tuned kernel block pair (None on the jnp path and for untuned
    buckets), so a tuning-cache update yields a new program at the new
    shape instead of mutating a compiled one. ``program`` is the method's
    *program family* (``method_spec(m).program``, so ``'pivot'`` and
    ``'pivot_raw'`` share compiled programs); the default pivot × disagree
    combination keeps the pre-registry 6-tuple key verbatim, so a warmed
    resident cache never fragments across the refactor."""
    base = (tuple(int(s) for s in shape), k, use_kernel, donate,
            _mesh_cache_key(mesh), block_rows)
    if program == "pivot" and objective == "disagree":
        return base
    return base + (program, objective)


def _resolve_block_rows(shape, use_kernel: bool,
                        block_rows=None) -> Optional[Tuple[int, int]]:
    """Static kernel block shapes a bucket program of ``shape`` will bake
    in: the caller's explicit pair, else the tuning-cache winners, else
    None (kernel default — the legacy key, so untuned buckets never
    fragment the program cache). Normalized to None when the kernels are
    not in play at all."""
    if not use_kernel:
        return None
    if block_rows is not None:
        if isinstance(block_rows, (tuple, list)):
            return (int(block_rows[0]), int(block_rows[1]))
        return (int(block_rows), int(block_rows))
    from repro.kernels.autotune import resolve_block_rows

    return resolve_block_rows(shape)


def _key_bucket(key: tuple) -> Tuple[int, int]:
    """(R, W) bucket shape of a cache key's packed (B, R, W) shape."""
    shape = key[0]
    return (shape[1], shape[2])


def _build_program(k: int, use_kernel: bool, donate: bool,
                   mesh: Optional[Mesh],
                   block_rows: Optional[Tuple[int, int]] = None,
                   program: str = "pivot",
                   objective: str = "disagree") -> Callable:
    impl = partial(bucket_impl, k=k, use_kernel=use_kernel,
                   block_rows=block_rows, program=program,
                   objective=objective)
    if mesh is not None:
        axis = mesh.axis_names[0]
        spec = P(axis)
        # check_rep=False: the pinned jax has no replication rule for
        # `while` inside shard_map (same situation as core.dist); every
        # entry is independent, so out specs sharded like the inputs.
        impl = _shard_map(impl, mesh=mesh,
                          in_specs=(spec, spec, spec, spec),
                          out_specs=(spec, spec, spec, spec),
                          check_rep=False)
    return jax.jit(impl, donate_argnums=(0, 1, 2, 3) if donate else ())


def _evict_to_capacity() -> None:
    global _program_cache_evictions
    while len(_program_cache) > _program_cache_capacity:
        # LRU order, skipping pinned bucket shapes; capacity is a hard
        # bound, so when everything left is pinned the LRU loses anyway.
        victim = next((key for key in _program_cache
                       if _key_bucket(key) not in _program_cache_pins),
                      None)
        if victim is None:
            victim = next(iter(_program_cache))
        fn = _program_cache.pop(victim)
        _program_cache_evictions += 1
        clear = getattr(fn, "clear_cache", None)
        if clear is not None:       # drop the compiled executable eagerly
            clear()


def program_cache_size() -> int:
    """Number of compiled bucket programs resident (benchmark: O(#buckets))."""
    return len(_program_cache)


def program_cache_capacity() -> int:
    return _program_cache_capacity


def set_program_cache_capacity(capacity: int) -> int:
    """Bound the compiled-program LRU; returns the previous capacity.

    Long-lived servers seeing many bucket shapes would otherwise accumulate
    one compiled executable per ``(B, R, W, k, kernel, donation, mesh)``
    combination forever. The default (256) is generous — a workload that
    legitimately cycles through more shapes than this pays recompiles on
    the evicted ones (correctness is unaffected; tested).
    """
    global _program_cache_capacity
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    prev = _program_cache_capacity
    _program_cache_capacity = capacity
    _evict_to_capacity()
    return prev


def program_cache_contains(shape, k: int, use_kernel: bool = False,
                           donate: bool = False,
                           mesh: Optional[Mesh] = None,
                           block_rows=None,
                           method: str = "pivot",
                           objective: str = "disagree") -> bool:
    """Non-mutating probe: is this exact bucket program compiled?

    Unlike a real run this never touches the LRU order, so the serving
    cost model can price the compile a candidate (coalesced) flush shape
    would pay without distorting the recency the eviction decision reads.
    ``block_rows`` resolves exactly as :func:`run_bucket_program` does
    (explicit pair > tuning-cache winners > None), and ``method``
    resolves through the registry to its program family, so probe and run
    can never disagree about which program a flush would use.
    """
    resolved = _resolve_block_rows(shape, use_kernel, block_rows)
    return _program_key(shape, k, use_kernel, donate, mesh, resolved,
                        program=method_spec(method).program,
                        objective=objective) in _program_cache


def program_cache_touch(bucket: Tuple[int, int]) -> int:
    """Refresh the LRU recency of every program of one ``(R, W)`` bucket
    shape; returns how many were touched.

    The cache's own order only updates when a program *runs* — the
    scheduler, which sees the request stream, can know a shape is about to
    be hot again before the next run does.
    """
    touched = 0
    for key in [key for key in _program_cache if _key_bucket(key) == bucket]:
        _program_cache.move_to_end(key)
        touched += 1
    return touched


def program_cache_pin(bucket: Tuple[int, int]) -> int:
    """Protect a bucket shape's programs from eviction (scheduler heat
    hint); returns the number currently resident. Pinning is durable —
    programs of this shape compiled later are protected too — and is a
    preference, not a leak: capacity remains a hard bound (see
    :func:`set_program_cache_capacity`). Pins are *refcounted*: each
    ``pin`` needs a matching ``unpin``, so one engine releasing its pins
    never strips a shape another live engine still pins."""
    bucket = (int(bucket[0]), int(bucket[1]))
    _program_cache_pins[bucket] = _program_cache_pins.get(bucket, 0) + 1
    return sum(1 for key in _program_cache if _key_bucket(key) == bucket)


def program_cache_unpin(bucket: Tuple[int, int]) -> bool:
    """Drop one reference to a bucket shape's eviction protection; True if
    the shape was pinned (it stays protected while other pinners remain)."""
    bucket = (int(bucket[0]), int(bucket[1]))
    count = _program_cache_pins.get(bucket, 0)
    if count <= 0:
        return False
    if count == 1:
        del _program_cache_pins[bucket]
    else:
        _program_cache_pins[bucket] = count - 1
    return True


def program_cache_info() -> dict:
    """Cache observability for serving stats / benchmarks."""
    resident = {_key_bucket(key) for key in _program_cache}
    return {
        "size": len(_program_cache),
        "capacity": _program_cache_capacity,
        "evictions": _program_cache_evictions,
        "compiles": _program_cache_compiles,
        "pinned": sorted(_program_cache_pins),
        # Learned compile walls per resident (R, W) shape — the measured
        # priors the serving cost model's compile_charge consumes.
        "compile_wall_ewma_ms": {
            f"{r}x{w}": _compile_walls[(r, w)] * 1e3
            for (r, w) in sorted(resident) if (r, w) in _compile_walls},
    }


# Observed compile walls per (R, W) bucket shape: EWMA over every program
# compiled at that shape (any B/k/kernel variant — the serving cost model
# prices per bucket shape, so that is the learning granularity too).
_compile_walls: dict = {}
_COMPILE_EWMA_ALPHA = 0.3
_last_compile_wall: Optional[float] = None


def consume_compile_wall() -> Optional[float]:
    """Compile wall (seconds) paid by the immediately preceding
    :func:`run_bucket_program` call, or None when it hit a resident
    program. Reading clears the stamp — executors consume it onto the
    in-flight handle so the serving telemetry sees each compile once."""
    global _last_compile_wall
    wall, _last_compile_wall = _last_compile_wall, None
    return wall


def run_bucket_program(ell, ranks_p, elig_p, m_edges, k: int,
                       use_kernel: bool = False, donate: bool = False,
                       mesh: Optional[Mesh] = None, block_rows=None,
                       method: str = "pivot",
                       objective: str = "disagree"):
    """Invoke one fused bucket program through the bounded program cache.

    The single entry point for every executor and the serving-layer warmup,
    so the donation policy and its warning handling live in one place: the
    selection outputs are group-shaped, so XLA cannot alias the
    entry-shaped inputs into them on every backend — donation still
    releases the inputs eagerly instead of holding two generations live,
    and the "not usable" warning is expected, not actionable.

    ``method`` / ``objective`` select the registered rounds body and cost
    pass (:mod:`repro.core.programs`); the method resolves to its program
    family before keying the cache, so family-sharing methods reuse one
    compiled program per shape.

    ``block_rows`` picks the kernel row tiles baked into the program: an
    explicit ``(neighbor_min, label_agree)`` pair, or (default) the tuning
    cache's winners for this packed shape (:mod:`repro.kernels.autotune`),
    or the kernel defaults when untuned. The resolved pair extends the
    program key, so re-tuning compiles a fresh program rather than
    repurposing an old one.

    On a cache miss the first invocation is timed: jit's first call blocks
    through trace + compile, so its wall is the compile wall. The sample
    feeds a per-bucket-shape EWMA (surfaced via ``program_cache_info`` and
    :func:`consume_compile_wall`) that the serving cost model learns
    ``compile_cost_s`` from.

    With JAX's async dispatch this returns device arrays that may still be
    computing; callers that need the values block via ``np.asarray`` (which
    is what :class:`InFlightBucket` does on harvest).
    """
    global _last_compile_wall
    _last_compile_wall = None
    program = method_spec(method).program
    objective_spec(objective)            # fail fast on unknown objectives
    if use_kernel:
        # First import must happen OUTSIDE any trace: the kernels modules
        # create module-level jnp constants, and a first import from inside
        # the traced while-loop body would stage those constants as tracers
        # that leak into every later (untraced) kernel call.
        from repro.kernels import ops  # noqa: F401

    ell = jnp.asarray(ell)
    resolved = _resolve_block_rows(ell.shape, use_kernel, block_rows)
    key = _program_key(ell.shape, k, use_kernel, donate, mesh, resolved,
                       program=program, objective=objective)
    fn = _program_cache.get(key)
    fresh = fn is None
    if fresh:
        global _program_cache_compiles
        _program_cache_compiles += 1
        fn = _build_program(k, use_kernel, donate, mesh, resolved,
                            program=program, objective=objective)
        _program_cache[key] = fn
        _evict_to_capacity()
    else:
        _program_cache.move_to_end(key)
    args = (ell, jnp.asarray(ranks_p), jnp.asarray(elig_p),
            jnp.asarray(m_edges))

    def _invoke():
        if donate:
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                return fn(*args)
        return fn(*args)

    if not fresh:
        return _invoke()
    t0 = time.perf_counter()
    out = _invoke()
    wall = time.perf_counter() - t0
    bucket = _key_bucket(key)
    prev = _compile_walls.get(bucket)
    _compile_walls[bucket] = wall if prev is None else (
        _COMPILE_EWMA_ALPHA * wall + (1.0 - _COMPILE_EWMA_ALPHA) * prev)
    _last_compile_wall = wall
    return out


# ---------------------------------------------------------------------------
# Executors.
# ---------------------------------------------------------------------------


class InFlightBucket:
    """Handle for one dispatched bucket program.

    Holds the (possibly still computing) device outputs, the submitter's
    ``payload`` context, and the staging lease pinning the host buffers
    that fed the program. ``result()`` blocks for the outputs, converts
    them to numpy, and only then releases the lease — the invariant that
    keeps overlapped flushes from refilling a buffer still in flight.

    Per-flush latency telemetry rides on the handle: ``shape`` is the
    packed ``(B, R, W)``, ``assemble_seconds`` the host bucket-assembly
    time (stamped by :func:`pack_and_submit`; the per-request row *build*
    happens at admission and is accounted there), ``submitted_at`` the
    dispatch wall-clock stamp, and ``wall_seconds`` the submit→fetch wall
    time, filled in when the outputs are first fetched. The serving layer
    feeds these into its :class:`~repro.serve.scheduler.FlushTelemetry`
    so scheduling policies can adapt to observed flush latency.
    """

    __slots__ = ("payload", "_outputs", "_fetched", "_lease",
                 "shape", "assemble_seconds", "submitted_at",
                 "wall_seconds", "inflight_at_submit", "compile_seconds",
                 "method", "objective")

    def __init__(self, outputs, payload: Any = None, lease=None,
                 shape: Optional[Tuple[int, ...]] = None,
                 assemble_seconds: float = 0.0,
                 submitted_at: Optional[float] = None,
                 inflight_at_submit: int = 1,
                 compile_seconds: Optional[float] = None,
                 method: str = "pivot", objective: str = "disagree"):
        self._outputs = outputs
        self._fetched: Optional[Tuple[np.ndarray, ...]] = None
        self.payload = payload
        self._lease = lease
        self.shape = shape
        self.assemble_seconds = assemble_seconds
        self.submitted_at = submitted_at
        self.wall_seconds: Optional[float] = None
        # Which registered program produced this flush — the serving
        # harvest keys its per-bucket telemetry by (method, R, W).
        self.method = method
        self.objective = objective
        # In-flight depth counting this flush — wall time includes queueing
        # behind the depth−1 earlier flushes, so telemetry divides by this
        # to estimate per-flush service time.
        self.inflight_at_submit = inflight_at_submit
        # Compile wall this flush paid (None on program-cache hits) — the
        # serving layer feeds these into the learned compile-cost stream.
        self.compile_seconds = compile_seconds

    @property
    def pack_seconds(self) -> float:
        """Deprecated pre-PR-8 name of :attr:`assemble_seconds`."""
        return self.assemble_seconds

    @property
    def harvested(self) -> bool:
        return self._fetched is not None

    def ready(self) -> bool:
        """True once the device program has finished (never blocks).

        Also true after a *failed* fetch (``_outputs`` cleared): there is
        nothing left to wait for, and ``result()`` reports the failure.
        """
        if self._fetched is not None or self._outputs is None:
            return True
        probe = getattr(self._outputs[0], "is_ready", None)
        if probe is None:        # very old jax: no non-blocking probe
            return False
        return all(o.is_ready() for o in self._outputs)

    def result(self) -> Tuple[np.ndarray, ...]:
        """(labels, costs, picked, rounds) as numpy; blocks if needed.

        The staging lease is released whether the fetch succeeds or the
        device program surfaces a runtime error here — either way the
        program is finished with its input buffers.
        """
        if self._fetched is None:
            outputs, self._outputs = self._outputs, None
            if outputs is None:
                raise RuntimeError(
                    "bucket program outputs unavailable (an earlier fetch "
                    "of this handle failed)")
            try:
                self._fetched = tuple(np.asarray(o) for o in outputs)
                if self.submitted_at is not None:
                    self.wall_seconds = time.perf_counter() - self.submitted_at
            finally:
                if self._lease is not None:
                    self._lease.release()
                    self._lease = None
        return self._fetched


@runtime_checkable
class BucketExecutor(Protocol):
    """Structural protocol the serving layer schedules bucket flushes by."""

    name: str
    mesh: Optional[Mesh]

    def group_pad(self, n_groups: int) -> int:
        """Padded group count for a bucket of ``n_groups`` graphs."""
        ...

    def submit(self, ell, ranks_p, elig_p, m_edges, k: int,
               use_kernel: bool = False, donate: bool = False,
               payload: Any = None, lease=None,
               track: bool = True,
               assemble_seconds: float = 0.0,
               method: str = "pivot",
               objective: str = "disagree") -> InFlightBucket:
        """Dispatch one packed bucket; returns its in-flight handle.

        ``track=True`` (serving layers) enqueues the handle for delivery
        through ``retire``/``drain``; ``track=False`` (one-shot callers
        that keep their own handle list and harvest via ``result()``)
        leaves queue bookkeeping to the submitter. ``assemble_seconds`` is
        the host bucket-assembly time the submitter measured; it is
        carried on the handle for latency telemetry. ``method`` /
        ``objective`` select the registered bucket program.
        """
        ...

    def retire(self) -> List[InFlightBucket]:
        """Harvest completed handles without blocking."""
        ...

    def drain(self) -> List[InFlightBucket]:
        """Hand back every outstanding handle (callers block via result)."""
        ...

    @property
    def in_flight(self) -> int:
        """Submitted-but-unharvested bucket count (backpressure signal)."""
        ...


class _QueueExecutor:
    """Shared submit/retire bookkeeping for the concrete executors."""

    name = "base"
    mesh: Optional[Mesh] = None

    def __init__(self):
        self._pending: Deque[InFlightBucket] = deque()

    def group_pad(self, n_groups: int) -> int:
        return next_pow2(max(1, n_groups))

    def submit(self, ell, ranks_p, elig_p, m_edges, k: int,
               use_kernel: bool = False, donate: bool = False,
               payload: Any = None, lease=None,
               track: bool = True,
               assemble_seconds: float = 0.0,
               method: str = "pivot",
               objective: str = "disagree") -> InFlightBucket:
        shape = tuple(int(s) for s in np.shape(ell))
        submitted_at = time.perf_counter()
        outputs = run_bucket_program(ell, ranks_p, elig_p, m_edges, k=k,
                                     use_kernel=use_kernel, donate=donate,
                                     mesh=self.mesh, method=method,
                                     objective=objective)
        handle = InFlightBucket(outputs, payload=payload, lease=lease,
                                shape=shape,
                                assemble_seconds=assemble_seconds,
                                submitted_at=submitted_at,
                                inflight_at_submit=len(self._pending) + 1,
                                compile_seconds=consume_compile_wall(),
                                method=method, objective=objective)
        self._post_submit(handle)
        if track:
            self._pending.append(handle)
        return handle

    def _post_submit(self, handle: InFlightBucket) -> None:
        pass

    @property
    def in_flight(self) -> int:
        return len(self._pending)

    def retire(self) -> List[InFlightBucket]:
        done: List[InFlightBucket] = []
        still: Deque[InFlightBucket] = deque()
        while self._pending:
            h = self._pending.popleft()
            if h.ready():
                done.append(h)
            else:
                still.append(h)
        self._pending = still
        return done

    def drain(self) -> List[InFlightBucket]:
        out = list(self._pending)
        self._pending.clear()
        return out


class SyncExecutor(_QueueExecutor):
    """The classic path: dispatch, block, fetch — one bucket at a time.

    ``submit`` returns only after the program has completed and its outputs
    (and staging lease) have been harvested into the handle, so ``retire``
    always finds every submitted handle ready and ``in_flight`` never
    exceeds the unharvested-handle count of the current caller.
    """

    name = "sync"

    def _post_submit(self, handle: InFlightBucket) -> None:
        handle.result()


class AsyncExecutor(_QueueExecutor):
    """Pipelined path: non-blocking dispatch, handles harvested later.

    JAX dispatch is asynchronous — ``submit`` returns as soon as the
    program is enqueued, so the caller overlaps host-side packing of the
    next bucket with device execution and device→host transfer of the
    previous ones. ``retire()`` harvests whatever has finished;
    ``drain()`` hands back everything (harvest order = submission order,
    so results block at most once per handle).
    """

    name = "async"


class ShardedExecutor(AsyncExecutor):
    """Data-parallel path: one flush spans every local device.

    The packed batch axis is split across a 1-D mesh with ``shard_map``
    (the same MPC ⇒ mesh mapping as :mod:`repro.core.dist`, reusing its
    mesh utilities): each device runs the fused program on ``B/D`` entries
    with zero collectives, because batch entries are mutually independent.
    ``group_pad`` raises the group padding to the device count so the pow2
    group axis splits evenly and best-of-k replicas never straddle a shard
    boundary. Dispatch stays asynchronous, so sharding and pipelining
    compose.
    """

    name = "sharded"

    def __init__(self, num_devices: Optional[int] = None,
                 mesh: Optional[Mesh] = None):
        super().__init__()
        if mesh is None:
            from .dist import pow2_device_mesh

            mesh = pow2_device_mesh(num_devices)
        self.mesh = mesh
        self.num_devices = int(mesh.devices.size)
        if self.num_devices & (self.num_devices - 1):
            raise ValueError(
                f"ShardedExecutor needs a power-of-two device count to "
                f"split the pow2 group axis evenly, got mesh of "
                f"{self.num_devices} (use pow2_device_mesh)")

    def group_pad(self, n_groups: int) -> int:
        return max(self.num_devices, next_pow2(max(1, n_groups)))


def pack_and_submit(plans, group_keys, k: int, executor: "BucketExecutor",
                    pool=None, use_kernel: bool = False, payload: Any = None,
                    track: bool = True, objective: str = "disagree"):
    """Pack one bucket and dispatch it through an executor.

    The single lease → ``pack_bucket`` → ``submit`` sequence shared by
    ``correlation_cluster_batch`` and the serving-layer flush, so group
    padding, donation policy and pad accounting cannot drift between the
    two paths. Plans carrying prebuilt :class:`~repro.core.plan.
    PackedRows` assemble by row copies (their ``group_keys`` entries may
    be ``None``); plans without get the legacy derive-at-flush build —
    the measured host time is stamped on the handle as
    ``assemble_seconds`` either way. Returns ``(handle, stats)`` where
    ``stats`` is this one flush's :class:`~repro.core.plan.PackStats` —
    the single source every caller's pad accounting merges from. If
    packing or dispatch raises, the staging lease is released before
    re-raising — nothing was dispatched, so the buffers are genuinely
    free.

    The clustering method rides on the plans themselves
    (``GraphPlan.method``): one flush is one method by construction, so a
    mixed-method plan list is rejected here — the last line of defence
    behind the scheduler's cross-method steal refusal.
    """
    from .plan import estimate_pack_stats, pack_bucket

    R, W = plans[0].bucket
    method = getattr(plans[0], "method", "pivot")
    for p in plans[1:]:
        if getattr(p, "method", "pivot") != method:
            raise ValueError(
                f"cannot pack methods {method!r} and "
                f"{getattr(p, 'method', 'pivot')!r} into one bucket flush: "
                "a bucket program runs exactly one registered method — "
                "cross-method coalescing/stealing is refused")
    g_pad = executor.group_pad(len(plans))
    b_pad = g_pad * k
    lease = pool.acquire(b_pad, R, W) if pool is not None else None
    try:
        t_pack = time.perf_counter()
        ell, ranks, elig, m_edges, _ = pack_bucket(
            plans, group_keys, k=k, g_pad=g_pad,
            staging=lease.arrays if lease is not None else None)
        assemble_seconds = time.perf_counter() - t_pack
        handle = executor.submit(
            ell, ranks, elig, m_edges, k=k, use_kernel=use_kernel,
            donate=pool is not None and pool.donate,
            payload=payload, lease=lease, track=track,
            assemble_seconds=assemble_seconds,
            method=method, objective=objective)
    except BaseException:
        if lease is not None:
            lease.release()
        raise
    # The same pure formula the serving cost model prices candidate
    # flushes with, so priced pads and reported pads can never drift.
    stats = estimate_pack_stats(plans, k, g_pad=g_pad)
    return handle, stats


_EXECUTORS = {
    "sync": SyncExecutor,
    "async": AsyncExecutor,
    "sharded": ShardedExecutor,
}


def make_executor(spec=None) -> BucketExecutor:
    """Resolve an executor argument: name, instance, or None (→ sync)."""
    if spec is None:
        return SyncExecutor()
    if isinstance(spec, str):
        try:
            return _EXECUTORS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown executor {spec!r}; expected one of "
                f"{sorted(_EXECUTORS)}") from None
    if isinstance(spec, BucketExecutor):
        return spec
    raise TypeError(f"executor must be a name or BucketExecutor, "
                    f"got {type(spec).__name__}")


__all__ = [
    "UNDECIDED",
    "IN_MIS",
    "REMOVED",
    "InFlightBucket",
    "BucketExecutor",
    "SyncExecutor",
    "AsyncExecutor",
    "ShardedExecutor",
    "make_executor",
    "pack_and_submit",
    "run_bucket_program",
    "consume_compile_wall",
    "program_cache_size",
    "program_cache_capacity",
    "set_program_cache_capacity",
    "program_cache_info",
    "program_cache_contains",
    "program_cache_touch",
    "program_cache_pin",
    "program_cache_unpin",
]
