"""Admission-time packing split: PackedRows build, assemble, promotion.

The contracts under test (core/plan.py build_packed_rows / pack_bucket /
PackedRows.promote, serve/cluster_batcher.py prebuild admission):

* one canonical edge list per plan — ``plan_graph`` lexsorts once and
  both ``graph_fingerprint`` and the packer consume it, so the PR 6
  fingerprint payload is byte-identical whether or not rows are prebuilt;
* a bucket assembled from prebuilt rows is **byte-identical** to the
  legacy full repack — same ELL/rank/eligibility/m_edges staging tensors,
  not merely the same clustering (device reductions are order-invariant,
  but we hold the stronger property so the bit-exactness contract can
  never hinge on it);
* ``PackedRows.promote`` relayouts into any larger ``(R, W)`` and the
  promoted rows assemble byte-identically to a legacy pack of the
  promoted plans (the coalesced-flush path);
* the serving engine retires bit-identical results with ``prebuild_rows``
  on and off, across executors, kernel paths, partial deadline
  sub-batches and coalesced (stolen) flushes;
* ``_pack_bucket`` survives as a deprecation shim of ``pack_bucket``;
* ``warmup(autotune=True)`` stages its sweep tensors through pool leases
  (and releases them).
"""

import warnings

import jax
import numpy as np
import pytest

from repro.core import (
    BucketBufferPool,
    PackedRows,
    build_graph,
    build_packed_rows,
    correlation_cluster,
    pack_bucket,
    plan_graph,
    promote_plan,
)
from repro.core.api import sample_keys
from repro.core.graph import path, random_arboric
from repro.core.mis import random_permutation_ranks_batch
from repro.core.plan import _pack_bucket, graph_fingerprint, plan_canonical_edges
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
from repro.serve.engine import serve_all
from repro.serve.scheduler import CoalescingPolicy
from repro.util import VirtualClock


def _graphs(num, lo, hi, seed, lam_hi=2):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num):
        n = int(rng.integers(lo, hi))
        edges, lam = random_arboric(n, int(rng.integers(1, lam_hi + 1)), rng)
        out.append(build_graph(n, edges))
    return out


def _path_graphs(ns):
    """Path graphs whose n all land in one (R, W) shape bucket."""
    return [build_graph(n, path(n)) for n in ns]


def _legacy(plans):
    """Strip prebuilt rows so pack_bucket takes the full-repack path."""
    for p in plans:
        p.rows = None
    return plans


def _pack_pair(graphs, k=1, promote=False, seed=0):
    """(prebuilt staging, legacy staging) for the same graphs and keys.

    With ``promote`` the plans are relayed into a bucket one pow2 step
    above the component-wise max of the group — the coalesced-flush path.
    Without it the graphs must already share one shape bucket.
    """
    keys = [sample_keys(jax.random.PRNGKey(seed + i), k)
            for i in range(len(graphs))]
    mk = lambda: [plan_graph(g) for g in graphs]          # noqa: E731
    pre, leg = mk(), _legacy(mk())
    for p, ks in zip(pre, keys):
        p.rows = build_packed_rows(p, ks)
    if promote:
        R = 2 * max(p.R for p in pre)
        W = 2 * max(p.W for p in pre)
        pre = [promote_plan(p, R, W) for p in pre]
        leg = [promote_plan(p, R, W) for p in leg]
    packed_pre = pack_bucket(pre, [None] * len(pre), k=k)
    packed_leg = pack_bucket(leg, keys, k=k)
    return packed_pre, packed_leg


def _assert_staging_equal(a, b):
    ell_a, ranks_a, elig_a, m_a, pad_a = a
    ell_b, ranks_b, elig_b, m_b, pad_b = b
    assert (ell_a == ell_b).all()
    assert (ranks_a == ranks_b).all()
    assert (elig_a == elig_b).all()
    assert (m_a == m_b).all()
    assert pad_a == pad_b


# ---------------------------------------------------------------------------
# Staging byte-equality: prebuilt assembly == legacy repack.
# ---------------------------------------------------------------------------


def test_prebuilt_assembly_matches_legacy_pack_bytes():
    _assert_staging_equal(*_pack_pair(_path_graphs([9, 12, 14, 16, 10])))


def test_prebuilt_assembly_matches_legacy_best_of_k():
    _assert_staging_equal(*_pack_pair(_path_graphs([11, 13, 16, 9]), k=3))


def test_promoted_rows_match_legacy_pack_at_promoted_shape():
    # The coalesced-flush relayout: mixed native buckets promoted into one
    # shape a pow2 step above the largest of them.
    graphs = _graphs(4, 5, 14, seed=3)
    _assert_staging_equal(*_pack_pair(graphs, k=2, promote=True))


def test_mixed_prebuilt_and_legacy_bucket():
    graphs = _path_graphs([10, 16, 9, 13, 15, 12])
    keys = [sample_keys(jax.random.PRNGKey(i), 1) for i in range(6)]
    mixed = [plan_graph(g) for g in graphs]
    for i, (p, ks) in enumerate(zip(mixed, keys)):
        p.rows = build_packed_rows(p, ks) if i % 2 == 0 else None
    group_keys = [None if p.rows is not None else ks
                  for p, ks in zip(mixed, keys)]
    legacy = _legacy([plan_graph(g) for g in graphs])
    _assert_staging_equal(pack_bucket(mixed, group_keys, k=1),
                          pack_bucket(legacy, keys, k=1))


def test_staging_reuse_resets_stale_tail():
    # A lease previously filled by a larger group must not leak rows into
    # a smaller all-prebuilt pack (only the tail is re-stamped).
    pool = BucketBufferPool()
    big = [plan_graph(g) for g in _path_graphs([9, 11, 13, 15, 16])]
    R, W = big[0].bucket
    small = big[:2]
    keys = [sample_keys(jax.random.PRNGKey(i), 1) for i in range(5)]
    for p, ks in zip(big, keys):
        p.rows = build_packed_rows(p, ks)
    lease = pool.acquire(8, R, W)
    pack_bucket(big, [None] * 5, k=1, staging=lease.arrays, g_pad=8)
    lease.release()
    lease = pool.acquire(8, R, W)      # same pooled (now dirty) buffers
    reused = pack_bucket(small, [None] * 2, k=1, staging=lease.arrays,
                         g_pad=8)
    lease.release()
    fresh = pack_bucket(small, [None] * 2, k=1, g_pad=8)
    _assert_staging_equal(reused, fresh)


def test_pack_bucket_rejects_mismatched_prebuilt_shape():
    plan = plan_graph(build_graph(6, path(6)))
    plan.rows = build_packed_rows(plan, sample_keys(jax.random.PRNGKey(0), 1))
    bigger = promote_plan(plan, plan.R * 2, plan.W)
    bigger.rows = plan.rows            # stale rows at the old bucket
    with pytest.raises(ValueError, match="prebuilt rows"):
        pack_bucket([bigger], [None], k=1)
    with pytest.raises(ValueError, match="prebuilt rows"):
        pack_bucket([plan], [None], k=2)   # k mismatch


def test_promote_rejects_shrinking():
    plan = plan_graph(build_graph(10, path(10)))
    rows = build_packed_rows(plan, sample_keys(jax.random.PRNGKey(0), 1))
    with pytest.raises(ValueError):
        rows.promote(plan.R // 2, plan.W)


def test_packed_rows_lazy_ranks_match_direct_dispatch():
    plan = plan_graph(build_graph(9, path(9)))
    keys = sample_keys(jax.random.PRNGKey(7), 2)
    rows = build_packed_rows(plan, keys)
    direct = np.asarray(random_permutation_ranks_batch(plan.n, keys))
    assert rows.ranks.shape == (2, plan.R + 1)
    assert (rows.ranks[:, :plan.n] == direct).all()
    assert (rows.ranks[:, plan.n:] == np.iinfo(np.int32).max).all()


# ---------------------------------------------------------------------------
# Canonical edge list shared with the fingerprint (PR 6 contract).
# ---------------------------------------------------------------------------


def test_fingerprint_payload_survives_canonical_sharing():
    for g in _graphs(4, 6, 30, seed=6, lam_hi=3):
        with_cache = plan_graph(g)
        assert with_cache.canonical_edges is not None
        stripped = plan_graph(g)
        stripped.canonical_edges = None      # hand-built-plan fallback
        fp_a = graph_fingerprint(with_cache, jax.random.PRNGKey(1))
        fp_b = graph_fingerprint(stripped, jax.random.PRNGKey(1))
        assert fp_a.digest == fp_b.digest
        # The lazy fallback memoizes the same canonical order.
        assert (plan_canonical_edges(stripped)
                == plan_canonical_edges(with_cache)).all()


# ---------------------------------------------------------------------------
# Deprecation shim.
# ---------------------------------------------------------------------------


def test_pack_bucket_deprecated_shim():
    plans = _legacy([plan_graph(build_graph(6, path(6)))])
    keys = [sample_keys(jax.random.PRNGKey(0), 1)]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shimmed = _pack_bucket(plans, keys, k=1)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    _assert_staging_equal(shimmed,
                          pack_bucket(_legacy(plans), keys, k=1))


# ---------------------------------------------------------------------------
# Serving engine: prebuild on/off bit-exactness.
# ---------------------------------------------------------------------------


def _serve(graphs, prebuild, executor="sync", use_kernel=False, policy=None,
           max_batch=4, num_samples=1, max_wait=None):
    batcher = ClusterBatcher(max_batch=max_batch, max_wait=max_wait,
                             num_samples=num_samples, executor=executor,
                             use_kernel=use_kernel, policy=policy,
                             result_cache=False, prebuild_rows=prebuild)
    reqs = [ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i))
            for i, g in enumerate(graphs)]
    done = {r.uid: r.result for r in serve_all(batcher, reqs)}
    assert len(done) == len(graphs)
    return done, batcher.stats


@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_serving_bitexact_across_executors_and_kernels(executor, use_kernel):
    graphs = _graphs(6, 6, 24, seed=8)
    pre, _ = _serve(graphs, True, executor=executor, use_kernel=use_kernel,
                    num_samples=2)
    leg, stats = _serve(graphs, False, executor=executor,
                        use_kernel=use_kernel, num_samples=2)
    assert stats.latency.total_builds == 0
    for i, g in enumerate(graphs):
        ref = correlation_cluster(g, key=jax.random.PRNGKey(i),
                                  num_samples=2, use_kernel=use_kernel)
        for arm in (pre, leg):
            assert (arm[i].labels == ref.labels).all()
            assert arm[i].cost == ref.cost
            assert arm[i].info["picked_sample"] == ref.info["picked_sample"]


def test_deadline_partial_subbatch_prebuilt_bitexact():
    # max_batch never fills: every flush is a partial deadline sub-batch.
    graphs = _graphs(5, 6, 20, seed=9)
    for prebuild in (True, False):
        clock = VirtualClock()
        batcher = ClusterBatcher(max_batch=64, max_wait=0.01, clock=clock,
                                 result_cache=False, prebuild_rows=prebuild)
        done = {}
        for i, g in enumerate(graphs):
            clock.advance(0.004)
            for r in batcher.admit(ClusterRequest(
                    uid=i, graph=g, key=jax.random.PRNGKey(i))):
                done[r.uid] = r.result
            for r in batcher.poll():
                done[r.uid] = r.result
        for r in batcher.flush():
            done[r.uid] = r.result
        assert batcher.stats.deadline_flushes > 0
        for i, g in enumerate(graphs):
            ref = correlation_cluster(g, key=jax.random.PRNGKey(i))
            assert (done[i].labels == ref.labels).all()
            assert done[i].cost == ref.cost


def test_coalesced_stolen_flush_prebuilt_bitexact():
    # Hot (32, 4) bucket + starved small bucket, aggressive stealing: the
    # stolen requests run at a promoted shape assembled from promoted
    # PackedRows. Identical steal schedule across arms (virtual clock).
    stolen_counts = {}
    for prebuild in (True, False):
        clock = VirtualClock()
        batcher = ClusterBatcher(
            max_batch=8, clock=clock, result_cache=False,
            prebuild_rows=prebuild,
            policy=CoalescingPolicy(8, max_wait=0.01, steal_wait=0.001))
        done = {}
        graphs = {}
        rng = np.random.default_rng(11)
        for i in range(24):
            n = 6 if i % 8 == 0 else int(rng.integers(17, 30))
            graphs[i] = build_graph(n, path(n))
            clock.advance(0.002)
            for r in batcher.admit(ClusterRequest(
                    uid=i, graph=graphs[i], key=jax.random.PRNGKey(i))):
                done[r.uid] = r.result
            for r in batcher.poll():
                done[r.uid] = r.result
        for r in batcher.flush():
            done[r.uid] = r.result
        assert batcher.stats.stolen_requests > 0
        stolen_counts[prebuild] = batcher.stats.stolen_requests
        for i, g in graphs.items():
            ref = correlation_cluster(g, key=jax.random.PRNGKey(i))
            assert (done[i].labels == ref.labels).all()
            assert done[i].cost == ref.cost
    assert stolen_counts[True] == stolen_counts[False]


# ---------------------------------------------------------------------------
# Warmup autotune sweep: staged through pool leases.
# ---------------------------------------------------------------------------


def test_warmup_autotune_sweeps_through_pool_lease(tmp_path):
    from repro.kernels import autotune as at

    prev = at.set_tuning_cache(
        at.TuningCache(path=str(tmp_path / "tuning.json")))
    try:
        batcher = ClusterBatcher(max_batch=4)
        graphs = _graphs(3, 20, 24, seed=12)
        compiled = batcher.warmup(graphs, autotune=True,
                                  candidates=(16, 32), repeats=1)
        assert compiled > 0
        # The sweep leased (and released) pool staging instead of packing
        # into ad-hoc buffers: buffers exist, none outstanding.
        assert batcher.pool.leased == 0
        assert batcher.pool.n_buffers > 0
        assert batcher.stats.tuning
    finally:
        at.set_tuning_cache(prev)
