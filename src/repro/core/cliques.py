"""Corollary 32: deterministic O(λ²)-approximation in O(1) MPC rounds.

Algorithm: every connected component (w.r.t. E⁺) that is a *clique* forms one
cluster; every other vertex is a singleton.

O(1)-round MPC realization (broadcast/convergecast trees, §2.1.5): each
vertex v computes ``h[v] = min id over N[v]`` in one convergecast. A label
group ``S = {v : h[v] = h, deg(v) = |S| − 1}`` is exactly a clique connected
component: ``deg(v) = |S|−1`` forbids edges leaving S, and a disjoint union
of ≥2 cliques inside one group would violate the degree equation. Groups
passing the check become clusters; everything else is singleton.

Also hosts the generic masked connected-components routine (min-label
propagation + pointer jumping) used by the Algorithm 2 shattering analysis
(Lemma 18 component-size measurements).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .graph import Graph
from .mis import INF_RANK, _masked_segment_min


@jax.jit
def clique_clustering(g: Graph) -> jnp.ndarray:
    """Corollary 32 clustering labels (deterministic, O(1) MPC rounds)."""
    n = g.n
    own = jnp.arange(n, dtype=jnp.int32)
    # Convergecast 1: min id over N[v] (closed neighbourhood).
    nbr_min = _masked_segment_min(g, own, jnp.ones((n,), bool))
    h = jnp.minimum(own, jnp.where(nbr_min < INF_RANK, nbr_min, own))

    # Group size per candidate label (scatter-add convergecast).
    group_size = jnp.zeros((n,), jnp.int32).at[h].add(1)
    k = group_size[h]
    deg_ok = g.deg == (k - 1)
    # All group members must pass deg_ok — min-reduce a boolean per label.
    ok_per_group = jnp.ones((n,), jnp.int32).at[h].min(deg_ok.astype(jnp.int32))
    accept = (ok_per_group[h] == 1) & (k >= 1)
    return jnp.where(accept, h, own)


@partial(jax.jit, static_argnames=("max_iters",))
def connected_components(g: Graph, mask: jnp.ndarray,
                         max_iters: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Connected components of the subgraph induced by ``mask``.

    Min-label propagation with pointer jumping ⇒ converges in O(log n)
    iterations. Returns (labels, iters); unmasked vertices label themselves.
    """
    n = g.n
    own = jnp.arange(n, dtype=jnp.int32)
    labels0 = own

    def body(state):
        labels, i, _ = state
        # Propagate: min over masked neighbours' labels (masked vertices only).
        nmin = _masked_segment_min(g, labels, mask)
        new = jnp.where(mask & (nmin < INF_RANK), jnp.minimum(labels, nmin), labels)
        # Pointer jump twice: label <- label[label].
        new = jnp.minimum(new, new[new])
        new = jnp.minimum(new, new[new])
        changed = jnp.any(new != labels)
        return new, i + 1, changed

    def cond(state):
        _, i, changed = state
        return changed & (i < max_iters)

    labels, iters, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.int32(0), jnp.bool_(True))
    )
    return labels, iters


def component_sizes(labels: jnp.ndarray, mask: jnp.ndarray, n: int) -> jnp.ndarray:
    """Size of each vertex's component (0 for unmasked vertices)."""
    sizes = jnp.zeros((n,), jnp.int32).at[labels].add(mask.astype(jnp.int32))
    return jnp.where(mask, sizes[labels], 0)


__all__ = ["clique_clustering", "connected_components", "component_sizes"]
