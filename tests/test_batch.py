"""Batched multi-graph engine ≡ per-graph engine, bit-exactly.

The contract under test (core/batch.py): for matching per-graph PRNG keys,
``correlation_cluster_batch`` returns labels and costs identical to looping
``correlation_cluster`` — across shape-bucket boundaries (n = R−1, R, R+1),
degree-capped and raw methods, best-of-k sampling, and both neighbour-min
paths (pure-jnp gather and the batched Pallas kernel)."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    build_graph,
    correlation_cluster,
    correlation_cluster_batch,
    plan_graph,
)
from repro.core import batch as batch_mod
from repro.core.graph import gnp, path, random_arboric, star
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest


def _rand_graph(n, lam, seed):
    edges, _ = random_arboric(n, lam, np.random.default_rng(seed))
    return build_graph(n, edges)


def _assert_matches(g, key, res_batch, **kwargs):
    res_single = correlation_cluster(g, key=key, **kwargs)
    assert (res_batch.labels == res_single.labels).all(), (
        g.n, np.flatnonzero(res_batch.labels != res_single.labels))
    assert res_batch.cost == res_single.cost


# n values straddling the R buckets (8, 16, 32): R−1, R, R+1.
BOUNDARY_NS = [7, 8, 9, 15, 16, 17, 31, 32, 33]


@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_batch_bucket_boundaries_bit_exact(n):
    g = _rand_graph(n, 2, seed=n)
    key = jax.random.PRNGKey(n)
    (res,) = correlation_cluster_batch([g], keys=[key])
    _assert_matches(g, key, res)


@pytest.mark.parametrize("method", ["pivot", "pivot_raw"])
def test_batch_64_graphs_bit_exact(method):
    """Acceptance: ≥64 mixed-shape graphs, labels/costs ≡ per-graph engine."""
    rng = np.random.default_rng(0)
    graphs, keys = [], []
    for i in range(64):
        n = int(rng.integers(4, 70))
        lam = int(rng.integers(1, 4))
        edges, _ = random_arboric(n, lam, rng)
        graphs.append(build_graph(n, edges))
        keys.append(jax.random.PRNGKey(1000 + i))
    results = correlation_cluster_batch(graphs, keys=keys, method=method)
    assert len(results) == 64
    for g, key, res in zip(graphs, keys, results):
        _assert_matches(g, key, res, method=method)


@pytest.mark.parametrize("method", ["pivot", "precluster"])
def test_minmax_objective_matches_host_oracle(method):
    """objective='minmax' scores the same labels with the worst-vertex
    disagreement: the rounds body is untouched (labels identical to the
    'disagree' run at num_samples=1) and every returned cost equals the
    numpy host oracle. λ=1 inputs keep every vertex under the Theorem 26
    cap, where the device pass and the full-graph oracle agree exactly."""
    graphs = [_rand_graph(n, 1, seed=n) for n in (6, 9, 14, 20, 33)]
    keys = [jax.random.PRNGKey(i) for i in range(len(graphs))]
    res_d = correlation_cluster_batch(graphs, keys=keys, method=method)
    res_m = correlation_cluster_batch(graphs, keys=keys, method=method,
                                      objective="minmax")
    for g, rd, rm in zip(graphs, res_d, res_m):
        assert (rd.labels == rm.labels).all()
        assert rm.cost == batch_mod._minmax_cost_host(g, rm.labels)
        # Min-max is a per-vertex maximum: never above the total.
        assert rm.cost <= rd.cost


def test_batch_degree_cap_active_bit_exact():
    """Star hub exceeds 12λ: the cap must singleton it in the batch too."""
    g = build_graph(40, star(40))
    key = jax.random.PRNGKey(3)
    (res,) = correlation_cluster_batch([g], keys=[key])
    _assert_matches(g, key, res)
    assert res.info["high_degree"] == 1


def test_batch_edgeless_graph():
    g = build_graph(5, np.zeros((0, 2), dtype=np.int64))
    (res,) = correlation_cluster_batch([g])
    assert (res.labels == np.arange(5)).all()
    assert res.cost == 0


def test_batch_num_samples_matches_single():
    graphs = [_rand_graph(n, 2, seed=n) for n in (10, 20, 30)]
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    results = correlation_cluster_batch(graphs, keys=keys, num_samples=4)
    for g, key, res in zip(graphs, keys, results):
        _assert_matches(g, key, res, num_samples=4)
        assert res.info["num_samples"] == 4


def test_batch_kernel_path_bit_exact():
    graphs = [_rand_graph(n, 2, seed=n) for n in (9, 16, 33)]
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    jnp_res = correlation_cluster_batch(graphs, keys=keys, use_kernel=False)
    ker_res = correlation_cluster_batch(graphs, keys=keys, use_kernel=True)
    for a, b in zip(jnp_res, ker_res):
        assert (a.labels == b.labels).all()
        assert a.cost == b.cost


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 40), p=st.floats(0.05, 0.5), seed=st.integers(0, 99))
def test_batch_property_bit_exact(n, p, seed):
    rng = np.random.default_rng(seed)
    g = build_graph(n, gnp(n, p, rng))
    key = jax.random.PRNGKey(seed)
    # Batch alongside a second graph so the bucket is genuinely multi-graph.
    g2 = _rand_graph(max(4, n // 2), 1, seed + 1)
    res = correlation_cluster_batch([g, g2],
                                    keys=[key, jax.random.PRNGKey(seed + 1)])
    _assert_matches(g, key, res[0])
    _assert_matches(g2, jax.random.PRNGKey(seed + 1), res[1])


def test_batch_compile_count_tracks_buckets_not_graphs():
    """Bucketing contract: compiles grow with #buckets, not #graphs."""
    before = batch_mod.program_cache_size()
    # 24 path graphs in exactly two (R, W) buckets (max degree 2 ⇒ W = 4).
    graphs = [build_graph(10, path(10)) for _ in range(12)]
    graphs += [build_graph(20, path(20)) for _ in range(12)]
    keys = [jax.random.PRNGKey(i) for i in range(24)]
    results = correlation_cluster_batch(graphs, keys=keys)
    buckets = {r.info["bucket"] for r in results}
    assert len(buckets) == 2
    added = batch_mod.program_cache_size() - before
    assert added <= len(buckets), (
        f"{added} compiles for {len(buckets)} buckets / {len(graphs)} graphs")


def test_plan_graph_width_bounded_by_degree_cap():
    """The eligible-induced width is capped at 12λ (ε=2) — the ELL padding
    bound that makes shape bucketing viable (paper Theorem 26)."""
    g = build_graph(60, star(60))
    plan = plan_graph(g, method="pivot", eps=2.0, lam=1)
    assert plan.wreq == 0           # hub singled out, leaves isolated
    assert plan.W == batch_mod.MIN_WIDTH
    raw = plan_graph(g, method="pivot_raw")
    assert raw.wreq == 59           # no cap: hub row is full width


def test_cluster_batcher_bit_exact_and_flushes():
    rng = np.random.default_rng(5)
    batcher = ClusterBatcher(max_batch=4)
    reqs = []
    for i in range(11):
        n = int(rng.integers(5, 40))
        edges, _ = random_arboric(n, 2, rng)
        req = ClusterRequest(uid=i, graph=build_graph(n, edges),
                             key=jax.random.PRNGKey(i))
        reqs.append(req)
        batcher.submit(req)
    batcher.flush_all()
    assert batcher.pending() == 0
    assert all(r.done for r in reqs)
    for r in reqs:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)
    assert batcher.stats.clustered == 11
    assert batcher.stats.flushes >= 1


def test_dedup_batched_matches_sharded_single():
    """Component-sharded batch dedup ≡ clustering each shard individually."""
    from repro.data.dedup import (dedup_corpus_batched, minhash_signatures,
                                  shard_similarity_graph, similarity_edges)
    from repro.data.synthetic import synthetic_corpus

    corpus = synthetic_corpus(n_docs=60, dup_fraction=0.5, mutate_p=0.05,
                              seed=7)
    res = dedup_corpus_batched(corpus, threshold=0.45, seed=7)
    sigs = minhash_signatures(corpus.docs, num_hashes=64, seed=7)
    edges = similarity_edges(sigs, threshold=0.45)
    shards = shard_similarity_graph(len(corpus.docs), edges)
    expect = np.arange(len(corpus.docs), dtype=np.int32)
    total = 0
    for i, (ids, local) in enumerate(shards):
        g = build_graph(len(ids), local)
        single = correlation_cluster(
            g, key=jax.random.fold_in(jax.random.PRNGKey(7), i),
            num_samples=4)
        expect[ids] = ids[single.labels]
        total += single.cost
    assert (res.labels == expect).all()
    assert res.clustering.cost == total
