"""Dry-run sweep driver: every (arch × shape × mesh) cell in a subprocess.

Each cell runs in a fresh process (jax locks the device count on first init,
and a crashed compile must not take down the sweep). Results land in
``artifacts/dryrun/<arch>__<shape>__<pods>.json``; ``--summarize`` renders
the EXPERIMENTS.md tables from the accumulated JSON.

    PYTHONPATH=src python -m repro.launch.sweep --run [--multi-pod-only]
    PYTHONPATH=src python -m repro.launch.sweep --summarize
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ART = Path("artifacts/dryrun")


def _arch_shapes():
    from repro.configs import ARCH_NAMES, SHAPES
    return [(a, s) for a in ARCH_NAMES for s in SHAPES]


def cell_path(arch: str, shape: str, multi_pod: bool) -> Path:
    pods = "pod2" if multi_pod else "pod1"
    return ART / f"{arch}__{shape}__{pods}.json"


def run_cell_subprocess(arch: str, shape: str, multi_pod: bool,
                        timeout: int = 1800, extra=()) -> dict:
    out = cell_path(arch, shape, multi_pod)
    out.parent.mkdir(parents=True, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", str(out)]
    if multi_pod:
        cmd.append("--multi-pod")
    cmd.extend(extra)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout)
        if out.exists():
            res = json.loads(out.read_text())
        else:
            res = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                   "status": "error",
                   "error": (proc.stderr or proc.stdout)[-2000:]}
    except subprocess.TimeoutExpired:
        res = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "status": "timeout", "timeout_s": timeout}
    res["wall_s"] = round(time.time() - t0, 1)
    out.write_text(json.dumps(res, indent=2))
    return res


def run_sweep(multi_pod_values=(False, True), skip_done=True,
              only_arch=None, only_shape=None):
    results = []
    for multi_pod in multi_pod_values:
        for arch, shape in _arch_shapes():
            if only_arch and arch != only_arch:
                continue
            if only_shape and shape != only_shape:
                continue
            p = cell_path(arch, shape, multi_pod)
            if skip_done and p.exists():
                prev = json.loads(p.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    results.append(prev)
                    continue
            res = run_cell_subprocess(arch, shape, multi_pod)
            tag = "pod2" if multi_pod else "pod1"
            print(f"[{tag}] {arch:22s} {shape:12s} -> {res['status']:8s} "
                  f"({res.get('wall_s', 0)}s)", flush=True)
            results.append(res)
    return results


def load_all():
    out = []
    for p in sorted(ART.glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            pass
    return out


def summarize(results=None) -> str:
    results = results or load_all()
    lines = []
    lines.append("| arch | shape | mesh | status | GiB/dev | bottleneck | "
                 "t_comp | t_mem | t_coll | useful | frac |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(results, key=lambda r: (r.get("arch", ""),
                                            order.get(r.get("shape"), 9),
                                            r.get("multi_pod", False))):
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "ok":
            m = r["memory"]["per_device_total"] / 2**30
            rf = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok | {m:.2f} | "
                f"{rf['bottleneck']} | {rf['t_compute_s']:.4g} | "
                f"{rf['t_memory_s']:.4g} | {rf['t_collective_s']:.4g} | "
                f"{rf['useful_ratio']:.2f} | {rf['roofline_fraction']:.2f} |")
        else:
            why = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | {mesh} | "
                         f"{r.get('status')} | | {why} | | | | | |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", action="store_true")
    ap.add_argument("--summarize", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--no-skip", action="store_true")
    args = ap.parse_args(argv)
    if args.run:
        pods = (False, True)
        if args.single_pod_only:
            pods = (False,)
        if args.multi_pod_only:
            pods = (True,)
        run_sweep(multi_pod_values=pods, skip_done=not args.no_skip,
                  only_arch=args.arch, only_shape=args.shape)
    if args.summarize or not args.run:
        print(summarize())


if __name__ == "__main__":
    main()
