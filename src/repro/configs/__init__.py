"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

The ten assigned architectures (+ the 4-shape grid) live here; every id is
selectable via ``--arch`` in the launch drivers.
"""

from __future__ import annotations

from . import (
    granite_3_2b,
    grok_1_314b,
    llama32_vision_90b,
    olmoe_1b_7b,
    qwen3_8b,
    rwkv6_1_6b,
    smollm_135m,
    stablelm_12b,
    whisper_base,
    zamba2_2_7b,
)
from .base import SHAPES, ModelConfig, ShapeConfig, supports_shape

_MODULES = {
    "whisper-base": whisper_base,
    "qwen3-8b": qwen3_8b,
    "granite-3-2b": granite_3_2b,
    "stablelm-12b": stablelm_12b,
    "smollm-135m": smollm_135m,
    "olmoe-1b-7b": olmoe_1b_7b,
    "grok-1-314b": grok_1_314b,
    "zamba2-2.7b": zamba2_2_7b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "llama-3.2-vision-90b": llama32_vision_90b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return _MODULES[name].config()


def get_smoke(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return _MODULES[name].smoke()


def all_cells():
    """Every (arch, shape) cell in the assignment grid (incl. skipped, with
    reason)."""
    cells = []
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES.values():
            ok, why = supports_shape(cfg, shape)
            cells.append((name, shape.name, ok, why))
    return cells


__all__ = [
    "ARCH_NAMES",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke",
    "supports_shape",
    "all_cells",
]
