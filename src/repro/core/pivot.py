"""PIVOT correlation clustering (Ailon–Charikar–Newman) via greedy MIS.

PIVOT = greedy MIS w.r.t. a uniform-at-random permutation, where each MIS
vertex (pivot) captures its surviving positive neighbours. 3-approximation
in expectation (bad-triangle charging). Three execution engines:

* ``engine='rounds'``   — plain round-parallel MIS (O(log n) depth w.h.p.)
* ``engine='phased'``   — Algorithm 1 scheduling (the paper's contribution);
                          identical output, better MPC round accounting.
* ``engine='sequential'`` — host oracle (tests / tiny inputs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .mis import (
    IN_MIS,
    assign_to_min_rank_mis_neighbor,
    greedy_mis_parallel,
    pivot_sequential,
    random_permutation_ranks,
)
from .phases import RoundLedger, algorithm1


@dataclasses.dataclass
class PivotResult:
    labels: np.ndarray           # (n,) cluster ids (pivot vertex ids)
    in_mis: np.ndarray           # (n,) bool pivot mask
    depth: int                   # realized parallel dependency depth
    ledger: Optional[RoundLedger]  # MPC round accounting (phased engine)


def pivot(g: Graph, key: jax.Array, engine: str = "rounds",
          eligible: Optional[jnp.ndarray] = None,
          subroutine: str = "alg3", use_kernel: bool = False) -> PivotResult:
    """Run PIVOT on the positive graph ``g``.

    ``eligible`` restricts to an induced subgraph (Theorem 26 degree cap);
    ineligible vertices come back as singletons labelled by their own id.
    """
    n = g.n
    ranks = random_permutation_ranks(n, key)

    if engine == "sequential":
        if eligible is not None:
            raise ValueError("sequential engine does not support eligible mask")
        labels = pivot_sequential(g, np.asarray(ranks))
        in_mis = labels == np.arange(n)
        return PivotResult(labels=labels, in_mis=in_mis, depth=-1, ledger=None)

    if engine == "phased":
        if eligible is not None:
            raise ValueError("phased engine composes with the degree cap at "
                             "the api layer (it re-ranks the subgraph)")
        state, ranks, ledger = algorithm1(g, ranks=ranks, subroutine=subroutine)
        in_mis = state.status == IN_MIS
        labels = assign_to_min_rank_mis_neighbor(g, ranks, in_mis)
        ledger.extra_rounds += 1.0  # capture convergecast
        return PivotResult(
            labels=np.asarray(labels),
            in_mis=np.asarray(in_mis),
            depth=int(state.rounds),
            ledger=ledger,
        )

    if engine != "rounds":
        raise ValueError(f"unknown engine {engine!r}")

    state = greedy_mis_parallel(g, ranks, eligible=eligible, use_kernel=use_kernel)
    in_mis = state.status == IN_MIS
    labels = assign_to_min_rank_mis_neighbor(g, ranks, in_mis)
    if eligible is not None:
        own = jnp.arange(n, dtype=jnp.int32)
        labels = jnp.where(eligible, labels, own)
    return PivotResult(
        labels=np.asarray(labels),
        in_mis=np.asarray(in_mis),
        depth=int(state.rounds),
        ledger=None,
    )


__all__ = ["PivotResult", "pivot"]
