"""Training driver: end-to-end loop with checkpoint/restart + dedup stage.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 50 --ckpt-dir /tmp/run1 [--resume] [--fail-at 30]

At laptop scale this runs the reduced (smoke) configs on whatever devices
exist; on a pod the same driver takes ``--production-mesh`` and the full
config. ``--fail-at`` raises a simulated host failure mid-run to exercise
the restart path (the integration test does exactly this and asserts the
loss trajectory is bitwise-identical to an uninterrupted run).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, get_smoke
from repro.data.dedup import dedup_corpus
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.data.synthetic import synthetic_corpus, token_stream
from repro.models import RunConfig, build_model, mesh_axis_sizes, resolve_plan
from repro.models.sharding import ShardingPlan
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.fault import SimulatedFailure, StepWatchdog
from repro.train.optimizer import OptConfig
from repro.train.train_step import (StepConfig, init_train_state,
                                    make_train_step)


def build_pipeline(cfg, seq_len: int, global_batch: int, dedup: bool = True,
                   seed: int = 0) -> TokenPipeline:
    corpus = synthetic_corpus(n_docs=300, vocab=cfg.vocab_size,
                              dup_fraction=0.4, seed=seed)
    keep = None
    if dedup:
        res = dedup_corpus(corpus, threshold=0.5)
        keep = res.keep
    stream = token_stream(corpus, keep=keep)
    # repeat stream to cover the requested steps
    reps = max(1, (global_batch * (seq_len + 1) * 4) // max(1, len(stream)))
    stream = np.tile(stream, reps + 1)
    return TokenPipeline(stream, PipelineConfig(seq_len=seq_len,
                                                global_batch=global_batch,
                                                seed=seed))


def run(arch: str, smoke: bool, steps: int, ckpt_dir: str | None,
        resume: bool, fail_at: int | None, seq_len: int, global_batch: int,
        ckpt_every: int = 10, dedup: bool = True, seed: int = 0,
        log_every: int = 5) -> dict:
    cfg = get_smoke(arch) if smoke else get_config(arch)
    rc = RunConfig(attn_impl="naive" if smoke else "chunked",
                   loss_chunk=min(256, seq_len), ssd_chunk=16,
                   rwkv_impl="scan" if smoke else "chunked")
    model = build_model(cfg, plan=ShardingPlan.null(), rc=rc,
                        param_dtype=jnp.float32)
    oc = OptConfig(lr=1e-3, warmup_steps=5, total_steps=max(steps, 10))
    sc = StepConfig(accum_steps=1)

    pipe = build_pipeline(cfg, seq_len, global_batch, dedup=dedup, seed=seed)
    state = init_train_state(model, jax.random.PRNGKey(seed), oc, sc)
    start_step = 0
    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        state, manifest = restore_checkpoint(ckpt_dir, state)
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, oc, sc))
    watchdog = StepWatchdog()
    losses = []
    for step in range(start_step, steps):
        batch = pipe.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "encdec":
            batch["audio_embeds"] = jnp.zeros(
                (global_batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (global_batch, cfg.num_image_tokens, cfg.d_model),
                jnp.float32)
        watchdog.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        straggler = watchdog.stop()
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}"
                  + (" [straggler]" if straggler else ""), flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, state,
                            extra={"arch": arch, "loss": loss})
        if fail_at is not None and step + 1 == fail_at:
            raise SimulatedFailure(f"simulated host failure at step {step+1}")
    return {"losses": losses, "final_step": steps}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--no-dedup", action="store_true")
    args = ap.parse_args(argv)
    run(args.arch, smoke=args.smoke, steps=args.steps,
        ckpt_dir=args.ckpt_dir, resume=args.resume, fail_at=args.fail_at,
        seq_len=args.seq_len, global_batch=args.global_batch,
        ckpt_every=args.ckpt_every, dedup=not args.no_dedup)


if __name__ == "__main__":
    main()
