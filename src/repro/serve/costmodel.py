"""Flush cost model: price a scheduling decision before committing to it.

The paper's MPC bound is an accounting argument — every machine's O(n^δ)
memory budget per round is spent on useful work, and the constant-round
results (Cohen-Addad et al.; Behnezhad et al.) follow from pricing exactly
what each round carries. The serving analogue: a flush is a round, its
``(B, R, W)`` tensor is the memory budget, and a work-stealing decision
that promotes a starving request into a hot flush *changes the budget* —
pow2 group inflation adds empty device entries, promoted rows pad every
stolen entry to the larger ``R``, and an inflated batch axis may hit a
bucket program that was never compiled. The age-only
:class:`~repro.serve.scheduler.CoalescingPolicy` ignores all of that; this
module prices it, from inputs the serving stack already has:

* **Padding** — the same pure ``PackStats`` formula the packer reports
  real flushes with (:func:`repro.core.plan.estimate_pack_stats`),
  differenced between the with-steal and without-steal packs. The
  marginal quantities reduce to count arithmetic over bucket keys (see
  :meth:`FlushCostModel.price_steal`), so pricing needs no tensors — and
  what it prices is exactly what ``stats.padded_slots`` will later report
  (locked down in ``tests/test_scheduler.py``'s pad-accounting test).
* **Service time** — the per-bucket / global EWMAs of
  :class:`~repro.serve.scheduler.FlushTelemetry`, already stamped on every
  harvested flush by the executor layer. Since the admission-time packing
  split these walls cover bucket *assembly* + device time only — the
  per-request row build happens at admission, in telemetry's separate
  ``build`` stream — so the EWMAs price exactly what a flush costs, not
  host work that would have been paid regardless of the steal. A
  configurable floor (``service_floor_s``) acts as a pessimistic prior
  for simulations and deterministic benches.
* **Compile probability** — :func:`repro.core.executor.
  program_cache_contains`, a non-mutating probe of the bounded program
  LRU: stealing is only charged a compile when it inflates the batch axis
  to a shape whose program is not resident.

The model is deliberately conservative and symmetric to the bit-exactness
contract: it only decides *whether* a steal happens, never what a flush
computes, and when telemetry is cold (no EWMA, no floor) it abstains —
the cost-aware policy then degrades to plain age-only coalescing.

:class:`ShapeHeat` is the second half of the budget story: the scheduler
watches which bucket shapes retire often and feeds that heat to the
program cache's ``touch``/``pin`` surface, so a hot shape's compiled
programs outlive a churn of one-off cold shapes the blind LRU would let
evict them.
"""

from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Callable, Optional, Sequence, Tuple

from repro.util import next_pow2

# Queue identity: (method, R, W), matching the scheduler's BucketKey. The
# pricing formulas only use the trailing shape pair (`bucket[-2:]`) plus
# the method prefix for the program-cache probe, so legacy bare (R, W)
# keys are tolerated (the prefix defaults to the 'pivot' program family).
BucketKey = Tuple[str, int, int]


@dataclasses.dataclass(frozen=True)
class FlushCost:
    """Priced outcome of one candidate steal set (all values marginal,
    relative to the same flush running without the steal).

    ``priced=False`` means the model abstained (cold telemetry): the
    caller should fall back to its unpriced behaviour.
    """

    benefit_s: float          # deadline slack saved + avoided own flushes
    pad_cost_s: float         # est. device time of added pad entries + rows
    compile_cost_s: float     # expected compile charge of the inflated B
    pad_entries_added: int    # marginal empty entries ((B1−B0) − stolen·k)
    vertex_waste_added: int   # Σ (R − R_src) over stolen groups (rows)
    priced: bool = True
    # Portion of benefit_s credited for source-bucket flushes this steal
    # avoids (observed compile-free walls only — zero for cold sources).
    own_flush_credit_s: float = 0.0

    @property
    def total_cost_s(self) -> float:
        return self.pad_cost_s + self.compile_cost_s

    def accepts(self, hurdle: float = 1.0) -> bool:
        """True when the steal pays for itself (or the model abstained)."""
        if not self.priced:
            return True
        return self.benefit_s >= hurdle * self.total_cost_s


_ABSTAIN = FlushCost(benefit_s=0.0, pad_cost_s=0.0, compile_cost_s=0.0,
                     pad_entries_added=0, vertex_waste_added=0, priced=False)


class FlushCostModel:
    """Prices candidate steals for :class:`~repro.serve.scheduler.
    CostAwareCoalescingPolicy`.

    Args:
      compile_cost_s: *static prior* charged when the steal inflates the
        batch axis to a ``(B, R, W)`` shape with no resident compiled
        program and no compile wall has been observed yet; once telemetry
        carries observed compile walls the learned per-shape EWMA replaces
        this prior (only meaningful once :meth:`bind_engine` has provided
        the exact program signature; unbound models never charge a
        compile).
      service_floor_s: lower bound on the assumed flush service time. The
        default 0.0 makes pricing purely telemetry-driven; simulations and
        deterministic benches set a pessimistic floor so decisions do not
        depend on host noise.
      hurdle: benefit must be at least ``hurdle ×`` cost to accept — >1
        biases against stealing, <1 toward it.
    """

    def __init__(self, compile_cost_s: float = 0.1,
                 service_floor_s: float = 0.0, hurdle: float = 1.0):
        if compile_cost_s < 0 or service_floor_s < 0:
            raise ValueError("compile_cost_s and service_floor_s must be "
                             ">= 0")
        if hurdle <= 0:
            raise ValueError(f"hurdle must be > 0, got {hurdle}")
        self.compile_cost_s = compile_cost_s
        self.service_floor_s = service_floor_s
        self.hurdle = hurdle
        # Engine binding (how the batcher actually runs flushes) — filled
        # in by ClusterBatcher via the policy's bind_engine hook.
        self._group_pad: Callable[[int], int] = lambda n: next_pow2(max(1, n))
        self._k = 1
        self._use_kernel = False
        self._donate = False
        self._mesh = None
        self._objective = "disagree"
        self._bound = False

    def bind_engine(self, *, executor=None, num_samples: int = 1,
                    use_kernel: bool = False, donate: bool = False,
                    objective: str = "disagree") -> None:
        """Learn the engine's execution profile (group padding rule and the
        compiled-program signature — including the engine's ``objective``,
        which is part of the program key) so pad and compile pricing match
        what the flush will really run. The *method* half of the signature
        is not bound: it rides in each bucket key, so one model prices
        mixed-method traffic. Called by the batcher at construction; an
        unbound model still prices padding with plain pow2 rules."""
        if executor is not None:
            self._group_pad = executor.group_pad
            self._mesh = getattr(executor, "mesh", None)
        self._k = max(1, int(num_samples))
        self._use_kernel = bool(use_kernel)
        self._donate = bool(donate)
        self._objective = objective
        self._bound = True

    # -- pricing inputs ---------------------------------------------------

    def group_pad(self, n_groups: int) -> int:
        """The engine's padded group count for ``n_groups`` graphs (plain
        pow2 until :meth:`bind_engine` supplies the executor's rule)."""
        return self._group_pad(max(1, n_groups))

    def service_estimate(self, bucket: BucketKey,
                         telemetry) -> Optional[float]:
        """Expected service seconds of one flush of this bucket shape:
        bucket EWMA, falling back to the global EWMA, floored by the
        configured prior. None = genuinely cold (no basis to price)."""
        ewma = telemetry.bucket_ewma_wall(bucket)
        if ewma is None:
            ewma = telemetry.ewma_wall
        if ewma is None:
            return self.service_floor_s if self.service_floor_s > 0 else None
        return max(ewma, self.service_floor_s)

    def compile_charge(self, bucket: BucketKey, b1: int,
                       telemetry=None) -> float:
        """Expected compile cost of running the inflated batch axis ``b1``
        at ``bucket`` — zero when the exact program is resident or the
        model has no binding to know the program signature.

        With ``telemetry`` the charge is *learned*: the per-shape EWMA of
        observed compile walls (fed by the executor's compile stamps via
        :meth:`FlushTelemetry.record_compile`), falling back to the global
        compile EWMA, and only then to the static ``compile_cost_s``
        prior — so warmed tiers are priced at what compiles actually cost
        on this host, not at a guess."""
        if not self._bound:
            return 0.0
        from repro.core.executor import program_cache_contains

        *prefix, R, W = bucket
        method = prefix[0] if prefix else "pivot"
        if program_cache_contains((b1, R, W), self._k,
                                  use_kernel=self._use_kernel,
                                  donate=self._donate, mesh=self._mesh,
                                  method=method,
                                  objective=self._objective):
            return 0.0
        if telemetry is not None:
            learned = telemetry.bucket_ewma_compile(bucket)
            if learned is None:
                learned = telemetry.ewma_compile
            if learned is not None:
                return learned
        return self.compile_cost_s

    # -- the decision -----------------------------------------------------

    def price_steal(self, bucket: BucketKey, count: int,
                    candidates: Sequence[Tuple[BucketKey, float]],
                    max_wait: Optional[float],
                    telemetry) -> FlushCost:
        """Price promoting ``candidates`` into a ``bucket`` flush already
        carrying ``count`` native requests.

        ``candidates`` is ``[(source_bucket, age_seconds), ...]`` — one
        entry per stolen request, in steal order. Benefit is the deadline
        slack saved: a rejected candidate waits out the remainder of its
        own ``max_wait`` budget, so riding this flush saves
        ``max_wait − age`` seconds (its full age when no deadline is
        configured) — plus, per distinct source bucket, the *avoided
        own-flush* service time: absorbing a source's stragglers spares
        the deadline flush that source would otherwise run. That credit
        uses only the source's observed compile-free wall EWMA
        (:meth:`FlushTelemetry.bucket_ewma_wall_xc`) — never the floor or
        the global fallback — so cold sources earn nothing and a
        pessimistic ``service_floor_s`` keeps its one-sided meaning. Cost
        is the marginal padding the promotion adds — pow2 group inflation
        priced at the bucket's observed per-entry service time, plus the
        promoted-row waste of running each stolen entry at the larger
        ``R`` — and the (learned, see :meth:`compile_charge`) compile the
        inflated batch axis would pay if its program is not resident.
        """
        if not candidates:
            return _ABSTAIN
        R, W = bucket[-2:]
        k = self._k
        g0 = self._group_pad(max(1, count))
        g1 = self._group_pad(count + len(candidates))
        b0, b1 = g0 * k, g1 * k
        service = self.service_estimate(bucket, telemetry)

        benefit = 0.0
        vertex_rows = 0
        for src, age in candidates:
            benefit += max(0.0, max_wait - age) if max_wait is not None \
                else max(0.0, age)
            vertex_rows += max(0, R - src[-2])
        pad_entries = (b1 - b0) - len(candidates) * k

        if service is None:
            # Cold engine: nothing to price against — abstain, but still
            # report the count arithmetic for observability.
            return dataclasses.replace(
                _ABSTAIN, pad_entries_added=pad_entries,
                vertex_waste_added=vertex_rows)

        per_entry = service / max(1, b0)
        pad_cost = max(0, pad_entries) * per_entry
        # A stolen entry's rows n..R are dead weight relative to running it
        # at its native R_src; charge the promoted fraction of an entry.
        vertex_cost = sum(
            k * max(0, R - src[-2]) / R for src, _ in candidates
        ) * per_entry
        compile_cost = self.compile_charge(bucket, b1, telemetry) \
            if b1 > b0 else 0.0
        own_flush_credit = 0.0
        xc = getattr(telemetry, "bucket_ewma_wall_xc", None)
        if xc is not None:
            for src in {src for src, _ in candidates}:
                observed = xc(src)
                if observed is not None:
                    own_flush_credit += observed
        return FlushCost(benefit_s=benefit + own_flush_credit,
                         pad_cost_s=pad_cost + vertex_cost,
                         compile_cost_s=compile_cost,
                         pad_entries_added=pad_entries,
                         vertex_waste_added=vertex_rows,
                         own_flush_credit_s=own_flush_credit)


class ShapeHeat:
    """Sliding-window bucket-shape heat → program-cache eviction hints.

    The executor's LRU only sees program *runs*; the scheduler sees the
    retire stream, which says which shapes keep coming back. Each retire
    lands in a bounded window; the ``max_pinned`` most frequent shapes with
    at least ``min_heat`` window hits are pinned in the program cache
    (:func:`repro.core.executor.program_cache_pin`) and every retire
    refreshes its shape's recency (``program_cache_touch``). Shapes that
    stop retiring fall out of the window and are unpinned, so a pin is a
    lease on heat, not a permanent reservation — and the cache capacity
    stays a hard bound regardless.
    """

    def __init__(self, window: int = 64, max_pinned: int = 4,
                 min_heat: int = 3, pin=None, unpin=None, touch=None):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if max_pinned < 0:
            raise ValueError(f"max_pinned must be >= 0, got {max_pinned}")
        if min_heat < 1:
            raise ValueError(f"min_heat must be >= 1, got {min_heat}")
        if pin is None or unpin is None or touch is None:
            from repro.core.executor import (program_cache_pin,
                                             program_cache_touch,
                                             program_cache_unpin)

            pin = pin or program_cache_pin
            unpin = unpin or program_cache_unpin
            touch = touch or program_cache_touch
        self.window = window
        self.max_pinned = max_pinned
        self.min_heat = min_heat
        self._pin, self._unpin, self._touch = pin, unpin, touch
        self._events: deque = deque(maxlen=window)
        self._counts: Counter = Counter()
        self.pinned: set = set()

    def on_retire(self, bucket: BucketKey) -> None:
        """Account one retired flush of ``bucket`` shape and refresh the
        cache hints (touch always; re-derive the pinned hot set).

        Heat is tracked per ``(R, W)`` *shape*, the granularity the
        program cache pins at: a ``(method, R, W)`` queue key is reduced
        to its shape part, so a shape two methods keep hot accumulates
        their combined heat (both methods' programs share the pin)."""
        bucket = (int(bucket[-2]), int(bucket[-1]))
        if len(self._events) == self._events.maxlen:
            old = self._events[0]
            self._counts[old] -= 1
            if self._counts[old] <= 0:
                del self._counts[old]
        self._events.append(bucket)
        self._counts[bucket] += 1
        self._touch(bucket)
        hot = {b for b, c in self._counts.most_common(self.max_pinned)
               if c >= self.min_heat}
        # Pins are refcounted process-global state, so bookkeeping must
        # stay consistent even if a pin/unpin call fails partway: drop a
        # shape from `pinned` *before* unpinning (a retry can then never
        # decrement the same refcount twice and strip another engine's
        # pin) and record a pin only *after* it succeeded. The failure
        # bias is deliberate — an interrupted update can at worst leak a
        # pin (released by `release`/`__del__` eventually), never steal
        # one.
        for b in list(self.pinned - hot):
            self.pinned.discard(b)
            self._unpin(b)
        for b in list(hot - self.pinned):
            self._pin(b)
            self.pinned.add(b)

    def release(self) -> None:
        """Unpin everything this tracker pinned (engine teardown).

        Pins live in the *process-global* program cache, so a tracker that
        dies without releasing would shield its shapes from eviction
        forever — ``__del__`` backstops that, but engines should call
        this (via ``ClusterBatcher.close()``) deterministically.

        Idempotent at the refcount level: each shape is popped from
        ``pinned`` before its single ``unpin``, so calling ``release``
        twice — or ``__del__`` after an explicit ``close()`` — cannot
        double-decrement a refcount and strip a shape another live
        engine still pins.
        """
        while self.pinned:
            self._unpin(self.pinned.pop())

    def __del__(self):
        try:
            self.release()
        except Exception:       # interpreter teardown: modules may be gone
            pass


__all__ = ["FlushCost", "FlushCostModel", "ShapeHeat"]
