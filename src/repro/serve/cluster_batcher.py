"""Continuous batching for clustering-as-a-service, with deadline flushes.

Implements the :class:`repro.serve.engine.ClusterEngine` protocol for graph
queries: incoming graphs are **admitted** into the shape bucket their padded
``(R, W)`` size maps to, a bucket **flushes** through
``correlation_cluster_batch`` the moment it fills ``max_batch`` slots — or,
under the deadline policy, as soon as its oldest request has waited
``max_wait`` seconds — and flushed requests **retire** with their results
attached.

Deadline policy (bounded tail latency)
  A full-bucket-only policy gives great throughput but unbounded latency: a
  request whose bucket never fills waits until end of stream. With
  ``max_wait`` set, :meth:`ClusterBatcher.poll` flushes any bucket whose
  oldest request is past its budget as a *partial* flush. The packer pads
  the partial batch to the next power-of-two sub-batch, so the jit cache
  stays **O(#buckets · log max_batch)** — latency is bounded without
  per-size recompiles. Padding actually performed on the device is reported
  by the packer itself (``PackStats``), so :class:`ClusterStats` can never
  drift from what ran.

Buffer reuse
  All flushes route through one :class:`repro.core.batch.BucketBufferPool`:
  host staging arrays per bucket shape are refilled in place and the device
  program runs with donated inputs, so steady-state serving keeps
  O(#buckets) persistent buffers.

Because the device program is jit-cached per bucket shape, a steady request
stream compiles O(#buckets · log B) programs total no matter how many
graphs flow through — the clustering analogue of a shape-static decode
batch.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BucketBufferPool, correlation_cluster_batch, plan_graph
from repro.core.api import ClusterResult
from repro.core.graph import Graph

from .engine import EngineStats


@dataclasses.dataclass
class ClusterRequest:
    uid: int
    graph: Graph
    key: jax.Array
    lam: Optional[int] = None
    result: Optional[ClusterResult] = None
    done: bool = False
    admitted_at: Optional[float] = None     # engine clock time of admission


@dataclasses.dataclass
class ClusterStats(EngineStats):
    flushes: int = 0
    deadline_flushes: int = 0    # partial flushes forced by max_wait
    clustered: int = 0
    padded_slots: int = 0        # empty device entries, from the packer
    pad_vertex_waste: int = 0    # Σ (R − n) over clustered graphs
    buckets_seen: int = 0        # distinct (R, W) buckets admitted


class ClusterBatcher:
    """Bucketed clustering engine: full-bucket flushes + deadline flushes.

    Implements the :class:`~repro.serve.engine.ClusterEngine` protocol
    (``admit`` / ``flush`` / ``retire`` / ``stats`` / ``pending``), plus
    :meth:`poll` for the ``max_wait`` deadline policy.

    Args:
      max_batch: bucket capacity; a bucket flushes when it holds this many
        requests.
      max_wait: optional deadline in seconds (engine-clock): ``poll()``
        flushes any bucket whose oldest request has waited longer, padded
        to the next power-of-two sub-batch. ``None`` = full buckets only.
      clock: the engine clock (monotonic seconds). Injectable so tests and
        simulators can drive virtual time.
      num_samples: best-of-k PIVOT per request (``< 1`` is coerced to 1;
        the engine itself rejects invalid values).
      pool: buffer pool shared by all flushes (created if omitted).
    """

    def __init__(self, max_batch: int = 64, method: str = "pivot",
                 eps: float = 2.0, num_samples: int = 1,
                 use_kernel: bool = False,
                 max_wait: Optional[float] = None,
                 clock=time.monotonic,
                 pool: Optional[BucketBufferPool] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait is not None and max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_batch = max_batch
        self.method = method
        self.eps = eps
        self.num_samples = max(1, num_samples)
        self.use_kernel = use_kernel
        self.max_wait = max_wait
        self.clock = clock
        self.pool = pool if pool is not None else BucketBufferPool()
        self.buckets: Dict[Tuple[int, int], List[ClusterRequest]] = {}
        self._bucket_keys_seen: set = set()
        self._retired: Deque[ClusterRequest] = deque()
        self.stats = ClusterStats()

    # -- ClusterEngine protocol ------------------------------------------

    def admit(self, req: ClusterRequest,
              now: Optional[float] = None) -> List[ClusterRequest]:
        """Admit a request; returns the retired batch if its bucket flushed.

        Shape/width validation happens here (``plan_graph`` raises for
        graphs exceeding the largest supported bucket) so a bad request
        fails at admission, not inside a later batched flush.
        """
        plan = plan_graph(req.graph, method=self.method, eps=self.eps,
                          lam=req.lam)
        req.lam = plan.lam  # resolved once; the flush reuses it verbatim
        req.admitted_at = self.clock() if now is None else now
        slot_list = self.buckets.setdefault(plan.bucket, [])
        slot_list.append(req)
        self.stats.submitted += 1
        self._bucket_keys_seen.add(plan.bucket)
        self.stats.buckets_seen = len(self._bucket_keys_seen)
        if len(slot_list) >= self.max_batch:
            self._flush(plan.bucket)
        return self.retire()

    def flush(self) -> List[ClusterRequest]:
        """Drain every bucket (end of stream), full or partial."""
        for bucket in list(self.buckets):
            self._flush(bucket)
        return self.retire()

    def retire(self) -> List[ClusterRequest]:
        """Drain finished requests not yet handed back to the caller."""
        out = list(self._retired)
        self._retired.clear()
        return out

    def pending(self) -> int:
        return sum(len(v) for v in self.buckets.values())

    # -- Deadline policy --------------------------------------------------

    def poll(self, now: Optional[float] = None) -> List[ClusterRequest]:
        """Flush buckets whose oldest request has waited past ``max_wait``.

        A no-op without a deadline configured. Partial buckets are padded
        to the next power-of-two sub-batch by the packer, so deadline
        flushes stay within the O(#buckets · log B) compile budget.
        """
        if self.max_wait is None:
            return []
        now = self.clock() if now is None else now
        for bucket, reqs in list(self.buckets.items()):
            if reqs and now - reqs[0].admitted_at >= self.max_wait:
                self._flush(bucket, deadline=True)
        return self.retire()

    def oldest_wait(self, now: Optional[float] = None) -> float:
        """Age of the oldest pending request (0.0 when idle)."""
        now = self.clock() if now is None else now
        ages = [now - reqs[0].admitted_at
                for reqs in self.buckets.values() if reqs]
        return max(ages, default=0.0)

    def warmup(self, graphs) -> int:
        """Precompile every pow2 sub-batch program the workload can hit.

        Deadline flushes run partial buckets at power-of-two sub-batch
        sizes, so a cold engine pays a jit compile the first time each
        ``(G_pad, R, W)`` shape appears — a latency spike exactly where the
        deadline policy promises a bound. JetStream warms its prefill
        buckets ahead of serving for the same reason. Given sample graphs
        covering the expected shape buckets, this compiles all
        ``log2(max_batch)+1`` sub-batch programs per bucket up front (via
        zero-filled dummy tensors; nothing is returned to callers).
        Returns the number of programs compiled.
        """
        from repro.core.batch import program_cache_size, run_bucket_program
        from repro.util import next_pow2

        before = program_cache_size()
        k = self.num_samples
        seen = set()
        for g in graphs:
            bucket = plan_graph(g, method=self.method, eps=self.eps).bucket
            if bucket in seen:
                continue
            seen.add(bucket)
            R, W = bucket
            g_pad = 1
            while g_pad <= next_pow2(self.max_batch):
                b = g_pad * k
                ell = jnp.full((b, R, W), R, dtype=jnp.int32)
                ranks = jnp.full((b, R + 1), np.iinfo(np.int32).max,
                                 dtype=jnp.int32)
                elig = jnp.zeros((b, R + 1), dtype=bool)
                m = jnp.zeros((b,), dtype=jnp.int32)
                jax.block_until_ready(run_bucket_program(
                    ell, ranks, elig, m, k=k, use_kernel=self.use_kernel,
                    donate=self.pool.donate))
                g_pad *= 2
        return program_cache_size() - before

    # -- Internals ---------------------------------------------------------

    def _flush(self, bucket: Tuple[int, int], deadline: bool = False) -> None:
        reqs = self.buckets.pop(bucket, [])
        if not reqs:
            return
        results, pack = correlation_cluster_batch(
            [r.graph for r in reqs],
            keys=[r.key for r in reqs],
            method=self.method,
            eps=self.eps,
            lams=[r.lam for r in reqs],
            num_samples=self.num_samples,
            use_kernel=self.use_kernel,
            pool=self.pool,
            with_stats=True,
        )
        self.stats.flushes += 1
        if deadline:
            self.stats.deadline_flushes += 1
        # Pad accounting straight from the packer — no re-derivation here.
        self.stats.padded_slots += pack.padded_entries
        self.stats.pad_vertex_waste += pack.pad_vertex_waste
        for req, res in zip(reqs, results):
            req.result = res
            req.done = True
            self.stats.clustered += 1
            self.stats.retired += 1
            self._retired.append(req)

    # -- Back-compat aliases (pre-engine API) ------------------------------

    def submit(self, req: ClusterRequest) -> List[ClusterRequest]:
        """Deprecated alias for :meth:`admit`."""
        return self.admit(req)

    def flush_all(self) -> List[ClusterRequest]:
        """Deprecated alias for :meth:`flush`."""
        return self.flush()


__all__ = ["ClusterRequest", "ClusterStats", "ClusterBatcher"]
