"""Mamba2 (SSD) block — chunked parallel scan + single-token decode step.

State space per head (scalar-decay SSD, Mamba2):
    h_t = a_t · h_{t-1} + dt_t · B_t ⊗ x_t          a_t = exp(A · dt_t) ∈ (0,1)
    y_t = C_t · h_t + D · x_t

Chunked algorithm (Mamba2 paper §6): split T into chunks of Q; within a
chunk the quadratic form ``(C Bᵀ ⊙ L) (dt·x)`` with the decay mask
``L[i,j] = exp(cum[i] − cum[j])`` (i ≥ j, computed as exact differences —
stable, exponents ≤ 0); across chunks a short ``lax.scan`` carries the
(H, N, P) state. Chunk size 64 keeps the per-head L tensor at
``B·H·(T/Q)·Q² ≈ 0.3 GB/device`` for the train_4k shape.

Simplification vs the reference CUDA implementation (noted in DESIGN.md):
the causal depthwise conv is applied to the x stream only (not B/C), and
n_groups = 1 (B/C shared across heads) — zamba2-2.7B's configuration.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import Pm, dense_init, rms_norm

CONV_K = 4


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_state


def init_mamba(cfg: ModelConfig, kg, dtype, plan):
    d = cfg.d_model
    d_in, h, n = ssm_dims(cfg)
    proj_out = 2 * d_in + 2 * n + h
    return {
        "in_proj": Pm(dense_init(kg(), (d, proj_out), dtype),
                      plan.P("embed", "ff")),
        "conv_w": Pm(dense_init(kg(), (CONV_K, d_in), dtype, in_axis_size=CONV_K),
                     plan.P(None, "ff")),
        "A_log": Pm(jnp.zeros((h,), jnp.float32), plan.P(None)),
        "D": Pm(jnp.ones((h,), jnp.float32), plan.P(None)),
        "dt_bias": Pm(jnp.zeros((h,), jnp.float32), plan.P(None)),
        "norm": Pm(jnp.ones((d_in,), dtype), plan.P(None)),
        "out_proj": Pm(dense_init(kg(), (d_in, d), dtype),
                       plan.P("ff", "embed")),
    }


def _split_proj(proj, d_in, h, n):
    z = proj[..., :d_in]
    xs = proj[..., d_in:2 * d_in]
    bv = proj[..., 2 * d_in:2 * d_in + n]
    cv = proj[..., 2 * d_in + n:2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xs, bv, cv, dt


def _causal_conv(xs, w, state=None):
    """Depthwise causal conv, kernel CONV_K. xs (B,T,C); state (B,K-1,C)."""
    b, t, c = xs.shape
    if state is None:
        state = jnp.zeros((b, CONV_K - 1, c), xs.dtype)
    xp = jnp.concatenate([state, xs], axis=1)
    out = sum(xp[:, i:i + t, :] * w[i][None, None, :] for i in range(CONV_K))
    new_state = xp[:, t:, :] if t >= CONV_K - 1 else xp[:, -(CONV_K - 1):, :]
    return out, new_state


def ssd_chunked(x, a_log, bv, cv, chunk: int = 64, init_state=None):
    """Chunked SSD. x (B,T,H,P); a_log (B,T,H) = A·dt (≤0);
    bv/cv (B,T,N). Returns y (B,T,H,P), final state (B,H,N,P)."""
    b, t, h, p = x.shape
    n = bv.shape[-1]
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        bv = jnp.pad(bv, ((0, 0), (0, pad), (0, 0)))
        cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0)))
    tt = t + pad
    nc = tt // chunk
    xq = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    aq = a_log.reshape(b, nc, chunk, h).astype(jnp.float32)
    bq = bv.reshape(b, nc, chunk, n).astype(jnp.float32)
    cq = cv.reshape(b, nc, chunk, n).astype(jnp.float32)

    cum = jnp.cumsum(aq, axis=2)                      # (B,nc,Q,H) inclusive
    # Intra-chunk: scores[i,j] = (C_i·B_j)·exp(cum_i − cum_j), i ≥ j.
    cb = jnp.einsum("bcin,bcjn->bcij", cq, bq)        # (B,nc,Q,Q)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    l_mask = jnp.where(causal, jnp.exp(diff), 0.0)    # exponents ≤ 0: stable
    scores = cb[..., None] * l_mask                   # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xq)

    # Chunk summary states and inter-chunk scan.
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bq, decay_to_end, xq)
    total_decay = jnp.exp(cum[:, :, -1, :])           # (B,nc,H)

    def scan_fn(s_prev, inp):
        s_c, dec = inp                                # (B,H,N,P), (B,H)
        s_new = s_prev * dec[..., None, None] + s_c
        return s_new, s_prev

    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    s_final, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total_decay, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)             # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cq, jnp.exp(cum), s_prevs)
    y = (y_intra + y_inter).reshape(b, tt, h, p)[:, :t]
    return y, s_final


def ssd_step(state, x_t, a_t, b_t, c_t):
    """One decode step. state (B,H,N,P); x_t (B,H,P); a_t (B,H);
    b_t/c_t (B,N)."""
    state = state * a_t[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", b_t, x_t)
    y = jnp.einsum("bn,bhnp->bhp", c_t, state)
    return state, y


class MambaCache(NamedTuple):
    conv: jnp.ndarray   # (B, K-1, d_in)
    ssm: jnp.ndarray    # (B, H, N, P)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, h, n = ssm_dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, CONV_K - 1, d_in), dtype),
        ssm=jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
    )


def mamba_block(params, cfg: ModelConfig, x, cache: MambaCache | None = None,
                chunk: int = 64):
    """Full-sequence Mamba2 block. x (B,T,d) → (B,T,d), new cache."""
    b, t, d = x.shape
    d_in, h, n = ssm_dims(cfg)
    p = cfg.ssm_head_dim
    proj = jax.lax.dot_general(
        x, params["in_proj"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xs, bv, cv, dt = _split_proj(proj, d_in, h, n)
    conv_state = cache.conv if cache is not None else None
    xs, new_conv = _causal_conv(xs, params["conv_w"], conv_state)
    xs = jax.nn.silu(xs)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                    # (H,) < 0
    a_log = a * dt                                   # (B,T,H) ≤ 0
    xh = xs.reshape(b, t, h, p)
    x_dt = xh.astype(jnp.float32) * dt[..., None]
    init_state = cache.ssm if cache is not None else None
    y, s_final = ssd_chunked(x_dt, a_log, bv, cv, chunk=chunk,
                             init_state=init_state)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jax.lax.dot_general(
        y, params["out_proj"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    return out, MambaCache(conv=new_conv, ssm=s_final)


def mamba_step(params, cfg: ModelConfig, x, cache: MambaCache):
    """One-token decode. x (B,1,d)."""
    b, _, d = x.shape
    d_in, h, n = ssm_dims(cfg)
    p = cfg.ssm_head_dim
    proj = jax.lax.dot_general(
        x, params["in_proj"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xs, bv, cv, dt = _split_proj(proj, d_in, h, n)
    xs, new_conv = _causal_conv(xs, params["conv_w"], cache.conv)
    xs = jax.nn.silu(xs)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt)      # (B,H)
    xh = xs.reshape(b, h, p).astype(jnp.float32) * dt[..., None]
    state, y = ssd_step(cache.ssm, xh, a, bv[:, 0].astype(jnp.float32),
                        cv[:, 0].astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs.reshape(b, h, p).astype(jnp.float32)
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jax.lax.dot_general(
        y, params["out_proj"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    return out, MambaCache(conv=new_conv, ssm=state)


__all__ = [
    "init_mamba", "mamba_block", "mamba_step", "MambaCache",
    "init_mamba_cache", "ssd_chunked", "ssd_step", "ssm_dims",
]
