"""Deterministic sharded batching with exact resume.

The pipeline is a pure function of (stream, step): every data-parallel
worker slices its own rows from the step's global batch, so restarts and
elastic re-sharding reproduce the exact token order from the checkpointed
``step`` cursor alone — no iterator state to snapshot. This is also the
straggler story: there is no coordinator handing out work; a rejoining or
replacement host computes its shard deterministically.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenPipeline:
    """Fixed token stream → (tokens, labels) batches by step index."""

    def __init__(self, stream: np.ndarray, cfg: PipelineConfig):
        self.cfg = cfg
        need = cfg.seq_len + 1
        n_seq = max(1, len(stream) // need)
        self._data = stream[: n_seq * need].reshape(n_seq, need)
        rng = np.random.default_rng(cfg.seed)
        self._order = rng.permutation(n_seq)

    @property
    def num_sequences(self) -> int:
        return len(self._data)

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1):
        """Deterministic global batch for ``step``; returns this shard's rows."""
        cfg = self.cfg
        rows_per_shard = cfg.global_batch // num_shards
        idx0 = step * cfg.global_batch + shard * rows_per_shard
        idx = (np.arange(rows_per_shard) + idx0) % self.num_sequences
        rows = self._data[self._order[idx]]
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }

    def batches(self, start_step: int = 0, shard: int = 0,
                num_shards: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step, shard, num_shards)
            step += 1


__all__ = ["PipelineConfig", "TokenPipeline"]
