"""PIVOT + Theorem 26 degree cap: label equivalence, 3-approx behaviour."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    brute_force_opt,
    build_graph,
    clustering_cost,
    correlation_cluster,
    degree_capped_pivot,
    degree_threshold,
    pivot,
    pivot_sequential,
    random_permutation_ranks,
)
from repro.core.mis import assign_to_min_rank_mis_neighbor, greedy_mis_parallel
from repro.core.graph import gnp, random_arboric, star


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 40), p=st.floats(0.05, 0.5), seed=st.integers(0, 99))
def test_pivot_parallel_equals_sequential(n, p, seed):
    rng = np.random.default_rng(seed)
    g = build_graph(n, gnp(n, p, rng))
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(seed))
    state = greedy_mis_parallel(g, ranks)
    labels = np.asarray(assign_to_min_rank_mis_neighbor(
        g, ranks, state.status == 1))
    assert (labels == pivot_sequential(g, np.asarray(ranks))).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 30), p=st.floats(0.1, 0.5), seed=st.integers(0, 50))
def test_pivot_clusters_are_pivot_neighbourhoods(n, p, seed):
    """Property: every cluster = a pivot + a subset of its neighbours."""
    rng = np.random.default_rng(seed)
    g = build_graph(n, gnp(n, p, rng))
    res = pivot(g, jax.random.PRNGKey(seed))
    und = g.undirected_edges()
    adj = [set() for _ in range(n)]
    for u, v in und:
        adj[u].add(v)
        adj[v].add(u)
    for v in range(n):
        c = res.labels[v]
        assert res.in_mis[c], "cluster label must be a pivot"
        if v != c:
            assert c in adj[v], "member must neighbour its pivot"


def test_pivot_expected_3_approx_small(rng):
    """E[cost] over many permutations ≤ 3·OPT on brute-forceable graphs."""
    for trial in range(3):
        n = 8
        g = build_graph(n, gnp(n, 0.45, rng))
        opt, _ = brute_force_opt(g)
        costs = []
        for s in range(60):
            res = pivot(g, jax.random.PRNGKey(trial * 100 + s))
            costs.append(clustering_cost(g, res.labels))
        mean = float(np.mean(costs))
        assert mean <= 3.0 * max(opt, 1) + 0.75, (mean, opt)


def test_degree_cap_singletons_high_degree(rng):
    n = 200
    g = build_graph(n, star(n))
    lam = 1
    res = degree_capped_pivot(g, lam=lam, key=jax.random.PRNGKey(0), eps=2.0)
    assert res.high_mask[0], "hub exceeds 12λ and must be singleton"
    assert res.labels[0] == 0
    # all leaves are also singletons (their only neighbour was removed)
    assert (res.labels == np.arange(n)).all()
    # Theorem 26: cost ≤ max{1+ε, 3}·OPT. For a star OPT = matching: n-2 cost.
    cost = clustering_cost(g, res.labels)
    opt = g.m - 1  # best: one matched pair
    assert cost <= 3 * opt + 1


def test_degree_cap_cost_bound_vs_bruteforce(rng):
    """max{1+ε, α}-approx in expectation against exact OPT (tiny graphs)."""
    for trial in range(3):
        n = 9
        edges, lam = random_arboric(n, 2, rng)
        g = build_graph(n, edges)
        opt, _ = brute_force_opt(g)
        costs = []
        for s in range(40):
            res = degree_capped_pivot(g, lam=lam,
                                      key=jax.random.PRNGKey(trial * 99 + s),
                                      eps=2.0)
            costs.append(clustering_cost(g, res.labels))
        assert float(np.mean(costs)) <= 3.0 * max(opt, 1) + 0.75


def test_phased_degree_cap(rng):
    edges, lam = random_arboric(150, 3, rng)
    g = build_graph(150, edges)
    res = degree_capped_pivot(g, lam=lam, key=jax.random.PRNGKey(1),
                              eps=2.0, engine="phased")
    assert res.inner is not None and res.inner.ledger is not None
    assert res.inner.ledger.total_rounds > 0
    # valid clustering: labels within range, cost computable
    assert clustering_cost(g, res.labels) >= 0


def test_api_methods_run(rng):
    edges, lam = random_arboric(120, 2, rng)
    g = build_graph(120, edges)
    for method in ("pivot", "pivot_phased", "pivot_raw", "cliques"):
        res = correlation_cluster(g, method=method, key=jax.random.PRNGKey(2))
        assert res.cost >= 0
        assert len(res.labels) == 120


def test_threshold_formula():
    assert degree_threshold(5, 2.0) == pytest.approx(8 * 1.5 * 5)
    assert degree_threshold(1, 2.0) == pytest.approx(12.0)
