"""zamba2-2.7b [hybrid]: 54 Mamba2 layers, d=2560, shared attention block
(32H MHA kv=32, ff=10240) applied every 6 layers, vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=10240, vocab_size=32000, head_dim=80,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, attn_every=2,
        vocab_round=64,
    )
