"""Algorithm 1 (prefix phases) with Algorithm 2 / Algorithm 3 subroutines.

The *output* of Algorithm 1 is by construction the global randomized greedy
MIS for the permutation π (tested bit-exactly against the sequential
oracle); what the phase/chunk machinery buys is the **MPC round complexity**
— the paper's metric. Since this container has no 1000-chip cluster to
wall-clock, we faithfully execute the schedule and *account* rounds with a
:class:`RoundLedger` whose charging rules follow the paper:

* Algorithm 2 (Model 1): per chunk graph ``G_{i,j}``, every vertex learns its
  connected component by graph exponentiation — ``ceil(log2(component))``
  rounds (Lemma 19) — and resolves it locally in 1 compressed round. We
  *measure* the realized max component size per chunk (Lemma 18 says
  O(log n) w.h.p. — validated in benchmarks).
* Algorithm 3 (Model 2): per prefix graph, gather the R-hop neighbourhood in
  ``ceil(log2 R)`` exponentiation rounds, then simulate the dependency chain
  in ``ceil(depth / R)`` compressed rounds, where ``depth`` is the realized
  parallel dependency depth of that prefix and ``R = Θ(log n / log Δ')``.
* Every phase pays +1 round for the status-update broadcast (§2.1.4 step 3),
  and the final PIVOT capture pass pays +1 convergecast round.

The paper's constants (100, 2000) make chunks degenerate below n ≈ 10⁶, so
they are configurable; defaults keep the *schedule shape* (geometric chunk
growth, Θ(log Δ) iterations per phase) at laptop sizes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cliques import component_sizes, connected_components
from .graph import Graph
from .mis import (
    IN_MIS,
    REMOVED,
    UNDECIDED,
    MISState,
    _mis_round,
    random_permutation_ranks,
)


@dataclasses.dataclass
class PhaseStat:
    phase: int
    prefix_start: int
    prefix_end: int
    delta_before: int          # max live degree entering the phase
    delta_prefix: int          # max degree inside the prefix graph
    depth: int                 # realized parallel dependency depth
    mpc_rounds: float          # charged rounds for this phase
    max_component: int = 0     # Alg 2 only: max chunk-component seen
    chunks: int = 0


@dataclasses.dataclass
class RoundLedger:
    model: str                 # 'model1' (Alg 2) | 'model2' (Alg 3)
    n: int
    phases: List[PhaseStat] = dataclasses.field(default_factory=list)
    extra_rounds: float = 0.0  # capture pass, Δ estimation, etc.

    @property
    def total_rounds(self) -> float:
        return sum(p.mpc_rounds for p in self.phases) + self.extra_rounds

    def summary(self) -> dict:
        return {
            "model": self.model,
            "n": self.n,
            "num_phases": len(self.phases),
            "total_mpc_rounds": self.total_rounds,
            "max_depth": max((p.depth for p in self.phases), default=0),
            "max_component": max((p.max_component for p in self.phases), default=0),
        }


def _live_max_degree(g: Graph, status: jnp.ndarray) -> int:
    """Max degree of the graph induced by still-undecided vertices."""
    n = g.n
    und = status == UNDECIDED
    dst_ok = g.dst < n
    dst_idx = jnp.minimum(g.dst, n - 1)
    src_idx = jnp.minimum(g.src, n - 1)
    contrib = (dst_ok & und[dst_idx] & und[src_idx]).astype(jnp.int32)
    deg = jnp.zeros((n + 1,), jnp.int32).at[jnp.minimum(g.src, n)].add(contrib)[:n]
    return int(jnp.max(jnp.where(und, deg, 0))) if n else 0


@jax.jit
def _run_window(g: Graph, ranks: jnp.ndarray, state: MISState,
                lo, hi) -> Tuple[MISState, jnp.ndarray]:
    """Resolve all undecided vertices with rank in [lo, hi); return depth.

    ``lo``/``hi`` are dynamic (traced) so one compiled program serves every
    prefix window and chunk.
    """
    eligible = (ranks >= lo) & (ranks < hi)

    def cond(s: MISState):
        return jnp.any((s.status == UNDECIDED) & eligible)

    def body(s: MISState):
        return _mis_round(g, ranks, s, eligible)

    before = state.rounds
    state = jax.lax.while_loop(cond, body, state)
    return state, state.rounds - before


def _run_window_jit(g, ranks, state, lo, hi):
    state, depth = _run_window(g, ranks, state, jnp.int32(lo), jnp.int32(hi))
    return state, int(depth)


def algorithm2_phase(g: Graph, ranks: jnp.ndarray, state: MISState,
                     lo: int, hi: int, delta_prefix: int,
                     chunk_c1: float = 4.0, iters_factor: float = 4.0,
                     measure_components: bool = True,
                     ) -> Tuple[MISState, float, int, int, int]:
    """Process prefix window [lo, hi) with Algorithm 2's chunk schedule.

    Returns (state, charged_rounds, total_depth, max_component, num_chunks).
    """
    t = hi - lo
    dp = max(2, delta_prefix)
    log_d = max(1, math.ceil(math.log2(dp)))
    charged = 0.0
    total_depth = 0
    max_comp = 0
    num_chunks = 0
    pos = lo
    for i in range(log_d + 1):
        if pos >= hi:
            break
        c_i = max(1, math.ceil((2**i) / (chunk_c1 * dp) * t))
        iters = max(1, math.ceil(iters_factor * log_d))
        for _ in range(iters):
            if pos >= hi:
                break
            end = min(hi, pos + c_i)
            if measure_components:
                chunk_mask = (
                    (ranks >= pos) & (ranks < end) & (state.status == UNDECIDED)
                )
                labels, _ = connected_components(g, chunk_mask)
                sizes = component_sizes(labels, chunk_mask, g.n)
                comp = int(jnp.max(sizes)) if g.n else 0
            else:
                comp = 2
            state, depth = _run_window_jit(g, ranks, state, pos, end)
            total_depth += depth
            max_comp = max(max_comp, comp)
            num_chunks += 1
            # Lemma 19 charge: learn component via exponentiation + resolve.
            charged += math.ceil(math.log2(max(2, comp))) + 2
            pos = end
    return state, charged, total_depth, max_comp, num_chunks


def algorithm3_phase(g: Graph, ranks: jnp.ndarray, state: MISState,
                     lo: int, hi: int, delta_prefix: int,
                     ) -> Tuple[MISState, float, int]:
    """Process prefix window [lo, hi) with Algorithm 3's accounting (Model 2).

    Returns (state, charged_rounds, depth).
    """
    n = g.n
    state, depth = _run_window_jit(g, ranks, state, lo, hi)
    dp = max(2, delta_prefix)
    R = max(1, math.ceil(math.log2(max(2, n)) / math.log2(dp)))
    charged = math.ceil(math.log2(R + 1)) + math.ceil(max(1, depth) / R) + 1
    return state, charged, depth


def algorithm1(g: Graph, ranks: Optional[jnp.ndarray] = None,
               key: Optional[jax.Array] = None,
               subroutine: str = "alg3",
               c_prefix: float = 2.0,
               chunk_c1: float = 4.0,
               iters_factor: float = 4.0,
               measure_components: bool = True,
               max_phases: int = 64,
               ) -> Tuple[MISState, jnp.ndarray, RoundLedger]:
    """Algorithm 1: phased prefix processing of randomized greedy MIS.

    Returns (final MISState, ranks, ledger). The MIS equals the global greedy
    MIS for π; the ledger holds the charged MPC rounds (Model 1 for
    ``subroutine='alg2'``, Model 2 for ``'alg3'``).
    """
    n = g.n
    if ranks is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        ranks = random_permutation_ranks(n, key)
    ranks = jnp.asarray(ranks, jnp.int32)

    ledger = RoundLedger(model="model1" if subroutine == "alg2" else "model2", n=n)
    state = MISState(status=jnp.zeros((n,), jnp.int32), rounds=jnp.int32(0))
    delta = max(1, g.max_degree())
    ledger.extra_rounds += 1.0  # O(1) rounds to estimate Δ (Remark 7)

    offset = 0
    log_n = math.log(max(2, n))
    for i in range(max_phases):
        if offset >= n:
            break
        target = max(1.0, delta / (2.0**i))
        t_i = min(n - offset, max(1, math.ceil(c_prefix * n * log_n / target)))
        lo, hi = offset, offset + t_i

        delta_before = _live_max_degree(g, state.status)
        # Max degree inside the prefix graph (undecided ∩ window, both ends).
        window = (ranks >= lo) & (ranks < hi) & (state.status == UNDECIDED)
        dst_ok = g.dst < n
        dst_idx = jnp.minimum(g.dst, n - 1)
        src_idx = jnp.minimum(g.src, n - 1)
        contrib = (dst_ok & window[dst_idx] & window[src_idx]).astype(jnp.int32)
        pdeg = jnp.zeros((n + 1,), jnp.int32).at[jnp.minimum(g.src, n)].add(
            contrib
        )[:n]
        delta_prefix = int(jnp.max(jnp.where(window, pdeg, 0))) if n else 0

        if subroutine == "alg2":
            state, charged, depth, max_comp, chunks = algorithm2_phase(
                g, ranks, state, lo, hi, delta_prefix,
                chunk_c1=chunk_c1, iters_factor=iters_factor,
                measure_components=measure_components,
            )
        else:
            state, charged, depth = algorithm3_phase(
                g, ranks, state, lo, hi, delta_prefix
            )
            max_comp, chunks = 0, 1

        ledger.phases.append(
            PhaseStat(
                phase=i,
                prefix_start=lo,
                prefix_end=hi,
                delta_before=delta_before,
                delta_prefix=delta_prefix,
                depth=depth,
                mpc_rounds=charged,
                max_component=max_comp,
                chunks=chunks,
            )
        )
        offset = hi

    # Mop-up (line 8 of Algorithm 1): any stragglers (should be none).
    if bool(jnp.any(state.status == UNDECIDED)):
        state, depth = _run_window_jit(g, ranks, state, 0, n)
        ledger.extra_rounds += math.ceil(math.log2(max(2, depth + 1))) + 1

    return state, ranks, ledger


def remaining_max_degree_after_prefix(g: Graph, ranks: jnp.ndarray,
                                      t: int) -> int:
    """Lemma 22 probe: run greedy MIS on the rank-prefix of size t, return the
    max degree among still-undecided vertices."""
    state = MISState(status=jnp.zeros((g.n,), jnp.int32), rounds=jnp.int32(0))
    state, _ = _run_window_jit(g, jnp.asarray(ranks, jnp.int32), state, 0, t)
    return _live_max_degree(g, state.status)


__all__ = [
    "PhaseStat",
    "RoundLedger",
    "algorithm1",
    "algorithm2_phase",
    "algorithm3_phase",
    "remaining_max_degree_after_prefix",
]
