"""Theorem 26 / Algorithm 4: the degree-cap reduction.

Vertices with positive degree > ``8(1+ε)/ε · λ`` become singleton clusters;
any α-approximate algorithm A runs on the remaining bounded-degree subgraph
(max degree O(λ/ε)); the union is a ``max{1+ε, α}``-approximation.

With ε = 2 and A = PIVOT this is the paper's headline 3-approximation
(Corollary 28): threshold 12λ, runtime O(log λ · polyloglog n) MPC rounds.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, build_graph
from .pivot import PivotResult, pivot


def degree_threshold(lam: int, eps: float) -> float:
    return 8.0 * (1.0 + eps) / eps * lam


@dataclasses.dataclass
class CappedResult:
    labels: np.ndarray
    high_mask: np.ndarray        # singleton'd high-degree vertices
    threshold: float
    inner: Optional[PivotResult]


def degree_capped_pivot(g: Graph, lam: int, key: jax.Array, eps: float = 2.0,
                        engine: str = "rounds",
                        use_kernel: bool = False) -> CappedResult:
    """Algorithm 4 with A = PIVOT (Corollary 28)."""
    n = g.n
    thresh = degree_threshold(lam, eps)
    high = np.asarray(g.deg) > thresh

    if engine == "phased":
        # Build the induced low-degree subgraph explicitly so Algorithm 1's
        # prefix sizes see the capped Δ' = O(λ/ε).
        low_ids = np.flatnonzero(~high)
        remap = np.full(n, -1, dtype=np.int64)
        remap[low_ids] = np.arange(len(low_ids))
        und = g.undirected_edges()
        keep = (~high[und[:, 0]]) & (~high[und[:, 1]])
        sub_edges = remap[und[keep]]
        sub = build_graph(len(low_ids), sub_edges)
        res = pivot(sub, key, engine="phased")
        labels = np.arange(n, dtype=np.int32)
        labels[low_ids] = low_ids[res.labels]
        in_mis = np.zeros(n, dtype=bool)
        in_mis[low_ids] = res.in_mis
        inner = PivotResult(labels=labels, in_mis=in_mis, depth=res.depth,
                            ledger=res.ledger)
        return CappedResult(labels=labels, high_mask=high, threshold=thresh,
                            inner=inner)

    eligible = jnp.asarray(~high)
    res = pivot(g, key, engine=engine, eligible=eligible, use_kernel=use_kernel)
    return CappedResult(labels=res.labels, high_mask=high, threshold=thresh,
                        inner=res)


def degree_capped(g: Graph, lam: int, eps: float,
                  inner_fn: Callable[[Graph, np.ndarray], np.ndarray]
                  ) -> CappedResult:
    """Generic Algorithm 4: ``inner_fn(subgraph, low_ids)`` returns labels in
    subgraph index space; high-degree vertices are singletons."""
    n = g.n
    thresh = degree_threshold(lam, eps)
    high = np.asarray(g.deg) > thresh
    low_ids = np.flatnonzero(~high)
    remap = np.full(n, -1, dtype=np.int64)
    remap[low_ids] = np.arange(len(low_ids))
    und = g.undirected_edges()
    keep = (~high[und[:, 0]]) & (~high[und[:, 1]])
    sub = build_graph(len(low_ids), remap[und[keep]])
    sub_labels = np.asarray(inner_fn(sub, low_ids))
    labels = np.arange(n, dtype=np.int32)
    labels[low_ids] = low_ids[sub_labels]
    return CappedResult(labels=labels, high_mask=high, threshold=thresh,
                        inner=None)


__all__ = ["degree_threshold", "CappedResult", "degree_capped_pivot",
           "degree_capped"]
