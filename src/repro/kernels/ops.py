"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels are written for TPU as the target and validated in interpret mode).
On a real TPU backend the same call sites lower the Mosaic kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import neighbor_min as _nm
from . import ref as _ref


# Resolved ONCE at import: ``interpret`` is a jit static arg on every
# kernel below, so re-probing the backend per call would let a mid-process
# backend flip silently retrace the hot path. A process's backend is fixed
# after jax initializes; tests override explicitly via set_interpret_mode.
_INTERPRET = jax.default_backend() != "tpu"


def interpret_mode() -> bool:
    """The interpret flag every kernel wrapper passes (import-time fixed)."""
    return _INTERPRET


def set_interpret_mode(interpret: bool | None) -> bool:
    """Override the import-time interpret resolution (tests only); returns
    the previous value. ``None`` re-resolves from the current backend."""
    global _INTERPRET
    prev = _INTERPRET
    _INTERPRET = (jax.default_backend() != "tpu") if interpret is None \
        else bool(interpret)
    return prev


def neighbor_min(g, ranks: jnp.ndarray, active: jnp.ndarray,
                 width: int | None = None) -> jnp.ndarray:
    """Graph-facing neighbour-min (contract of core.mis.neighbor_min_ranks).

    Builds the ELL view once per (graph, width); jit caching makes repeated
    MIS rounds reuse the compiled kernel.
    """
    ell = _nm.ell_from_graph(g, width=width)
    ranks_p, active_p = _nm.pad_state(jnp.asarray(ranks, jnp.int32), active)
    return _nm.neighbor_min_ell(ell, ranks_p, active_p,
                                interpret=_INTERPRET)


def neighbor_min_ell(ell, ranks_p, active_p, block_rows: int = 256):
    return _nm.neighbor_min_ell(ell, ranks_p, active_p,
                                block_rows=block_rows,
                                interpret=_INTERPRET)


def neighbor_min_ell_batch(ell, ranks_p, active_p, block_rows: int = 256):
    """Batched (B, R, W) neighbour-min — per-round hot loop of core.batch."""
    return _nm.neighbor_min_ell_batch(ell, ranks_p, active_p,
                                      block_rows=block_rows,
                                      interpret=_INTERPRET)


def label_agree_ell_batch(ell, labels_p, block_rows: int = 256):
    """Batched (B, R, W) same-label neighbour count — the device cost pass
    of core.batch (2·intra_pos when summed per graph)."""
    return _nm.label_agree_ell_batch(ell, labels_p, block_rows=block_rows,
                                     interpret=_INTERPRET)


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), size


def flash_attention(q, k, v, causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128):
    """Padded/unpadded flash attention. q (B,H,Sq,D), k/v (B,KH,Sk,D).

    Sequence lengths are padded up to the block size; padded KV columns are
    masked out by giving them -inf scores via an explicit active length —
    here we rely on causal masking for Sq==Sk and pad-safe softmax (padded
    rows are sliced away, padded KV columns only matter for non-causal
    inputs, where we pre-mask keys by padding V with zeros and K with a
    -inf-producing sentinel handled below).
    """
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    qp, sq0 = _pad_to(q, block_q, 2)
    kp, sk0 = _pad_to(k, block_k, 2)
    vp, _ = _pad_to(v, block_k, 2)
    if kp.shape[2] != sk0 and not causal:
        # Ragged non-causal KV (padded keys would need an explicit length
        # mask): take the oracle path — only hit by tiny encoder shapes.
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale)
    out = _fa.flash_attention(qp, kp, vp, causal=causal, scale=scale,
                              block_q=block_q, block_k=block_k,
                              interpret=_INTERPRET,
                              row_offset=sk0 - sq0)
    return out[:, :, :sq0, :]


__all__ = ["neighbor_min", "neighbor_min_ell", "neighbor_min_ell_batch",
           "label_agree_ell_batch", "flash_attention",
           "interpret_mode", "set_interpret_mode"]
