"""Version compatibility shims for the pinned container toolchain.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace around jax 0.6; the container pins an older jax, so every
call site imports it from here instead of guessing the location.
"""

from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
