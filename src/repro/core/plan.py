"""Host-side planning layer of the batch engine: bucketing, packing, staging.

This is the "what runs" half of the plan/executor split (the "how it runs"
half is :mod:`repro.core.executor`). Everything here is pure numpy on the
host:

* :func:`plan_graph` resolves one graph's degree cap and its ``(R, W)``
  shape bucket (``R`` = vertex count rounded to a power of two, ``W`` = max
  *eligible-induced* degree rounded to a power of two — the Theorem 26 cap
  is what keeps ``W ≤ 12λ`` and makes ELL padding cheap). It also
  canonicalises the eligible-induced edge list (lexsorted) exactly once;
  :func:`graph_fingerprint` and the packer both read
  ``GraphPlan.canonical_edges`` instead of re-deriving it.
* :func:`build_packed_rows` turns one plan into a :class:`PackedRows`
  artifact — the graph's finished ``(R, W)`` ELL rows, rank rows, and
  eligibility row. Serving builds it once per request at admission, so the
  argsort/bincount/scatter work leaves the flush critical path.
* :func:`pack_bucket` lays one bucket's graphs (× k best-of-k samples)
  into the ``(B, R, W)`` ELL tensor plus ``(B, R+1)`` rank/eligibility
  state the device program consumes, with the group axis padded to a power
  of two (callers may request extra group padding, e.g. to a device-count
  multiple for the sharded executor). Plans carrying prebuilt
  :class:`PackedRows` assemble by row copies only; plans without fall back
  to the legacy derive-at-flush build — the two paths are bit-identical
  and compose freely within one bucket.
* :class:`PackStats` is the packer's own padding accounting — the single
  source serving stats are derived from, so they cannot drift from what was
  actually padded onto the device. :func:`estimate_pack_stats` is the pure
  formula behind it, shared with the serving cost model so candidate
  flushes are priced with exactly the math the real pack will report.
* :class:`BucketBufferPool` owns the persistent host staging arrays.
  Staging is handed out as **leases**: an acquired buffer is not eligible
  for reuse until its lease is released, which the executor layer does only
  after the bucket's device program has completed and its outputs have been
  fetched. That is the invariant that makes async (overlapped) flushes
  safe — a buffer feeding an in-flight program is never refilled.

The bit-exactness contract lives at this layer too: ranks come from the
same ``random_permutation_ranks(n_i, key_i)`` as the per-graph engine, so
for matching keys any grouping of graphs into buckets — full flushes,
partial deadline flushes, sharded flushes — yields identical results.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.util import next_pow2

from .arboricity import arboricity_bounds
from .degree_cap import degree_threshold
from .graph import Graph
from .mis import random_permutation_ranks_batch

MIN_ROWS = 8     # smallest R bucket
MIN_WIDTH = 4    # smallest W bucket

# Largest supported bucket shapes. R is bounded so the int32 pair count
# R·(R−1)/2 of the device cost pass cannot overflow (jax x64 is disabled in
# this deployment); W is bounded because an eligible-induced degree that
# large means the degree cap is effectively off for a dense graph — the
# per-graph engine is the right tool there.
MAX_ROWS = 1 << 15
MAX_WIDTH = 1 << 12

_INT32_MAX = np.iinfo(np.int32).max


@dataclasses.dataclass
class GraphPlan:
    """Per-graph packing plan: bucket key + degree-cap metadata."""

    g: Graph
    n: int
    lam: Optional[int]          # resolved arboricity bound (None for raw)
    threshold: Optional[float]  # degree-cap threshold (None for raw)
    eligible: np.ndarray        # (n,) bool — vertices the inner PIVOT sees
    wreq: int                   # max eligible-induced degree
    R: int                      # row bucket (pow2)
    W: int                      # width bucket (pow2)
    # Eligible-induced undirected edge list in canonical (lexsorted (u, v))
    # order, int64 C-contiguous. Built once by plan_graph; both
    # graph_fingerprint and the packer consume it, so the keep-mask/sort
    # happens exactly once per request and the two can never diverge.
    canonical_edges: Optional[np.ndarray] = None
    # Prebuilt device rows (admission-time packing). None = the packer
    # derives rows at flush time from canonical_edges instead.
    rows: Optional["PackedRows"] = None
    # Registered clustering method this plan was resolved for. Part of the
    # serving-layer queue key: one flush runs one method's bucket program.
    method: str = "pivot"

    @property
    def bucket(self) -> Tuple[int, int]:
        """Shape bucket (R, W) — the packing/promotion identity."""
        return (self.R, self.W)

    @property
    def queue_key(self) -> Tuple[str, int, int]:
        """Serving-layer bucket key (method, R, W): requests coalesce into
        one flush only when they share both the packed shape and the
        registered bucket program."""
        return (self.method, self.R, self.W)


def plan_graph(g: Graph, method: str = "pivot", eps: float = 2.0,
               lam: Optional[int] = None) -> GraphPlan:
    """Resolve the degree cap and the (R, W) shape bucket for one graph.

    ``method`` must be registered in :mod:`repro.core.programs`; its spec
    drives planning. Degree-capped methods mirror the per-graph api
    exactly: ``lam`` defaults to the degeneracy upper bound, eligibility
    is ``deg <= 8(1+ε)/ε·λ`` (Theorem 26). Uncapped methods
    (``'pivot_raw'``) mark every vertex eligible.

    Raises ``ValueError`` for an unregistered method, or when the graph
    exceeds the largest supported bucket (``MAX_ROWS`` vertices /
    eligible-induced degree ``MAX_WIDTH``).
    """
    from .programs import method_spec

    spec = method_spec(method)     # ValueError lists registered methods
    n = g.n
    if spec.degree_cap:
        if lam is None:
            _, lam = arboricity_bounds(g, exact=n <= 200_000)
        threshold = degree_threshold(lam, eps)
        eligible = ~(np.asarray(g.deg) > threshold)
    else:
        lam, threshold = None, None
        eligible = np.ones(n, dtype=bool)

    und = g.undirected_edges()
    if len(und):
        keep = eligible[und[:, 0]] & eligible[und[:, 1]]
        kept = und[keep]
    else:
        kept = np.zeros((0, 2), dtype=np.int64)
    if len(kept):
        # Canonical order: lexsorted by (u, v). This is the byte order the
        # fingerprint hashes and the edge order the packer scatters from.
        kept = kept[np.lexsort((kept[:, 1], kept[:, 0]))]
        wreq = int(np.bincount(kept.ravel(), minlength=n).max())
    else:
        wreq = 0
    kept = np.ascontiguousarray(kept, dtype=np.int64)

    R = max(MIN_ROWS, next_pow2(max(1, n)))
    W = max(MIN_WIDTH, next_pow2(max(1, wreq)))
    if R > MAX_ROWS:
        raise ValueError(
            f"graph with n={n} needs row bucket R={R} > MAX_ROWS={MAX_ROWS}; "
            "the batch engine targets many small graphs — cluster this one "
            "through correlation_cluster (per-graph engine) instead")
    if W > MAX_WIDTH:
        raise ValueError(
            f"graph needs ELL width W={W} > MAX_WIDTH={MAX_WIDTH} (max "
            f"eligible-induced degree {wreq}); with method='pivot' the "
            "Theorem 26 degree cap bounds this by 12λ — a width this large "
            "means the graph is too dense for the bucketed ELL layout; use "
            "the per-graph engine")
    return GraphPlan(g=g, n=n, lam=lam, threshold=threshold,
                     eligible=eligible, wreq=wreq, R=R, W=W,
                     canonical_edges=kept, method=method)


def plan_canonical_edges(plan: GraphPlan) -> np.ndarray:
    """The plan's canonical (lexsorted) eligible-induced edge list.

    ``plan_graph`` always attaches it; plans constructed by hand get it
    derived (and memoised) here so the fingerprint and the packer keep one
    source of truth either way.
    """
    if plan.canonical_edges is None:
        und = plan.g.undirected_edges()
        if len(und):
            keep = plan.eligible[und[:, 0]] & plan.eligible[und[:, 1]]
            kept = und[keep]
            if len(kept):
                kept = kept[np.lexsort((kept[:, 1], kept[:, 0]))]
        else:
            kept = np.zeros((0, 2), dtype=np.int64)
        plan.canonical_edges = np.ascontiguousarray(kept, dtype=np.int64)
    return plan.canonical_edges


class PackedRows:
    """Prebuilt device rows for one planned graph (admission-time packing).

    Everything :func:`pack_bucket` would derive for this graph at flush
    time, finished once up front: the ``(R, W)`` int32 ELL adjacency rows
    (pad id ``R``), the ``(k, R+1)`` rank rows for the request's best-of-k
    sample keys (``INT32_MAX`` beyond ``n``), the ``(R+1,)`` eligibility
    row (slot ``R`` False), and the full edge count ``m`` the cost
    identity reads. Flush-time assembly then reduces to row copies into
    the leased staging arrays.

    The rank permutations are dispatched to the device when the artifact
    is built (one fused async call) and materialised into the padded
    numpy layout lazily on first access — by flush time they have long
    finished, so admission keeps the overlap the flush-time packer had.
    """

    __slots__ = ("R", "W", "n", "m", "k", "ell", "elig",
                 "_ranks", "_ranks_dev")

    def __init__(self, R: int, W: int, n: int, m: int, k: int,
                 ell: np.ndarray, elig: np.ndarray,
                 ranks: Optional[np.ndarray] = None, ranks_dev=None):
        self.R = R
        self.W = W
        self.n = n
        self.m = m
        self.k = k
        self.ell = ell
        self.elig = elig
        self._ranks = ranks
        self._ranks_dev = ranks_dev

    @property
    def bucket(self) -> Tuple[int, int]:
        return (self.R, self.W)

    @property
    def ranks(self) -> np.ndarray:
        """``(k, R+1)`` int32 rank rows (materialises the device batch)."""
        if self._ranks is None:
            out = np.full((self.k, self.R + 1), _INT32_MAX, dtype=np.int32)
            if self._ranks_dev is not None:
                out[:, : self.n] = np.asarray(self._ranks_dev)
                self._ranks_dev = None
            self._ranks = out
        return self._ranks

    def promote(self, R: int, W: int) -> "PackedRows":
        """Pad-copy relayout into a larger ``(R, W)`` bucket (coalescing).

        Bit-exact for the same reason :func:`promote_plan` is: promoted
        rows ``n..R`` carry INF rank and are ineligible, extra width slots
        hold the new pad id ``R``. Raises ``ValueError`` for a target that
        cannot hold these rows.
        """
        if (R, W) == (self.R, self.W):
            return self
        if R < self.R or W < self.W:
            raise ValueError(
                f"cannot promote packed rows {self.bucket} into ({R}, {W}):"
                " the target must be at least as large in both dimensions")
        ell = np.full((R, W), R, dtype=np.int32)
        if self.n:
            # Real entries only live in rows < n; re-stamp the pad id.
            sub = self.ell[: self.n]
            ell[: self.n, : self.W] = np.where(sub == self.R, R, sub)
        elig = np.zeros(R + 1, dtype=bool)
        elig[: self.n] = self.elig[: self.n]
        ranks = np.full((self.k, R + 1), _INT32_MAX, dtype=np.int32)
        ranks[:, : self.n] = self.ranks[:, : self.n]
        return PackedRows(R=R, W=W, n=self.n, m=self.m, k=self.k,
                          ell=ell, elig=elig, ranks=ranks)


def build_packed_rows(plan: GraphPlan,
                      keys: Sequence[jax.Array]) -> PackedRows:
    """Build one graph's :class:`PackedRows` at its native bucket.

    ``keys`` are the request's best-of-k sample keys; the rank batch is
    dispatched here (async) and harvested lazily. The ELL rows scatter
    straight from the plan's canonical edge list — the same array the
    fingerprint hashes — so the sort/bincount of packing happens exactly
    once per request, at admission.
    """
    n = plan.n
    R, W = plan.bucket
    ell = np.full((R, W), R, dtype=np.int32)
    e = plan_canonical_edges(plan)
    if len(e):
        src = np.concatenate([e[:, 0], e[:, 1]])
        dst = np.concatenate([e[:, 1], e[:, 0]])
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        deg = np.bincount(src, minlength=n)
        starts = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(deg, out=starts[1:])
        slot = np.arange(len(src)) - starts[src]
        ell[src, slot] = dst
    elig = np.zeros(R + 1, dtype=bool)
    if n:
        elig[:n] = plan.eligible
    ranks_dev = random_permutation_ranks_batch(n, keys) if n else None
    return PackedRows(R=R, W=W, n=n, m=int(plan.g.m), k=len(keys),
                      ell=ell, elig=elig, ranks_dev=ranks_dev)


def promote_plan(plan: GraphPlan, R: int, W: int) -> GraphPlan:
    """Re-target a plan at a larger ``(R, W)`` shape bucket (coalescing).

    The scheduler's work-stealing policy packs a starving bucket's
    requests into a compatible hot bucket's flush; this is the shape
    promotion that makes the packed tensors line up. It is bit-exact by
    construction: ranks/eligibility are a function of ``(n, key)`` only,
    promoted rows ``n..R`` carry INF rank and are ineligible (removed
    before the first MIS round, singleton labels sliced off by
    ``result_for_plan``), extra ELL width slots hold the pad id ``R``
    whose gathered rank is INF / label is −1, and the cost identity sums
    zero over both. Asserted against the per-graph engine in
    ``tests/test_scheduler.py``.

    Raises ``ValueError`` if the target shape cannot hold the plan
    (``R < plan.R`` or ``W < plan.W``) or exceeds the largest supported
    bucket.
    """
    if R < plan.R or W < plan.W:
        raise ValueError(
            f"cannot promote bucket {plan.bucket} into ({R}, {W}): the "
            "target must be at least as large in both dimensions")
    if R > MAX_ROWS or W > MAX_WIDTH:
        raise ValueError(
            f"promotion target ({R}, {W}) exceeds the largest supported "
            f"bucket ({MAX_ROWS}, {MAX_WIDTH})")
    if (R, W) == plan.bucket:
        return plan
    # Prebuilt rows relayout with the plan (cheap pad-copies), so a
    # coalesced flush at the promoted shape still assembles by row copies.
    rows = plan.rows.promote(R, W) if plan.rows is not None else None
    return dataclasses.replace(plan, R=R, W=W, rows=rows)


@dataclasses.dataclass(frozen=True)
class GraphFingerprint:
    """Content address of one planned clustering request.

    ``digest`` is a 128-bit blake2b over ``payload``, the canonical byte
    encoding of everything that determines the device result bit-for-bit
    (see :func:`graph_fingerprint`). The payload rides along so a cache
    keyed by ``digest`` can *verify* equality on every hit instead of
    trusting the hash — a digest collision is detected, counted, and
    treated as a miss rather than silently serving another graph's labels.
    """

    digest: str
    payload: bytes

    @property
    def nbytes(self) -> int:
        """Size of the retained canonical payload (cache byte accounting)."""
        return len(self.payload)


def _key_payload(key: jax.Array) -> bytes:
    """Canonical bytes of a PRNG key — dtype, size, and raw key data.

    Handles both legacy ``uint32`` key arrays and new-style typed key
    arrays (``jax.random.key``); the encoding distinguishes them, which is
    correct — they can drive different bit streams.
    """
    try:
        arr = np.asarray(key)
    except TypeError:
        # Typed key arrays refuse np.asarray; unwrap to the raw key data.
        arr = np.asarray(jax.random.key_data(key))
    arr = np.ascontiguousarray(arr)
    return (str(arr.dtype).encode("utf-8") + b"\0"
            + struct.pack("<q", arr.size) + arr.tobytes())


def graph_fingerprint(plan: GraphPlan, key: jax.Array, *,
                      method: str = "pivot", num_samples: int = 1,
                      eps: float = 2.0,
                      objective: str = "disagree") -> GraphFingerprint:
    """Canonical, collision-checked content hash of one planned request.

    Two requests with equal fingerprints produce bit-identical device
    inputs, hence bit-identical ``(labels, cost, picked)`` — the invariant
    the serving-layer result cache and single-flight coalescing rest on.
    The payload canonicalises exactly what :func:`pack_bucket` puts on
    the device for this graph at its native bucket (bucket-shape-stable:
    promotion to a larger flush shape is bit-exact, so it does not enter
    the fingerprint):

    * the eligible-induced edge set in a canonical (lexsorted) order, the
      eligibility mask, ``n``, and ``m`` (the cost identity reads the full
      edge count) — together these determine the ELL rows and the
      eligibility state;
    * the **exact PRNG key bytes** plus ``num_samples`` — ranks are a
      function of ``(n, key)`` only, and best-of-k sample keys are derived
      by ``fold_in`` from the base key, so key + k pins every permutation.
      Caching is keyed on the exact key precisely because the contract is
      bit-exactness *per key*, not statistical equivalence;
    * ``method`` / ``objective`` / ``eps`` / the resolved ``lam`` — method
      and objective select the registered bucket program (different
      methods or objectives on identical inputs produce different labels
      or different best-of-k winners, so their cache entries must never
      alias), and ``eps``/``lam`` resolve the degree cap (eligibility,
      threshold) and the result's info schema.

    Only post-selection winners (the argmin-of-k labels/cost/picked the
    engine returns) are cached against this fingerprint: intermediate
    per-sample outputs never leave the device program, so the cached value
    is exactly what a cold flush would have returned.
    """
    g = plan.g
    # The canonical lexsorted edge list is built once by plan_graph and
    # shared with the packer — hashing here re-derives nothing.
    kept = plan_canonical_edges(plan)
    elig = np.ascontiguousarray(np.asarray(plan.eligible, dtype=bool))
    payload = b"".join([
        b"cc-graph-fp2\0",
        method.encode("utf-8") + b"\0",
        objective.encode("utf-8") + b"\0",
        struct.pack("<d", float(eps)),
        struct.pack("<q", -1 if plan.lam is None else int(plan.lam)),
        struct.pack("<qqq", max(1, int(num_samples)), int(plan.n), int(g.m)),
        _key_payload(key),
        np.packbits(elig).tobytes() if plan.n else b"",
        kept.tobytes(),
    ])
    return GraphFingerprint(
        digest=hashlib.blake2b(payload, digest_size=16).hexdigest(),
        payload=payload)


@dataclasses.dataclass
class PackStats:
    """Packing/padding accounting for one ``correlation_cluster_batch`` call.

    Returned by the packer itself (``with_stats=True``) so serving-layer
    stats can never drift from what was actually padded onto the device.
    """

    n_graphs: int = 0
    n_entries: int = 0        # real device entries = graphs × num_samples
    padded_entries: int = 0   # empty entries added for pow2 group padding
    pad_vertex_waste: int = 0  # Σ (R − n) over real graphs
    bucket_shapes: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)  # (R, W, B) per bucket actually run

    def merge(self, other: "PackStats") -> None:
        """Accumulate another flush's packing accounting into this one."""
        self.n_graphs += other.n_graphs
        self.n_entries += other.n_entries
        self.padded_entries += other.padded_entries
        self.pad_vertex_waste += other.pad_vertex_waste
        self.bucket_shapes.extend(other.bucket_shapes)


def estimate_pack_stats(plans: Sequence[GraphPlan], k: int,
                        g_pad: Optional[int] = None) -> PackStats:
    """Price a prospective flush's padding without packing it.

    A pure function over :class:`GraphPlan`\\ s — the single
    :class:`PackStats` formula. ``pack_and_submit`` builds its real
    accounting from it, and the serving cost model
    (:mod:`repro.serve.costmodel`) prices *candidate* coalesced flushes
    with it before committing, so a priced decision and the pad stats the
    flush later reports are the same numbers by construction. For a
    promoted (coalesced) pack, pass plans already run through
    :func:`promote_plan` — every plan must share one bucket shape.

    ``g_pad`` is the padded group count (defaults to the plain pow2
    padding; executors may require more, e.g. a device-count floor).
    """
    if not plans:
        raise ValueError("estimate_pack_stats needs at least one plan")
    R, W = plans[0].bucket
    if any(p.bucket != (R, W) for p in plans):
        raise ValueError("plans must share one (R, W) bucket shape — "
                         "promote them first")
    if g_pad is None:
        g_pad = next_pow2(len(plans))
    elif g_pad < len(plans):
        raise ValueError(f"g_pad={g_pad} < {len(plans)} graphs in bucket")
    return PackStats(
        n_graphs=len(plans),
        n_entries=len(plans) * k,
        padded_entries=(g_pad - len(plans)) * k,
        pad_vertex_waste=sum(R - p.n for p in plans),
        bucket_shapes=[(R, W, g_pad * k)],
    )


def pack_bucket(plans: Sequence[GraphPlan],
                group_keys: Sequence[Optional[Sequence[jax.Array]]],
                k: int,
                staging: Optional[dict] = None,
                g_pad: Optional[int] = None):
    """Assemble one bucket's graphs (× k samples each) into device tensors.

    Returns ``(ell, ranks, elig, m_edges, pad_groups)`` with batch axis
    ``B = g_pad · k`` where ``g_pad`` defaults to ``next_pow2(len(plans))``
    — executors may request more group padding (e.g. the sharded executor
    pads to at least its device count so the batch axis splits evenly).
    The ``k`` sample replicas of a graph occupy contiguous entries so the
    device argmin can reduce over a simple ``(G, k)`` reshape. ``staging``
    (a lease from :class:`BucketBufferPool`) reuses host arrays across
    flushes instead of reallocating.

    Per graph, one of two bit-identical paths runs:

    * **prebuilt** — a plan carrying :class:`PackedRows` (built at
      admission by :func:`build_packed_rows`, promoted with its plan for
      coalesced flushes) assembles by row copies only; its ``group_keys``
      entry may be ``None`` because the rank permutations were drawn when
      the rows were built. A flush of all-prebuilt plans skips the full
      staging reset too: every real row is wholly overwritten by its copy,
      so only the group-padding tail is (re)stamped with the pad pattern.
    * **legacy** — a plan without rows gets the derive-at-flush build,
      scattering from the plan's canonical edge list (the same array the
      fingerprint hashes) with its rank batch dispatched up front (async)
      and harvested after the host-side scatters.
    """
    R, W = plans[0].bucket
    if g_pad is None:
        g_pad = next_pow2(len(plans))
    elif g_pad < len(plans):
        raise ValueError(f"g_pad={g_pad} < {len(plans)} graphs in bucket")
    b_pad = g_pad * k
    rows_list = [p.rows for p in plans]
    for pr in rows_list:
        if pr is not None and (pr.bucket != (R, W) or pr.k != k):
            raise ValueError(
                f"prebuilt rows at bucket {pr.bucket} with k={pr.k} cannot "
                f"assemble into a ({R}, {W}) flush with k={k}; promote the "
                "plan first (promote_plan relays its PackedRows)")
    all_prebuilt = all(pr is not None for pr in rows_list)
    n_real = len(plans) * k
    if staging is None:
        if all_prebuilt:
            ell = np.empty((b_pad, R, W), dtype=np.int32)
            ranks = np.empty((b_pad, R + 1), dtype=np.int32)
            elig = np.empty((b_pad, R + 1), dtype=bool)
            m_edges = np.empty((b_pad,), dtype=np.int32)
        else:
            ell = np.full((b_pad, R, W), R, dtype=np.int32)
            ranks = np.full((b_pad, R + 1), _INT32_MAX, dtype=np.int32)
            elig = np.zeros((b_pad, R + 1), dtype=bool)
            m_edges = np.zeros((b_pad,), dtype=np.int32)
    else:
        ell, ranks, elig, m_edges = (staging["ell"], staging["ranks"],
                                     staging["elig"], staging["m_edges"])
        if not all_prebuilt:
            ell.fill(R)
            ranks.fill(_INT32_MAX)
            elig.fill(False)
            m_edges.fill(0)
    if all_prebuilt:
        # Rows [0, n_real) are wholly overwritten below; only the
        # group-padding tail needs the pad pattern.
        ell[n_real:] = R
        ranks[n_real:] = _INT32_MAX
        elig[n_real:] = False
        m_edges[n_real:] = 0

    # Dispatch the legacy graphs' rank batches first (one fused device
    # call per graph, async under JAX dispatch): the permutations compute
    # while the numpy ELL packing below runs on the host. Same per-graph
    # permutation as the single-graph engine — ranks are a function of
    # (n, key) only, and the batched call is row-bit-identical to per-key
    # calls — so the result stays bit-exact per graph. Prebuilt graphs
    # dispatched theirs at admission.
    rank_batches = [
        random_permutation_ranks_batch(plan.n, keys)
        if pr is None and plan.n else None
        for plan, keys, pr in zip(plans, group_keys, rows_list)
    ]

    for gi, (plan, keys) in enumerate(zip(plans, group_keys)):
        n = plan.n
        base = gi * k
        pr = rows_list[gi]
        if pr is not None:
            ell[base: base + k] = pr.ell
            ranks[base: base + k] = pr.ranks
            elig[base: base + k] = pr.elig
            m_edges[base: base + k] = pr.m
            continue
        e = plan_canonical_edges(plan)
        if len(e):
            src = np.concatenate([e[:, 0], e[:, 1]])
            dst = np.concatenate([e[:, 1], e[:, 0]])
            order = np.argsort(src, kind="stable")
            src, dst = src[order], dst[order]
            deg = np.bincount(src, minlength=n)
            starts = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(deg, out=starts[1:])
            slot = np.arange(len(src)) - starts[src]
            ell[base, src, slot] = dst
        # The adjacency is identical across the k sample replicas; only the
        # permutation (hence ranks) differs per sample key.
        for si in range(1, k):
            ell[base + si] = ell[base]
        for si in range(len(keys)):
            if n:
                elig[base + si, :n] = plan.eligible
            m_edges[base + si] = plan.g.m

    # Harvest the (by now computed) rank batches into the staging arrays.
    for gi, (plan, batch) in enumerate(zip(plans, rank_batches)):
        if batch is None:
            continue
        base = gi * k
        rk = np.asarray(batch)
        for si in range(rk.shape[0]):
            ranks[base + si, : plan.n] = rk[si]
    return ell, ranks, elig, m_edges, g_pad - len(plans)


def _pack_bucket(plans, group_keys, k, staging=None, g_pad=None):
    """Deprecated pre-PR-8 private name of :func:`pack_bucket`."""
    warnings.warn(
        "repro.core.plan._pack_bucket is deprecated; use pack_bucket",
        DeprecationWarning, stacklevel=2)
    return pack_bucket(plans, group_keys, k, staging=staging, g_pad=g_pad)


def result_for_plan(plan: GraphPlan, labels_row: np.ndarray, cost: int,
                    picked: int, rounds: int, k: int, method: str):
    """Build one :class:`~repro.core.api.ClusterResult` from device outputs.

    Shared by ``correlation_cluster_batch`` and the serving-layer harvest so
    the result/info schema cannot diverge between the one-shot and the
    streaming paths.
    """
    from .api import ClusterResult  # deferred: api imports the batch layer

    info = {
        "bucket": plan.bucket,
        "depth": rounds,
        "engine": "batch",
    }
    if plan.threshold is not None:
        info.update(threshold=plan.threshold,
                    high_degree=int((~plan.eligible).sum()),
                    lambda_bound=plan.lam)
    if k > 1:
        info.update(num_samples=k, picked_sample=picked)
    return ClusterResult(labels=labels_row[: plan.n].astype(np.int32),
                         cost=cost, method=method, info=info)


class StagingLease:
    """One checked-out host staging buffer set (see :class:`BucketBufferPool`).

    ``arrays`` maps ``ell``/``ranks``/``elig``/``m_edges`` to the numpy
    staging arrays a flush packs into. The lease must be released (once)
    after the device program consuming the buffers has completed; the
    executor layer does this when a flush's outputs are fetched.
    """

    __slots__ = ("pool", "key", "arrays", "released")

    def __init__(self, pool: "BucketBufferPool", key: Tuple[int, int, int],
                 arrays: dict):
        self.pool = pool
        self.key = key
        self.arrays = arrays
        self.released = False

    def release(self) -> None:
        if not self.released:
            self.released = True
            self.pool._release(self)


class BucketBufferPool:
    """Persistent per-bucket-shape buffers for steady-state serving.

    Two halves, both keyed by the packed shape ``(B, R, W)``:

    * **Host staging** — the numpy ``ell``/``ranks``/``eligible``/``m``
      arrays a flush packs into are allocated once per shape and refilled
      in place on later flushes. Buffers are handed out as
      :class:`StagingLease` objects: a leased buffer is **never** handed
      out again until released, so an async executor overlapping flushes of
      the same bucket shape gets a second buffer generation instead of
      corrupting the one still feeding an in-flight program (regression
      tested in ``tests/test_executor.py``). Synchronous serving releases
      each lease before the next flush, holding O(#buckets) buffers;
      pipelined serving holds O(#buckets · in-flight).
    * **Device donation** — flushes routed through a pool run the
      ``donate_argnums`` jit variant, so the device input buffers are
      recycled into the outputs instead of surviving alongside them.

    Results are bit-identical with or without the pool (asserted in
    ``tests/test_engine.py``); the pool only changes allocation behaviour.
    """

    def __init__(self, donate: bool = True):
        self.donate = donate
        self._free: Dict[Tuple[int, int, int], List[dict]] = {}
        self._allocated = 0
        self._leased = 0

    def _new_buffers(self, b: int, r: int, w: int) -> dict:
        return {
            "ell": np.empty((b, r, w), dtype=np.int32),
            "ranks": np.empty((b, r + 1), dtype=np.int32),
            "elig": np.empty((b, r + 1), dtype=bool),
            "m_edges": np.empty((b,), dtype=np.int32),
        }

    def acquire(self, b: int, r: int, w: int) -> StagingLease:
        """Check out a staging buffer set for shape ``(b, r, w)``.

        Reuses a free buffer when one exists; otherwise allocates — a
        buffer whose lease is outstanding is never returned.
        """
        key = (b, r, w)
        free = self._free.get(key)
        if free:
            arrays = free.pop()
        else:
            arrays = self._new_buffers(b, r, w)
            self._allocated += 1
        self._leased += 1
        return StagingLease(self, key, arrays)

    def _release(self, lease: StagingLease) -> None:
        self._leased -= 1
        self._free.setdefault(lease.key, []).append(lease.arrays)

    @property
    def n_buffers(self) -> int:
        """Total staging buffer sets allocated (free + leased)."""
        return self._allocated

    @property
    def leased(self) -> int:
        """Buffer sets currently checked out to in-flight flushes."""
        return self._leased


__all__ = [
    "GraphPlan",
    "GraphFingerprint",
    "graph_fingerprint",
    "PackStats",
    "PackedRows",
    "StagingLease",
    "BucketBufferPool",
    "plan_graph",
    "plan_canonical_edges",
    "promote_plan",
    "build_packed_rows",
    "pack_bucket",
    "estimate_pack_stats",
    "result_for_plan",
    "MIN_ROWS",
    "MIN_WIDTH",
    "MAX_ROWS",
    "MAX_WIDTH",
]
