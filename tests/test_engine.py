"""Unified serving engine: deadline flushes, device cost, donated buffers.

The contracts under test (serve/engine.py, serve/cluster_batcher.py,
core/batch.py):

* partial-bucket (deadline) flushes are bit-exact vs per-graph
  ``correlation_cluster`` — flush grouping can never change a result;
* the device-side cost pass equals the ``_cost_host`` numpy oracle across
  methods and kernel paths, and the device best-of-k argmin picks the same
  sample index as the host loop;
* flushes through a :class:`BucketBufferPool` (staging reuse + donated
  device inputs) return identical results on reuse;
* the packer's ``PackStats`` is the single source of pad accounting;
* both serving paths satisfy the :class:`ClusterEngine` protocol.
"""

import jax
import numpy as np
import pytest

from repro.core import (
    BucketBufferPool,
    build_graph,
    correlation_cluster,
    correlation_cluster_batch,
    plan_graph,
)
from repro.core import batch as batch_mod
from repro.core.batch import _cost_host
from repro.core.graph import gnp, random_arboric, star
from repro.serve.batching import ContinuousBatcher
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
from repro.serve.engine import ClusterEngine, EngineStats, serve_all
from repro.util import VirtualClock, next_pow2


def _rand_graph(n, lam, seed):
    edges, _ = random_arboric(n, lam, np.random.default_rng(seed))
    return build_graph(n, edges)


def _assert_matches(g, key, res_batch, **kwargs):
    res_single = correlation_cluster(g, key=key, **kwargs)
    assert (res_batch.labels == res_single.labels).all()
    assert res_batch.cost == res_single.cost


# ---------------------------------------------------------------------------
# pow2 helper + packer-stat single-sourcing (satellite: no drift).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("x,want", [(0, 1), (1, 1), (2, 2), (3, 4), (4, 4),
                                    (5, 8), (63, 64), (64, 64), (65, 128)])
def test_next_pow2(x, want):
    assert next_pow2(x) == want


def test_pack_stats_match_batcher_stats():
    """ClusterStats.padded_slots comes straight from the packer: a full
    flush of 4 path graphs pads nothing, the deadline flush of the 3
    stragglers pads one group = k entries."""
    from repro.core.graph import path

    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, num_samples=3, max_wait=1.0,
                             clock=clock)
    for i in range(7):      # path(6): one (8, 4) bucket for all requests
        batcher.admit(ClusterRequest(uid=i, graph=build_graph(6, path(6)),
                                     key=jax.random.PRNGKey(i)))
    clock.advance(2.0)
    batcher.poll()
    assert batcher.pending() == 0
    assert batcher.stats.clustered == 7
    assert batcher.stats.flushes == 2
    # full flush: G=4 → pad 0; deadline flush: G=3 → pad (4−3)·k = 3.
    assert batcher.stats.padded_slots == 3
    assert batcher.stats.pad_vertex_waste == 7 * (8 - 6)
    # Cross-check the packer directly under the same grouping.
    _, pack = correlation_cluster_batch(
        [build_graph(6, path(6))] * 3,
        keys=[jax.random.PRNGKey(i) for i in (4, 5, 6)],
        num_samples=3, with_stats=True)
    assert pack.padded_entries == 3


def test_engine_returns_pack_stats():
    graphs = [_rand_graph(n, 2, seed=n) for n in (9, 10, 20)]
    results, stats = correlation_cluster_batch(
        graphs, keys=[jax.random.PRNGKey(i) for i in range(3)],
        num_samples=2, with_stats=True)
    assert len(results) == 3
    assert stats.n_graphs == 3
    assert stats.n_entries == 6
    # groups pad to pow2 per bucket; entries pad by the same factor k
    assert stats.padded_entries % 2 == 0
    assert stats.pad_vertex_waste == sum(
        plan_graph(g).R - g.n for g in graphs)
    for (R, W, B) in stats.bucket_shapes:
        assert B % 2 == 0 and next_pow2(B // 2) == B // 2


# ---------------------------------------------------------------------------
# Deadline (partial-bucket) flush bit-exactness.
# ---------------------------------------------------------------------------


def test_deadline_partial_flush_bit_exact():
    """A max_wait flush runs a partial bucket — results must still be
    bit-identical to the per-graph engine."""
    rng = np.random.default_rng(3)
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=64, max_wait=0.5, clock=clock)
    reqs = []
    for i in range(5):
        n = int(rng.integers(5, 40))
        g = _rand_graph(n, 2, seed=100 + i)
        req = ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i))
        reqs.append(req)
        assert batcher.admit(req) == []     # nothing fills a 64-bucket
    assert batcher.poll() == []             # not overdue yet
    clock.advance(1.0)
    retired = batcher.poll()
    assert sorted(r.uid for r in retired) == list(range(5))
    assert batcher.pending() == 0
    assert batcher.stats.deadline_flushes >= 1
    for r in reqs:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)


def test_deadline_flush_only_overdue_buckets():
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=64, max_wait=1.0, clock=clock)
    g_small = _rand_graph(6, 1, seed=1)     # R=8 bucket
    g_big = _rand_graph(30, 1, seed=2)      # R=32 bucket
    batcher.admit(ClusterRequest(uid=0, graph=g_small,
                                 key=jax.random.PRNGKey(0)))
    clock.advance(0.8)
    batcher.admit(ClusterRequest(uid=1, graph=g_big,
                                 key=jax.random.PRNGKey(1)))
    clock.advance(0.4)                      # uid0 is 1.2s old, uid1 0.4s
    retired = batcher.poll()
    assert [r.uid for r in retired] == [0]
    assert batcher.pending() == 1
    clock.advance(1.0)
    assert [r.uid for r in batcher.poll()] == [1]


def test_serve_all_driver_retires_everything_once():
    rng = np.random.default_rng(9)
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, max_wait=10.0, clock=clock)
    stream = []
    for i in range(9):
        n = int(rng.integers(5, 30))
        stream.append(ClusterRequest(uid=i, graph=_rand_graph(n, 2, seed=i),
                                     key=jax.random.PRNGKey(i)))
    retired = serve_all(batcher, stream)
    assert sorted(r.uid for r in retired) == list(range(9))
    assert all(r.done for r in retired)
    assert batcher.stats.retired == 9
    for r in retired:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)


# ---------------------------------------------------------------------------
# Device-side cost == host oracle; device argmin == host argmin.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["pivot", "pivot_raw"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_device_cost_matches_host_oracle(method, use_kernel):
    rng = np.random.default_rng(11)
    graphs, keys = [], []
    for i in range(10):
        n = int(rng.integers(4, 50))
        graphs.append(build_graph(n, gnp(n, 0.15, rng)))
        keys.append(jax.random.PRNGKey(500 + i))
    # star: exercises cap-dropped edges (always cut) in the cost identity
    graphs.append(build_graph(40, star(40)))
    keys.append(jax.random.PRNGKey(999))
    results = correlation_cluster_batch(graphs, keys=keys, method=method,
                                        use_kernel=use_kernel)
    for g, res in zip(graphs, results):
        assert res.cost == _cost_host(g, res.labels), (g.n, method)


def test_device_argmin_matches_host_pick():
    """Best-of-k selection on device picks the identical sample index."""
    for seed in range(6):
        g = _rand_graph(12 + seed, 2, seed=seed)
        key = jax.random.PRNGKey(seed)
        (res,) = correlation_cluster_batch([g], keys=[key], num_samples=5)
        single = correlation_cluster(g, key=key, num_samples=5)
        assert res.info["picked_sample"] == single.info["picked_sample"]
        assert (res.labels == single.labels).all()
        assert res.cost == single.cost


# ---------------------------------------------------------------------------
# Donated buffer pool: identical results on reuse, O(#buckets) staging.
# ---------------------------------------------------------------------------


def test_pool_reuse_bit_identical():
    graphs = [_rand_graph(n, 2, seed=n) for n in (7, 9, 16, 33)]
    keys = [jax.random.PRNGKey(i) for i in range(4)]
    pool = BucketBufferPool()
    ref = correlation_cluster_batch(graphs, keys=keys, num_samples=2)
    for _ in range(3):          # repeated flushes reuse staging + donation
        got = correlation_cluster_batch(graphs, keys=keys, num_samples=2,
                                        pool=pool)
        for a, b in zip(got, ref):
            assert (a.labels == b.labels).all()
            assert a.cost == b.cost
    buckets = {plan_graph(g).bucket for g in graphs}
    assert pool.n_buffers == len(buckets)   # staging is O(#buckets)


def test_pool_reuse_with_different_graphs_no_stale_state():
    """Staging arrays are refilled in place — a smaller second flush must
    not see leftovers from a larger first flush in the same bucket."""
    pool = BucketBufferPool()
    dense = [build_graph(10, gnp(10, 0.5, np.random.default_rng(i)))
             for i in range(4)]
    keys4 = [jax.random.PRNGKey(i) for i in range(4)]
    correlation_cluster_batch(dense, keys=keys4, pool=pool)
    sparse = [_rand_graph(9, 1, seed=7)]
    (res,) = correlation_cluster_batch(sparse, keys=[jax.random.PRNGKey(7)],
                                       pool=pool)
    _assert_matches(sparse[0], jax.random.PRNGKey(7), res)


def test_batcher_warmup_precompiles_subbatch_programs():
    rng = np.random.default_rng(21)
    graphs = [_rand_graph(int(rng.integers(5, 12)), 1, seed=i)
              for i in range(4)]
    # num_samples=7 keys program-cache entries no other test compiles, so
    # the cold-warmup count below is robust to suite ordering (the LRU is
    # process-global).
    batcher = ClusterBatcher(max_batch=4, num_samples=7)
    compiled = batcher.warmup(graphs)
    assert compiled >= 1
    before = batch_mod.program_cache_size()
    for i, g in enumerate(graphs):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    batcher.flush()
    assert batch_mod.program_cache_size() == before, \
        "warmed engine must not compile during serving"


# ---------------------------------------------------------------------------
# Validation / edge cases (satellite).
# ---------------------------------------------------------------------------


def test_engine_rejects_bad_num_samples():
    g = _rand_graph(10, 1, seed=0)
    with pytest.raises(ValueError, match="num_samples"):
        correlation_cluster_batch([g], num_samples=0)
    with pytest.raises(ValueError, match="num_samples"):
        correlation_cluster_batch([g], num_samples=-3)


def test_batcher_clamps_num_samples_and_validates_args():
    assert ClusterBatcher(num_samples=0).num_samples == 1
    with pytest.raises(ValueError, match="max_batch"):
        ClusterBatcher(max_batch=0)
    with pytest.raises(ValueError, match="max_wait"):
        ClusterBatcher(max_wait=-1.0)


def test_width_exceeding_largest_bucket_raises():
    n = batch_mod.MAX_WIDTH + 2
    g = build_graph(n, star(n))     # hub degree n-1 > MAX_WIDTH
    with pytest.raises(ValueError, match="MAX_WIDTH"):
        plan_graph(g, method="pivot_raw")
    # ... and the batcher surfaces it at admission, not inside a flush.
    batcher = ClusterBatcher(method="pivot_raw")
    with pytest.raises(ValueError, match="MAX_WIDTH"):
        batcher.admit(ClusterRequest(uid=0, graph=g,
                                     key=jax.random.PRNGKey(0)))
    assert batcher.pending() == 0


def test_rows_exceeding_largest_bucket_raises():
    n = batch_mod.MAX_ROWS + 1
    g = build_graph(n, np.zeros((0, 2), dtype=np.int64))
    with pytest.raises(ValueError, match="MAX_ROWS"):
        plan_graph(g, method="pivot_raw")


def test_empty_graph_request_is_graceful():
    g0 = build_graph(0, np.zeros((0, 2), dtype=np.int64))
    (res,) = correlation_cluster_batch([g0])
    assert res.cost == 0 and res.labels.shape == (0,)
    batcher = ClusterBatcher(max_batch=1)
    retired = batcher.admit(ClusterRequest(uid=0, graph=g0,
                                           key=jax.random.PRNGKey(0)))
    assert len(retired) == 1 and retired[0].result.cost == 0


# ---------------------------------------------------------------------------
# Protocol conformance (tentpole: one engine API for both paths).
# ---------------------------------------------------------------------------


def test_both_paths_satisfy_engine_protocol():
    cluster = ClusterBatcher(max_batch=2)
    token = ContinuousBatcher(model=None, params=None, max_slots=1)
    assert isinstance(cluster, ClusterEngine)
    assert isinstance(token, ClusterEngine)
    # Idle engines: flush/retire are safe no-ops returning [].
    assert token.flush() == [] and token.retire() == []
    assert cluster.flush() == [] and cluster.retire() == []
    assert token.pending() == 0 and cluster.pending() == 0
    assert isinstance(cluster.stats, EngineStats)
    assert isinstance(token.stats, EngineStats)


class _ConstLogitModel:
    """Fake decode model: prefill/decode always argmax to a fixed token."""

    class cfg:
        vocab_size = 4

    def __init__(self, token, fail_on_decode=False):
        self.token = token
        self.fail_on_decode = fail_on_decode

    def _logits(self):
        import jax.numpy as jnp
        return jnp.zeros((1, 4)).at[0, self.token].set(5.0)

    def prefill(self, params, batch, cache_len):
        return self._logits(), {}

    def decode_step(self, params, tok, caches, pos):
        assert not self.fail_on_decode, \
            "decode ran for a request already finished at prefill"
        return self._logits(), caches


def test_token_path_retires_at_prefill():
    """EOS (or max_new_tokens) hit by the prefill token retires the request
    before any decode tick — no garbage token past the stop condition."""
    from repro.serve.batching import Request

    # Prefill emits EOS directly.
    eos_model = _ConstLogitModel(token=1, fail_on_decode=True)
    b = ContinuousBatcher(eos_model, params=None, max_slots=2, eos_token=1)
    done = b.admit(Request(uid=0, prompt=np.array([2, 3], np.int32),
                           max_new_tokens=5))
    assert [r.uid for r in done] == [0]
    assert done[0].out_tokens == [1]
    assert b.pending() == 0

    # max_new_tokens=1 satisfied by the prefill token (non-EOS).
    one_model = _ConstLogitModel(token=2, fail_on_decode=True)
    b2 = ContinuousBatcher(one_model, params=None, max_slots=1, eos_token=1)
    done = b2.admit(Request(uid=1, prompt=np.array([3], np.int32),
                            max_new_tokens=1))
    assert len(done) == 1 and done[0].out_tokens == [2]
    assert b2.flush() == []


def test_streaming_dedup_rejects_mismatched_reused_batcher():
    from repro.data.dedup import dedup_corpus_streaming
    from repro.data.synthetic import synthetic_corpus

    corpus = synthetic_corpus(n_docs=10, dup_fraction=0.5, mutate_p=0.05,
                              seed=1)
    reused = ClusterBatcher(num_samples=1)
    with pytest.raises(ValueError, match="reused batcher"):
        dedup_corpus_streaming(corpus, seed=1, num_samples=4, batcher=reused)


def test_streaming_dedup_matches_batched():
    from repro.data.dedup import dedup_corpus_batched, dedup_corpus_streaming
    from repro.data.synthetic import synthetic_corpus

    corpus = synthetic_corpus(n_docs=50, dup_fraction=0.5, mutate_p=0.05,
                              seed=3)
    rb = dedup_corpus_batched(corpus, threshold=0.45, seed=3)
    # Tiny buckets + aggressive deadline: many partial flushes, same answer.
    rs = dedup_corpus_streaming(corpus, threshold=0.45, seed=3,
                                max_batch=4, max_wait=0.0)
    assert (rs.labels == rb.labels).all()
    assert rs.clustering.cost == rb.clustering.cost
    assert (rs.keep == rb.keep).all()
    assert rs.clustering.info["flushes"] >= 1


def test_streaming_dedup_reused_batcher_deltas_are_per_call():
    """A reused engine carries lifetime stats; each streaming call's
    ``info`` must report only *its own* flush/sample activity. The
    shallow ``dataclasses.replace`` snapshot this guards against aliased
    the nested telemetry, so ``flush_samples`` read 0 for every call."""
    from repro.data.dedup import dedup_corpus_batched, dedup_corpus_streaming
    from repro.data.synthetic import synthetic_corpus

    batcher = ClusterBatcher(max_batch=4, max_wait=0.0, num_samples=4)
    c1 = synthetic_corpus(n_docs=30, dup_fraction=0.5, mutate_p=0.05, seed=3)
    c2 = synthetic_corpus(n_docs=40, dup_fraction=0.4, mutate_p=0.05, seed=4)
    r1 = dedup_corpus_streaming(c1, threshold=0.45, seed=3, max_batch=4,
                                max_wait=0.0, batcher=batcher)
    r2 = dedup_corpus_streaming(c2, threshold=0.45, seed=4, max_batch=4,
                                max_wait=0.0, batcher=batcher)
    for res in (r1, r2):
        info = res.clustering.info
        # Per-call deltas, not engine-lifetime totals: the nested-telemetry
        # sample count must agree with the top-level flush delta, and both
        # must be this call's own (>= 1, not the running sum).
        assert info["flushes"] >= 1
        assert info["flush_samples"] == info["flushes"]
    total = batcher.stats.latency.total_flushes
    assert (r1.clustering.info["flush_samples"]
            + r2.clustering.info["flush_samples"]) == total
    # And reuse did not bend the bit-exactness contract.
    rb2 = dedup_corpus_batched(c2, threshold=0.45, seed=4, num_samples=4)
    assert (r2.labels == rb2.labels).all()
    assert r2.clustering.cost == rb2.clustering.cost


class _GatedDeadlinePolicy:
    """One request in the system at a time: refuse admission while any
    queue is non-empty, flush only once the oldest request is ``max_wait``
    old. Progress therefore *requires* engine-clock time to advance while
    serve_all retries a rejected admission."""

    name = "gated-deadline"

    def __init__(self, max_wait: float):
        self.max_wait = max_wait

    def on_admit(self, queues, now, telemetry) -> bool:
        return not any(queues.values())

    def select_flushes(self, queues, now, telemetry):
        from repro.serve.scheduler import FlushDecision

        return [FlushDecision(bucket=b, count=len(q))
                for b, q in queues.items()
                if q and now - q[0].admitted_at >= self.max_wait]

    def on_retire(self, bucket, telemetry) -> None:
        pass


def test_serve_all_advances_virtual_clock_on_rejection():
    """Regression: serve_all backed off with a wall-clock ``time.sleep``
    even when the engine ran on a virtual clock, so a rejection loop spun
    with the deadline frozen — virtual time never moved, the gated bucket
    never flushed, and the loop never terminated. The backoff must advance
    the *engine's* clock when it is injectable."""
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=8, clock=clock,
                             policy=_GatedDeadlinePolicy(max_wait=0.01))
    graphs = [_rand_graph(10, 2, seed=s) for s in range(3)]
    reqs = [ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i))
            for i, g in enumerate(graphs)]
    retired = serve_all(batcher, reqs, reject_backoff=0.005)
    assert sorted(r.uid for r in retired) == [0, 1, 2]
    for g, r in zip(graphs, sorted(retired, key=lambda r: r.uid)):
        _assert_matches(g, jax.random.PRNGKey(r.uid), r.result)
    # Each gated admission needed >= max_wait of engine time to open.
    assert clock.t >= 0.02
    assert batcher.stats.rejected >= 2
    assert batcher.pending() == 0


def test_serve_all_fails_loudly_when_stalled():
    """An admission gate that can never open must surface as a loud
    RuntimeError after ``max_stalled_rounds`` no-progress retries, not an
    unbounded spin."""

    class _NeverAdmitPolicy(_GatedDeadlinePolicy):
        name = "never"

        def on_admit(self, queues, now, telemetry) -> bool:
            return False

    batcher = ClusterBatcher(max_batch=8, clock=VirtualClock(),
                             policy=_NeverAdmitPolicy(max_wait=1.0))
    req = ClusterRequest(uid=0, graph=_rand_graph(8, 2, seed=0),
                         key=jax.random.PRNGKey(0))
    with pytest.raises(RuntimeError, match="no progress"):
        serve_all(batcher, [req], reject_backoff=0.001,
                  max_stalled_rounds=25)
