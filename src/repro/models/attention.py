"""GQA attention: train/prefill (blocked-softmax), decode (KV cache), cross.

Three implementations with one contract:
* ``impl='pallas'``  — the Pallas flash-attention kernel (TPU target).
* ``impl='chunked'`` — pure-XLA blocked softmax (lax.scan over KV blocks with
  running max/denominator): O(S·block) memory, used for the dry-run lowering
  and long prefills on CPU. Same math as the kernel.
* ``impl='naive'``   — quadratic reference (tiny smoke shapes only).

GQA is computed in grouped layout ``(B, KH, G, S, hd)`` — KV is never
repeated to H heads (that materialization is what blows decode memory).
Decode attends a 1-token query against a padded cache with a position mask,
and relies on the sharding plan to shard the cache sequence dim across
'model' (flash-decoding style; softmax reductions over the sharded axis
lower to the psum/LSE-combine collectives visible in the dry-run HLO).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .common import Pm, apply_rope, dense_init, head_rms_norm, linear

NEG_INF = -1e30


def init_attention(cfg: ModelConfig, kg, dtype, plan, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    p = {
        "wq": Pm(dense_init(kg(), (d, nq), dtype), plan.P("embed", "heads")),
        "wk": Pm(dense_init(kg(), (d, nkv), dtype), plan.P("embed", "kv")),
        "wv": Pm(dense_init(kg(), (d, nkv), dtype), plan.P("embed", "kv")),
        "wo": Pm(dense_init(kg(), (nq, d), dtype), plan.P("heads", "embed")),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = Pm(jnp.ones((hd,), dtype), plan.P(None))
        p["k_norm"] = Pm(jnp.ones((hd,), dtype), plan.P(None))
    if cross:
        p["gate"] = Pm(jnp.zeros((1,), dtype), plan.P(None))
    return p


def _grouped(q, k):
    """Reshape q (B,S,H,hd) to (B,S,KH,G,hd) to match k's KH."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    return q.reshape(b, s, kh, h // kh, hd)


def _naive_attention(q, k, v, causal: bool, row_offset: int = 0):
    """q (B,Sq,KH,G,hd), k/v (B,Sk,KH,hd)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        rows = jnp.arange(sq)[:, None] + row_offset
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(rows >= cols, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunked_attention(q, k, v, causal: bool, row_offset: int = 0,
                       q_chunk: int = 512, kv_chunk: int = 1024):
    """Blocked-softmax attention in pure XLA (same math as the kernel).

    q (B,Sq,KH,G,hd), k/v (B,Sk,KH,hd). Memory O(q_chunk × kv_chunk).
    """
    b, sq, kh, g, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # Pad to multiples.
    pq = (-sq) % q_chunk
    pk = (-sk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    scale = 1.0 / (hd ** 0.5)

    kb = kp.reshape(b, nk, kv_chunk, kh, hd)
    vb = vp.reshape(b, nk, kv_chunk, kh, hd)
    qb = qp.reshape(b, nq, q_chunk, kh, g, hd)

    @jax.checkpoint
    def q_block(iq):
        qi = qb[:, iq].astype(jnp.float32) * scale     # (B,qc,KH,G,hd)

        @jax.checkpoint
        def kv_step(carry, ik):
            m, l, acc = carry
            ki = kb[:, ik].astype(jnp.float32)          # (B,kc,KH,hd)
            vi = vb[:, ik].astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki)  # (B,KH,G,qc,kc)
            rows = (iq * q_chunk + jnp.arange(q_chunk))[:, None] + row_offset
            cols = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = cols < sk                             # kv padding
            if causal:
                mask = mask & (rows >= cols)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            pexp = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(pexp, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", pexp, vi)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        l = jnp.where(l == 0.0, 1.0, l)
        out = acc / l[..., None]                         # (B,KH,G,qc,hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4))       # (B,qc,KH,G,hd)

    outs = jax.lax.map(q_block, jnp.arange(nq))          # (nq,B,qc,KH,G,hd)
    out = jnp.transpose(outs, (1, 0, 2, 3, 4, 5)).reshape(
        b, nq * q_chunk, kh, g, hd)
    return out[:, :sq].astype(q.dtype)


def _pallas_attention(q, k, v, causal: bool, row_offset: int = 0):
    from repro.kernels import ops as kops
    b, sq, kh, g, hd = q.shape
    qh = jnp.transpose(q.reshape(b, sq, kh * g, hd), (0, 2, 1, 3))
    kh_ = jnp.transpose(k, (0, 2, 1, 3))
    vh_ = jnp.transpose(v, (0, 2, 1, 3))
    out = kops.flash_attention(qh, kh_, vh_, causal=causal)
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(b, sq, kh, g, hd)
    return out


class AttnOutput(NamedTuple):
    out: jnp.ndarray
    k: Optional[jnp.ndarray]  # projected K (B,S,KH,hd) for cache building
    v: Optional[jnp.ndarray]


def attention(params, cfg: ModelConfig, plan, x, positions, *,
              kv_x=None, causal=True, impl="chunked",
              return_kv=False) -> AttnOutput:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, d = x.shape
    hd, h, kh = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    cross = kv_x is not None
    src = kv_x if cross else x
    q = linear(x, params["wq"]).reshape(b, s, h, hd)
    k = linear(src, params["wk"]).reshape(b, src.shape[1], kh, hd)
    v = linear(src, params["wv"]).reshape(b, src.shape[1], kh, hd)
    if cfg.qk_norm and "q_norm" in params:
        q = head_rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, params["k_norm"], cfg.norm_eps)
    if not cross and not cfg.attention_free:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    qg = _grouped(q, k)
    row_offset = src.shape[1] - s if causal else 0
    if impl == "naive":
        o = _naive_attention(qg, k, v, causal, row_offset)
    elif impl == "pallas":
        o = _pallas_attention(qg, k, v, causal, row_offset)
    else:
        o = _chunked_attention(qg, k, v, causal, row_offset)
    o = o.reshape(b, s, h * hd)
    out = linear(o, params["wo"])
    if "gate" in params:  # gated cross-attention (vlm)
        out = out * jnp.tanh(params["gate"].astype(out.dtype))
    return AttnOutput(out=out, k=k if return_kv else None,
                      v=v if return_kv else None)


def decode_attention(params, cfg: ModelConfig, plan, x, pos, cache_k, cache_v,
                     *, update_cache=True, rope_on_q=True,
                     mask_to_pos=True) -> AttnOutput:
    """One-token decode. x (B,1,D); cache_k/v (B,S,KH,hd); pos scalar.

    The position mask admits keys at indices <= pos. With the plan's
    ``seq_kv`` sharding the cache stays sharded across 'model' (and 'data'
    for B=1 long-context); the softmax reduction over the sharded axis is
    the flash-decoding LSE combine in the lowered HLO.
    """
    b, _, d = x.shape
    hd, h, kh = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    sk = cache_k.shape[1]
    q = linear(x, params["wq"]).reshape(b, 1, h, hd)
    k_new = linear(x, params["wk"]).reshape(b, 1, kh, hd)
    v_new = linear(x, params["wv"]).reshape(b, 1, kh, hd)
    if cfg.qk_norm and "q_norm" in params:
        q = head_rms_norm(q, params["q_norm"], cfg.norm_eps)
        k_new = head_rms_norm(k_new, params["k_norm"], cfg.norm_eps)
    if rope_on_q and not cfg.attention_free:
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)

    if update_cache:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))

    qg = _grouped(q, cache_k)                       # (B,1,KH,G,hd)
    scale = 1.0 / (hd ** 0.5)
    # Keep the cache in its storage dtype: einsum with a f32 accumulator
    # reads bf16 operands directly — upcasting first would materialize an
    # f32 copy of the whole (B,S,KH,hd) cache (2× cache HBM, fatal at 32k).
    s = jnp.einsum("bqkgd,bskd->bkgqs", (qg * scale).astype(cache_k.dtype),
                   cache_k, preferred_element_type=jnp.float32)
    if mask_to_pos:
        mask = jnp.arange(sk)[None, None, None, None, :] <= pos
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o.astype(x.dtype).reshape(b, 1, h * hd)
    out = linear(o, params["wo"])
    if "gate" in params:
        out = out * jnp.tanh(params["gate"].astype(out.dtype))
    return AttnOutput(out=out, k=cache_k, v=cache_v)


__all__ = ["init_attention", "attention", "decode_attention", "AttnOutput"]
