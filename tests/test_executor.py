"""Pluggable bucket-executor layer: sync ≡ async ≡ sharded, bit-exactly.

The contracts under test (core/plan.py, core/executor.py,
serve/cluster_batcher.py):

* all three executors return labels/costs/picked sample indices
  bit-identical to per-graph ``correlation_cluster`` — for full flushes,
  partial deadline flushes, and both kernel paths;
* ``BucketBufferPool`` leases: a staging buffer feeding an in-flight
  program is never handed out again until that flush's outputs are
  fetched (the async-overlap regression);
* ``max_in_flight`` admission backpressure rejects at admit time and
  counts the rejection;
* the compiled-program cache is a bounded LRU with eviction/compile
  counters, and eviction only costs a recompile, never correctness; its
  hint surface (``contains`` probe, ``touch`` recency refresh,
  ``pin``/``unpin`` protection) never mutates order on probes and never
  lets pins defeat the hard capacity bound;
* the sharded executor raises group padding to its device count (8-device
  proof runs in a subprocess, mirroring tests/test_dist.py).
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (
    AsyncExecutor,
    BucketBufferPool,
    BucketExecutor,
    ShardedExecutor,
    SyncExecutor,
    build_graph,
    correlation_cluster,
    correlation_cluster_batch,
    make_executor,
    plan_graph,
    pow2_device_mesh,
)
from repro.core import executor as exec_mod
from repro.core.api import sample_keys
from repro.core.executor import run_bucket_program
from repro.core.graph import path, random_arboric
from repro.core.plan import pack_bucket
from repro.serve.cluster_batcher import (
    AdmissionRejected,
    ClusterBatcher,
    ClusterRequest,
)
from repro.util import VirtualClock


def _rand_graph(n, lam, seed):
    edges, _ = random_arboric(n, lam, np.random.default_rng(seed))
    return build_graph(n, edges)


def _assert_matches(g, key, res_batch, **kwargs):
    res_single = correlation_cluster(g, key=key, **kwargs)
    assert (res_batch.labels == res_single.labels).all()
    assert res_batch.cost == res_single.cost


class _StallingExecutor(AsyncExecutor):
    """Async executor whose harvests are deferred until released — makes
    in-flight overlap deterministic for backpressure/lease tests."""

    def __init__(self):
        super().__init__()
        self.stalled = True

    def retire(self):
        return [] if self.stalled else super().retire()


# ---------------------------------------------------------------------------
# Bit-exactness: every executor, full + partial deadline flushes, both
# kernel paths (the tentpole contract).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
def test_executor_full_and_deadline_flushes_bit_exact(executor, use_kernel):
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, max_wait=1.0, clock=clock,
                             executor=executor, use_kernel=use_kernel,
                             num_samples=2)
    rng = np.random.default_rng(17)
    reqs = []
    for i in range(6):          # 4 fill one bucket; 2 become stragglers
        n = int(rng.integers(5, 13))
        req = ClusterRequest(uid=i, graph=_rand_graph(n, 2, seed=200 + i),
                             key=jax.random.PRNGKey(i))
        reqs.append(req)
        batcher.admit(req)
    clock.advance(2.0)
    batcher.poll()              # deadline partial flush for the stragglers
    batcher.flush()             # drains in-flight work too
    assert batcher.pending() == 0
    assert all(r.done for r in reqs)
    for r in reqs:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result,
                        num_samples=2)
    assert batcher.stats.clustered == 6
    assert batcher.stats.deadline_flushes >= 1


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
def test_precluster_executors_bit_exact(executor, use_kernel):
    """Satellite 3 of PR 10: the 'precluster' bucket program — full and
    deadline-partial flushes alike — is bit-identical to the per-graph
    'precluster' engine under every executor × kernel path, exactly like
    the pivot contract above."""
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, max_wait=1.0, clock=clock,
                             executor=executor, use_kernel=use_kernel,
                             method="precluster", num_samples=2)
    reqs = []
    for i in range(6):
        n = int(np.random.default_rng(40 + i).integers(5, 13))
        req = ClusterRequest(uid=i, graph=_rand_graph(n, 2, seed=300 + i),
                             key=jax.random.PRNGKey(i))
        reqs.append(req)
        batcher.admit(req)
    clock.advance(2.0)
    batcher.poll()
    batcher.flush()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.result.method == "precluster"
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result,
                        method="precluster", num_samples=2)


@pytest.mark.parametrize("executor", ["async", "sharded"])
def test_batch_api_executor_param_bit_exact(executor):
    graphs = [_rand_graph(n, 2, seed=n) for n in (7, 9, 16, 33)]
    keys = [jax.random.PRNGKey(i) for i in range(4)]
    pool = BucketBufferPool()
    results = correlation_cluster_batch(graphs, keys=keys, num_samples=2,
                                        executor=executor, pool=pool)
    for g, key, res in zip(graphs, keys, results):
        _assert_matches(g, key, res, num_samples=2)
    # One-shot calls harvest everything before returning: no leaked leases.
    assert pool.leased == 0


def test_async_executor_overlaps_then_drains():
    """Handles stay in flight across admits; flush() collects everything."""
    ex = AsyncExecutor()
    batcher = ClusterBatcher(max_batch=2, executor=ex)
    reqs = [ClusterRequest(uid=i, graph=build_graph(6, path(6)),
                           key=jax.random.PRNGKey(i)) for i in range(6)]
    retired = []
    for r in reqs:
        retired += batcher.admit(r)     # 3 full-bucket flushes dispatched
    retired += batcher.flush()
    assert sorted(r.uid for r in retired) == list(range(6))
    assert ex.in_flight == 0 and batcher.pending() == 0
    for r in reqs:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)


# ---------------------------------------------------------------------------
# Admission backpressure (max_in_flight).
# ---------------------------------------------------------------------------


def test_backpressure_rejects_at_admit_and_recovers():
    ex = _StallingExecutor()
    batcher = ClusterBatcher(max_batch=2, executor=ex, max_in_flight=1)
    g = build_graph(6, path(6))
    for i in range(2):          # fills the bucket → one in-flight flush
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    assert ex.in_flight == 1
    with pytest.raises(AdmissionRejected):
        batcher.admit(ClusterRequest(uid=2, graph=g,
                                     key=jax.random.PRNGKey(2)))
    assert batcher.stats.rejected == 1
    assert batcher.stats.submitted == 2     # the rejected one never entered
    ex.stalled = False
    done = batcher.flush()      # blocking harvest clears the backpressure
    assert sorted(r.uid for r in done) == [0, 1]
    out = batcher.admit(ClusterRequest(uid=2, graph=g,
                                       key=jax.random.PRNGKey(2)))
    assert out == []            # admitted fine once capacity freed
    assert batcher.stats.in_flight_peak == 1
    batcher.flush()


def test_batcher_validates_max_in_flight():
    with pytest.raises(ValueError, match="max_in_flight"):
        ClusterBatcher(max_in_flight=0)


# ---------------------------------------------------------------------------
# BucketBufferPool leases: the async-overlap regression (satellite).
# ---------------------------------------------------------------------------


def test_pool_lease_not_reused_while_outstanding():
    pool = BucketBufferPool()
    lease1 = pool.acquire(4, 8, 4)
    lease2 = pool.acquire(4, 8, 4)      # same shape, first still leased
    assert lease1.arrays["ell"] is not lease2.arrays["ell"]
    assert pool.n_buffers == 2 and pool.leased == 2
    lease1.release()
    lease1.release()                    # idempotent
    assert pool.leased == 1
    lease3 = pool.acquire(4, 8, 4)      # reuses the freed generation
    assert lease3.arrays["ell"] is lease1.arrays["ell"]
    assert pool.n_buffers == 2
    lease2.release()
    lease3.release()
    assert pool.leased == 0


def test_interleaved_async_flushes_never_refill_in_flight_staging():
    """Two same-shape flushes in flight at once must pack into *distinct*
    staging generations, and both must stay bit-exact — the regression
    guard for the async host↔device overlap path."""
    ex = _StallingExecutor()
    pool = BucketBufferPool()
    batcher = ClusterBatcher(max_batch=2, executor=ex, pool=pool)
    graphs = [_rand_graph(6, 1, seed=s) for s in range(4)]
    for i, g in enumerate(graphs):      # two flushes of the same (8,4) bucket
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    assert ex.in_flight == 2
    # Both flushes hold their own staging lease — nothing was refilled.
    assert pool.leased == 2 and pool.n_buffers == 2
    ex.stalled = False
    done = {r.uid: r for r in batcher.flush()}
    assert sorted(done) == [0, 1, 2, 3]
    assert pool.leased == 0             # harvest released both leases
    for i, g in enumerate(graphs):
        _assert_matches(g, jax.random.PRNGKey(i), done[i].result)
    # Steady state: the freed generations are reused, the pool stops growing.
    for i, g in enumerate(graphs):
        batcher.admit(ClusterRequest(uid=10 + i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    batcher.flush()
    assert pool.n_buffers == 2


def test_flush_failure_releases_lease_and_requeues_requests():
    """A failed dispatch must release the staging lease (no pool growth)
    and put the popped requests back so none are silently lost."""
    class _FailingExecutor(SyncExecutor):
        def __init__(self):
            super().__init__()
            self.fail = True

        def submit(self, *args, **kwargs):
            if self.fail:
                raise RuntimeError("injected submit failure")
            return super().submit(*args, **kwargs)

    ex = _FailingExecutor()
    pool = BucketBufferPool()
    batcher = ClusterBatcher(max_batch=2, executor=ex, pool=pool)
    g = build_graph(6, path(6))
    batcher.admit(ClusterRequest(uid=0, graph=g, key=jax.random.PRNGKey(0)))
    with pytest.raises(RuntimeError, match="injected"):
        batcher.admit(ClusterRequest(uid=1, graph=g,
                                     key=jax.random.PRNGKey(1)))
    assert pool.leased == 0             # lease released on the failure path
    assert batcher.pending() == 2       # both requests requeued
    ex.fail = False
    done = batcher.flush()              # retry succeeds with the same state
    assert sorted(r.uid for r in done) == [0, 1]
    for r in done:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)
    assert pool.leased == 0


def test_harvest_failure_requeues_requests_and_releases_lease():
    """A device-side error surfacing at fetch time must requeue the
    flush's requests, release its staging lease, and keep pending()
    accounting sound — then a retry must succeed."""
    class _Boom:
        def __array__(self, *args, **kwargs):
            raise RuntimeError("injected fetch failure")

    ex = _StallingExecutor()
    pool = BucketBufferPool()
    batcher = ClusterBatcher(max_batch=2, executor=ex, pool=pool)
    g = build_graph(6, path(6))
    for i in range(2):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    assert ex.in_flight == 1
    ex._pending[0]._outputs = (_Boom(),) * 4    # poison the fetch
    ex.stalled = False
    with pytest.raises(RuntimeError, match="injected fetch"):
        batcher.flush()
    assert pool.leased == 0                     # lease released on failure
    assert batcher.pending() == 2               # requests requeued, not lost
    done = batcher.flush()                      # retry re-packs and succeeds
    assert sorted(r.uid for r in done) == [0, 1]
    for r in done:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)


def test_handle_result_releases_lease_exactly_once():
    pool = BucketBufferPool()
    g = build_graph(6, path(6))
    plan = plan_graph(g)
    lease = pool.acquire(1, plan.R, plan.W)
    ell, ranks, elig, m, _ = pack_bucket(
        [plan], [sample_keys(jax.random.PRNGKey(0), 1)], k=1,
        staging=lease.arrays, g_pad=1)
    ex = AsyncExecutor()
    h = ex.submit(ell, ranks, elig, m, k=1, donate=pool.donate, lease=lease)
    assert pool.leased == 1
    h.result()
    h.result()      # second fetch is a no-op
    assert pool.leased == 0
    (res,) = correlation_cluster_batch([g], keys=[jax.random.PRNGKey(0)])
    assert (h.result()[0][0, :6].astype(np.int32) == res.labels).all()


# ---------------------------------------------------------------------------
# Bounded LRU program cache (satellite).
# ---------------------------------------------------------------------------


def test_program_cache_lru_evicts_and_recompiles_correctly():
    prev = exec_mod.set_program_cache_capacity(2)
    try:
        evict0 = exec_mod.program_cache_info()["evictions"]
        # Three distinct bucket shapes through a capacity-2 cache.
        graphs = [build_graph(6, path(6)), build_graph(12, path(12)),
                  build_graph(24, path(24))]
        keys = [jax.random.PRNGKey(i) for i in range(3)]
        for g, key in zip(graphs, keys):
            (res,) = correlation_cluster_batch([g], keys=[key])
            _assert_matches(g, key, res)
        info = exec_mod.program_cache_info()
        assert info["size"] <= 2 and info["capacity"] == 2
        assert info["evictions"] > evict0
        # The evicted shape recompiles and still answers bit-exactly.
        (res,) = correlation_cluster_batch([graphs[0]], keys=[keys[0]])
        _assert_matches(graphs[0], keys[0], res)
    finally:
        exec_mod.set_program_cache_capacity(prev)


def test_program_cache_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        exec_mod.set_program_cache_capacity(0)
    info = exec_mod.program_cache_info()
    assert info["size"] <= info["capacity"]


def _run_dummy(R, W, B=1, k=1, donate=False):
    """Compile/run one tiny bucket program of shape (B, R, W)."""
    ell = np.full((B, R, W), R, dtype=np.int32)
    ranks = np.full((B, R + 1), np.iinfo(np.int32).max, dtype=np.int32)
    elig = np.zeros((B, R + 1), dtype=bool)
    m = np.zeros((B,), dtype=np.int32)
    jax.block_until_ready(run_bucket_program(ell, ranks, elig, m, k=k,
                                             donate=donate))


def test_program_cache_contains_probe_is_non_mutating():
    prev = exec_mod.set_program_cache_capacity(2)
    try:
        _run_dummy(8, 4)        # key A (the LRU after B runs)
        _run_dummy(16, 4)       # key B
        assert exec_mod.program_cache_contains((1, 8, 4), 1)
        assert exec_mod.program_cache_contains((1, 16, 4), 1)
        # Different signature, same shape: not resident.
        assert not exec_mod.program_cache_contains((1, 8, 4), 2)
        assert not exec_mod.program_cache_contains((2, 8, 4), 1)
        # Probing A must NOT refresh it: a third shape evicts A (the true
        # LRU), which a mutating probe would have protected.
        assert exec_mod.program_cache_contains((1, 8, 4), 1)
        _run_dummy(32, 4)       # key C → evicts A
        assert not exec_mod.program_cache_contains((1, 8, 4), 1)
        assert exec_mod.program_cache_contains((1, 16, 4), 1)
    finally:
        exec_mod.set_program_cache_capacity(prev)


def test_program_cache_touch_refreshes_recency():
    prev = exec_mod.set_program_cache_capacity(2)
    try:
        _run_dummy(8, 4)
        _run_dummy(16, 4)
        # Touch the LRU shape: the next insert must evict the other one.
        assert exec_mod.program_cache_touch((8, 4)) >= 1
        assert exec_mod.program_cache_touch((64, 64)) == 0   # no-op miss
        _run_dummy(32, 4)
        assert exec_mod.program_cache_contains((1, 8, 4), 1)
        assert not exec_mod.program_cache_contains((1, 16, 4), 1)
    finally:
        exec_mod.set_program_cache_capacity(prev)


def test_program_cache_pin_protects_until_unpin_with_hard_capacity():
    prev = exec_mod.set_program_cache_capacity(2)
    try:
        _run_dummy(8, 4)
        assert exec_mod.program_cache_pin((8, 4)) >= 1
        assert (8, 4) in exec_mod.program_cache_info()["pinned"]
        # Churn: two fresh shapes; the pinned LRU survives both inserts.
        _run_dummy(16, 4)
        _run_dummy(32, 4)
        assert exec_mod.program_cache_contains((1, 8, 4), 1)
        assert exec_mod.program_cache_info()["size"] <= 2
        # Unpinned, the same churn evicts it.
        assert exec_mod.program_cache_unpin((8, 4))
        assert not exec_mod.program_cache_unpin((8, 4))      # idempotent
        _run_dummy(16, 4)
        _run_dummy(32, 4)
        assert not exec_mod.program_cache_contains((1, 8, 4), 1)
        # Pins are preferences, capacity is the law: with every resident
        # shape pinned, inserts still evict (hard bound, no growth).
        for bucket in [(16, 4), (32, 4), (64, 4)]:
            exec_mod.program_cache_pin(bucket)
        _run_dummy(64, 4)
        assert exec_mod.program_cache_info()["size"] <= 2
    finally:
        for bucket in list(exec_mod.program_cache_info()["pinned"]):
            exec_mod.program_cache_unpin(tuple(bucket))
        exec_mod.set_program_cache_capacity(prev)


def test_program_cache_pin_is_refcounted():
    """Pins are process-global while pinners are per-engine: each pin
    needs a matching unpin, and a shape stays protected while any pinner
    remains."""
    try:
        exec_mod.program_cache_pin((8, 4))
        exec_mod.program_cache_pin((8, 4))      # second pinner
        assert exec_mod.program_cache_unpin((8, 4))
        assert (8, 4) in exec_mod.program_cache_info()["pinned"]
        assert exec_mod.program_cache_unpin((8, 4))
        assert (8, 4) not in exec_mod.program_cache_info()["pinned"]
        assert not exec_mod.program_cache_unpin((8, 4))
    finally:
        while exec_mod.program_cache_unpin((8, 4)):
            pass


def test_program_cache_counts_compiles():
    info0 = exec_mod.program_cache_info()
    _run_dummy(8, 8)            # width-8 shape: unused elsewhere
    _run_dummy(8, 8)            # cache hit — no second compile
    info1 = exec_mod.program_cache_info()
    assert info1["compiles"] == info0["compiles"] + 1


# ---------------------------------------------------------------------------
# Factory / protocol / sharded group padding.
# ---------------------------------------------------------------------------


def test_make_executor_resolves_names_and_instances():
    assert isinstance(make_executor(None), SyncExecutor)
    assert isinstance(make_executor("async"), AsyncExecutor)
    assert isinstance(make_executor("sharded"), ShardedExecutor)
    ex = AsyncExecutor()
    assert make_executor(ex) is ex
    for impl in (SyncExecutor(), AsyncExecutor(), make_executor("sharded")):
        assert isinstance(impl, BucketExecutor)
    with pytest.raises(ValueError, match="unknown executor"):
        make_executor("turbo")
    with pytest.raises(TypeError, match="executor"):
        make_executor(42)


def test_sharded_group_pad_floors_at_device_count():
    ex = ShardedExecutor(mesh=pow2_device_mesh(1))
    assert ex.num_devices == 1
    assert ex.group_pad(3) == 4         # plain pow2 on a 1-device mesh
    assert ex.group_pad(0) == 1


def test_sync_executor_completes_at_submit():
    ex = SyncExecutor()
    g = build_graph(6, path(6))
    plan = plan_graph(g)
    ell, ranks, elig, m, _ = pack_bucket(
        [plan], [sample_keys(jax.random.PRNGKey(0), 1)], k=1)
    h = ex.submit(ell, ranks, elig, m, k=1)
    assert h.ready() and h.harvested
    assert ex.retire() == [h]           # delivered exactly once
    assert ex.retire() == []


# ---------------------------------------------------------------------------
# 8-virtual-device sharded execution (slow, subprocess — mirrors test_dist).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_executor_eight_devices_subprocess():
    """One flush spans all 8 host devices and stays bit-exact vs the
    per-graph engine, with group padding raised to the device count."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.core import (build_graph, correlation_cluster,
                                correlation_cluster_batch)
        from repro.core.executor import ShardedExecutor
        from repro.core.graph import random_arboric
        from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
        ex = ShardedExecutor()
        assert ex.num_devices == 8, ex.num_devices
        assert ex.group_pad(3) == 8     # floored at the device count
        rng = np.random.default_rng(4)
        graphs = [build_graph(n, random_arboric(n, 2, rng)[0])
                  for n in rng.integers(5, 30, size=12)]
        keys = [jax.random.PRNGKey(i) for i in range(12)]
        res, stats = correlation_cluster_batch(
            graphs, keys=keys, num_samples=2, executor=ex, with_stats=True)
        assert all(B % 8 == 0 for _, _, B in stats.bucket_shapes)
        for g, key, r in zip(graphs, keys, res):
            ref = correlation_cluster(g, key=key, num_samples=2)
            assert (r.labels == ref.labels).all(), "8-shard label mismatch"
            assert r.cost == ref.cost
        b = ClusterBatcher(max_batch=4, executor="sharded", num_samples=2)
        done = []
        for i, g in enumerate(graphs):
            done += b.admit(ClusterRequest(uid=i, graph=g,
                                           key=jax.random.PRNGKey(i)))
        done += b.flush()
        assert len(done) == 12
        for r in done:
            ref = correlation_cluster(r.graph,
                                      key=jax.random.PRNGKey(r.uid),
                                      num_samples=2)
            assert (r.result.labels == ref.labels).all()
            assert r.result.cost == ref.cost
        print("OK devices=", ex.num_devices)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_engine_close_is_pin_refcount_idempotent():
    """Double-close / close-then-__del__ must release an engine's pin
    refs exactly once: with two live engines pinning the same shape, one
    engine's sloppy teardown can never strip the other's pin."""
    from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
    from repro.serve.costmodel import ShapeHeat
    from repro.serve.scheduler import CostAwareCoalescingPolicy

    def make_engine():
        policy = CostAwareCoalescingPolicy(
            2, max_wait=10.0,
            heat=ShapeHeat(window=8, max_pinned=1, min_heat=1))
        return ClusterBatcher(policy=policy)

    engines = [make_engine(), make_engine()]
    for i, eng in enumerate(engines):
        for j in range(2):       # fill the (8, 4) bucket → flush → retire
            eng.admit(ClusterRequest(uid=j, graph=build_graph(6, path(6)),
                                     key=jax.random.PRNGKey(10 * i + j)))
        eng.flush()
    assert (8, 4) in exec_mod.program_cache_info()["pinned"]   # refcount 2

    a, b = engines
    a.close()
    a.close()                    # double close: second must be a no-op
    del a                        # __del__ after close: also a no-op
    assert (8, 4) in exec_mod.program_cache_info()["pinned"], \
        "engine A's teardown stole engine B's pin ref"
    b.close()
    assert (8, 4) not in exec_mod.program_cache_info()["pinned"]
    b.close()                    # close after the pin is gone: still safe
