"""Composable model stack covering the ten assigned architectures.

  common.py      — Pm (param+spec) leaves, norms, RoPE, linears
  sharding.py    — logical-axis sharding plans per (arch × shape × mesh)
  attention.py   — GQA attention (chunked / pallas / naive) + decode
  mlp.py         — SwiGLU + MoE (sort- and einsum-dispatch)
  ssm.py         — Mamba2 SSD (chunked + step)
  rwkv.py        — RWKV6 (scan + chunked)
  transformer.py — family assembly, scanned stacks, chunked CE loss
  decoding.py    — prefill / decode with per-family caches
  model.py       — facade + dry-run input specs
"""

from .model import Model, build_model
from .sharding import ShardingPlan, mesh_axis_sizes, resolve_plan
from .transformer import RunConfig

__all__ = ["Model", "build_model", "ShardingPlan", "resolve_plan",
           "mesh_axis_sizes", "RunConfig"]
