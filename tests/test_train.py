"""Training substrate: optimizer, accumulation, checkpoint/restart,
compression, elasticity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import RunConfig, build_model
from repro.train.checkpoint import (latest_step, prune_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.compression import compress_decompress
from repro.train.optimizer import OptConfig, lr_at, opt_init, opt_update
from repro.train.train_step import (StepConfig, TrainState, init_train_state,
                                    make_train_step)

RC = RunConfig(attn_impl="naive", loss_chunk=16)


def _model():
    cfg = get_smoke("smollm-135m")
    return cfg, build_model(cfg, rc=RC, param_dtype=jnp.float32)


def _batch(cfg, key, b=4, s=16):
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }


def test_accumulation_matches_single_batch():
    """accum=2 over a batch == accum=1 with the same global batch (to fp32
    tolerance): the microbatch loop is semantically invisible."""
    cfg, m = _model()
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    s1 = init_train_state(m, jax.random.PRNGKey(0), oc, StepConfig())
    s2 = TrainState(params=jax.tree.map(jnp.copy, s1.params),
                    opt=opt_init(s1.params, oc), err=None)
    batch = _batch(cfg, jax.random.PRNGKey(1), b=4)
    st1 = jax.jit(make_train_step(m, oc, StepConfig(accum_steps=1)))
    st2 = jax.jit(make_train_step(m, oc, StepConfig(accum_steps=2)))
    s1, m1 = st1(s1, batch)
    s2, m2 = st2(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         s1.params, s2.params)
    assert max(jax.tree.leaves(diffs)) < 5e-5


def test_lr_schedule():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                   min_lr_ratio=0.1)
    assert float(lr_at(oc, jnp.int32(0))) < 2e-4
    assert float(lr_at(oc, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_grad_clipping_bounds_update():
    """clip_by_global_norm actually bounds the global norm (Adam itself is
    scale-invariant, so we test the clip primitive, not param movement)."""
    from repro.train.optimizer import clip_by_global_norm, global_norm
    rng = np.random.default_rng(0)
    grads = (jnp.asarray(rng.normal(size=(32, 32)), jnp.float32) * 10.0,
             jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 5.0)
    clipped, norm = clip_by_global_norm(grads, 0.5)
    assert float(norm) > 0.5  # original norm was large
    assert float(global_norm(clipped)) <= 0.5 + 1e-4
    # direction preserved
    cos = float(jnp.sum(grads[0] * clipped[0])) / (
        float(jnp.linalg.norm(grads[0])) * float(jnp.linalg.norm(clipped[0]))
        + 1e-9)
    assert cos > 0.999


def test_checkpoint_roundtrip_and_resume_bitwise(tmp_path):
    """Restart from a checkpoint reproduces the uninterrupted trajectory
    bitwise (pure-function-of-step data pipeline + exact state restore)."""
    cfg, m = _model()
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    sc = StepConfig()
    step = jax.jit(make_train_step(m, oc, sc))

    def batches(i):
        return _batch(cfg, jax.random.PRNGKey(100 + i))

    # uninterrupted: 6 steps
    sA = init_train_state(m, jax.random.PRNGKey(0), oc, sc)
    lossesA = []
    for i in range(6):
        sA, mt = step(sA, batches(i))
        lossesA.append(float(mt["loss"]))

    # interrupted at 3 + restore
    sB = init_train_state(m, jax.random.PRNGKey(0), oc, sc)
    for i in range(3):
        sB, mt = step(sB, batches(i))
    save_checkpoint(tmp_path, 3, sB)
    del sB
    template = init_train_state(m, jax.random.PRNGKey(42), oc, sc)
    sB, manifest = restore_checkpoint(tmp_path, template)
    assert manifest["step"] == 3
    lossesB = []
    for i in range(3, 6):
        sB, mt = step(sB, batches(i))
        lossesB.append(float(mt["loss"]))
    assert lossesB == lossesA[3:], (lossesB, lossesA[3:])


def test_checkpoint_integrity_detection(tmp_path):
    cfg, m = _model()
    oc = OptConfig()
    state = init_train_state(m, jax.random.PRNGKey(0), oc, StepConfig())
    path = save_checkpoint(tmp_path, 1, state)
    # corrupt one byte
    import numpy as np
    f = path / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(Exception):
        restore_checkpoint(tmp_path, state)


def test_checkpoint_prune(tmp_path):
    cfg, m = _model()
    oc = OptConfig()
    state = init_train_state(m, jax.random.PRNGKey(0), oc, StepConfig())
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, state)
    prune_checkpoints(tmp_path, keep=2)
    assert latest_step(tmp_path) == 4
    import pathlib
    kept = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert kept == ["step_00000003", "step_00000004"]


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit (trivial 1-device) NamedShardings — the elastic
    re-mesh path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cfg, m = _model()
    oc = OptConfig()
    state = init_train_state(m, jax.random.PRNGKey(0), oc, StepConfig())
    save_checkpoint(tmp_path, 7, state)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, manifest = restore_checkpoint(tmp_path, state,
                                            shardings=shardings)
    same = jax.tree.map(lambda a, b: bool((np.asarray(a) ==
                                           np.asarray(b)).all()),
                        state, restored)
    assert all(jax.tree.leaves(same))


def test_compression_error_feedback():
    """Quantize→dequantize error is carried, so the *sum* over steps of
    dequantized grads tracks the true sum (unbiasedness in the limit)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    err = jnp.zeros_like(g_true)
    total_deq = jnp.zeros_like(g_true)
    steps = 50
    for _ in range(steps):
        (deq,), (err,) = compress_decompress((g_true,), (err,))
        total_deq = total_deq + deq
    drift = float(jnp.max(jnp.abs(total_deq - steps * g_true)))
    scale = float(jnp.max(jnp.abs(g_true)))
    assert drift < 0.05 * scale * 2  # residual bounded by one quantum


def test_opt_update_bf16_policy():
    cfg, m = _model()
    params, _ = m.init(jax.random.PRNGKey(0))
    oc = OptConfig(state_dtype="bfloat16")
    state = opt_init(params, oc)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    p2, s2, metrics = opt_update(grads, state, params, oc)
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(s2.mu))
    moved = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2)))
    assert 0 < moved < 1.0
