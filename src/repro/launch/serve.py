"""Serving driver: continuous batching over prefill/decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import RunConfig, build_model
from repro.models.sharding import ShardingPlan
from repro.serve.batching import ContinuousBatcher, Request


def run(arch: str, smoke: bool, n_requests: int, max_new: int,
        max_slots: int = 4, cache_len: int = 160, seed: int = 0):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    rc = RunConfig(attn_impl="naive" if smoke else "chunked",
                   rwkv_impl="scan", ssd_chunk=16)
    model = build_model(cfg, plan=ShardingPlan.null(), rc=rc,
                        param_dtype=jnp.float32)
    params, _ = model.init(jax.random.PRNGKey(seed))

    rng = np.random.default_rng(seed)
    batcher = ContinuousBatcher(model, params, max_slots=max_slots,
                                cache_len=cache_len)
    reqs = []
    for i in range(n_requests):
        ln = int(rng.integers(4, 24))
        prompt = rng.integers(2, cfg.vocab_size, ln).astype(np.int32)
        r = Request(uid=i, prompt=prompt,
                    max_new_tokens=int(rng.integers(2, max_new)))
        reqs.append(r)
        batcher.submit(r)
    batcher.run()
    st = batcher.stats
    print(f"served {n_requests} requests: prefills={st.prefills} "
          f"decode_steps={st.decode_steps} tokens={st.emitted_tokens} "
          f"wasted_slot_steps={st.wasted_slot_steps}")
    for r in reqs:
        assert r.done and len(r.out_tokens) >= 1, f"request {r.uid} unserved"
    return reqs, st


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)
    run(args.arch, smoke=args.smoke, n_requests=args.requests,
        max_new=args.max_new, max_slots=args.slots)


if __name__ == "__main__":
    main()
