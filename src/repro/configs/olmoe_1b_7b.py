"""olmoe-1b-7b [moe]: 16L, d=2048, 16H (kv=16), per-expert ff=1024,
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, moe_d_ff=1024, vocab_size=50304, head_dim=128,
        num_experts=64, experts_per_tok=8, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=64, moe_d_ff=64, vocab_size=512, head_dim=16,
        num_experts=8, experts_per_tok=2, vocab_round=64,
    )
