"""Data pipeline: dedup-by-correlation-clustering quality + deterministic
batching (the paper's first-class integration point)."""

import numpy as np
import pytest

from repro.data.dedup import dedup_corpus, dedup_quality, minhash_signatures, similarity_edges
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.data.synthetic import synthetic_corpus, token_stream


def test_minhash_similarity_reflects_jaccard():
    corpus = synthetic_corpus(n_docs=40, dup_fraction=0.5, mutate_p=0.05,
                              seed=1)
    sigs = minhash_signatures(corpus.docs, num_hashes=64)
    dup_pairs = [(i, int(corpus.duplicate_of[i]))
                 for i in range(len(corpus.docs))
                 if corpus.duplicate_of[i] >= 0]
    dup_sims = [np.mean(sigs[i] == sigs[j]) for i, j in dup_pairs]
    rng = np.random.default_rng(0)
    rand_sims = []
    orig = np.flatnonzero(corpus.duplicate_of < 0)
    for _ in range(50):
        i, j = rng.choice(orig, 2, replace=False)
        rand_sims.append(np.mean(sigs[i] == sigs[j]))
    assert np.mean(dup_sims) > 0.5 > np.mean(rand_sims) + 0.2


def test_dedup_end_to_end_quality():
    corpus = synthetic_corpus(n_docs=120, dup_fraction=0.4, mutate_p=0.05,
                              seed=2)
    res = dedup_corpus(corpus, threshold=0.45)
    q = dedup_quality(res, corpus)
    assert q["pairs_recall"] > 0.7, q
    assert q["pairs_precision"] > 0.9, q
    assert q["kept_fraction"] < 0.85, q


def test_dedup_distributed_matches_local():
    corpus = synthetic_corpus(n_docs=80, dup_fraction=0.4, seed=3)
    a = dedup_corpus(corpus, threshold=0.45, distributed=False, seed=5)
    b = dedup_corpus(corpus, threshold=0.45, distributed=True, seed=5)
    assert (a.labels == b.labels).all()


def test_similarity_graph_is_sparse():
    corpus = synthetic_corpus(n_docs=100, dup_fraction=0.3, seed=4)
    sigs = minhash_signatures(corpus.docs)
    edges = similarity_edges(sigs, threshold=0.45)
    n = len(corpus.docs)
    assert len(edges) < 0.1 * n * (n - 1) / 2, "graph should be sparse"


def test_pipeline_determinism_and_resume():
    stream = np.arange(100_000, dtype=np.int32) % 977
    cfg = PipelineConfig(seq_len=64, global_batch=8, seed=0)
    p1 = TokenPipeline(stream, cfg)
    p2 = TokenPipeline(stream, cfg)
    for step in (0, 3, 17):
        b1 = p1.batch_at(step)
        b2 = p2.batch_at(step)
        assert (b1["tokens"] == b2["tokens"]).all()
        assert (b1["labels"] == b2["labels"]).all()
    # labels are next-token shifted
    b = p1.batch_at(5)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_pipeline_sharding_partitions_batch():
    stream = np.arange(50_000, dtype=np.int32)
    cfg = PipelineConfig(seq_len=32, global_batch=8, seed=1)
    p = TokenPipeline(stream, cfg)
    full = p.batch_at(2)["tokens"]
    parts = [p.batch_at(2, shard=i, num_shards=4)["tokens"]
             for i in range(4)]
    assert (np.concatenate(parts) == full).all()


def test_token_stream_respects_keep_mask():
    corpus = synthetic_corpus(n_docs=20, dup_fraction=0.5, seed=5)
    keep = np.zeros(20, dtype=bool)
    keep[:5] = True
    s = token_stream(corpus, keep=keep)
    expect_len = sum(len(corpus.docs[i]) + 1 for i in range(5))
    assert len(s) == expect_len
