"""Greedy MIS: round-parallel ≡ sequential oracle, Algorithm 1 phases,
Fischer–Noever depth, Pallas-kernel path — the paper's R1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    algorithm1,
    build_graph,
    greedy_mis_parallel,
    greedy_mis_sequential,
    random_permutation_ranks,
    remaining_max_degree_after_prefix,
)
from repro.core.graph import gnp, random_arboric, star


def _mis_mask(state):
    return np.asarray(state.status) == 1


@pytest.mark.parametrize("n,lam,seed", [(50, 1, 0), (200, 3, 1), (400, 5, 2)])
def test_parallel_equals_sequential(n, lam, seed, rng):
    edges, _ = random_arboric(n, lam, rng)
    g = build_graph(n, edges)
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(seed))
    seq = greedy_mis_sequential(g, np.asarray(ranks))
    par = _mis_mask(greedy_mis_parallel(g, ranks))
    assert (seq == par).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 40), p=st.floats(0.05, 0.5), seed=st.integers(0, 99))
def test_parallel_equals_sequential_property(n, p, seed):
    rng = np.random.default_rng(seed)
    g = build_graph(n, gnp(n, p, rng))
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(seed))
    seq = greedy_mis_sequential(g, np.asarray(ranks))
    par = _mis_mask(greedy_mis_parallel(g, ranks))
    assert (seq == par).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 30), p=st.floats(0.05, 0.5), seed=st.integers(0, 99))
def test_mis_is_maximal_independent(n, p, seed):
    """Property: output is independent AND maximal (paper's MIS defn)."""
    rng = np.random.default_rng(seed)
    edges = gnp(n, p, rng)
    g = build_graph(n, edges)
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(seed))
    mis = _mis_mask(greedy_mis_parallel(g, ranks))
    und = g.undirected_edges()
    for u, v in und:
        assert not (mis[u] and mis[v]), "not independent"
    # maximality: every non-MIS vertex has an MIS neighbour
    adj = [set() for _ in range(n)]
    for u, v in und:
        adj[u].add(v)
        adj[v].add(u)
    for v in range(n):
        if not mis[v]:
            assert any(mis[u] for u in adj[v]), "not maximal"


def test_algorithm1_matches_global(rng):
    edges, _ = random_arboric(300, 4, rng)
    g = build_graph(300, edges)
    ranks = random_permutation_ranks(300, jax.random.PRNGKey(7))
    seq = greedy_mis_sequential(g, np.asarray(ranks))
    for sub in ("alg2", "alg3"):
        state, _, ledger = algorithm1(g, ranks=ranks, subroutine=sub)
        assert (_mis_mask(state) == seq).all(), sub
        assert ledger.total_rounds > 0
        assert len(ledger.phases) >= 1


def test_fischer_noever_depth_logarithmic(rng):
    """Depth grows like O(log n), not n — scaling sanity over 8× n range."""
    depths = {}
    for n in (250, 2000):
        edges, _ = random_arboric(n, 3, rng)
        g = build_graph(n, edges)
        ds = []
        for s in range(3):
            ranks = random_permutation_ranks(n, jax.random.PRNGKey(s))
            ds.append(int(greedy_mis_parallel(g, ranks).rounds))
        depths[n] = np.mean(ds)
    # 8x vertices should cost far less than 8x rounds.
    assert depths[2000] <= depths[250] * 3.0, depths


def test_lemma22_degree_drop(rng):
    """After greedy-processing a prefix of size t, max degree ≤ O(n log n/t)."""
    n = 2000
    edges, _ = random_arboric(n, 3, rng)
    g = build_graph(n, edges)
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(3))
    for t in (100, 500, 1500):
        d = remaining_max_degree_after_prefix(g, ranks, t)
        assert d <= 10 * n * np.log(n) / t


def test_star_graph_depth_constant(rng):
    """Star: the hub either wins round 1 or is removed round 1 — depth ≤ 2."""
    g = build_graph(100, star(100))
    for s in range(5):
        ranks = random_permutation_ranks(100, jax.random.PRNGKey(s))
        assert int(greedy_mis_parallel(g, ranks).rounds) <= 2


def test_kernel_path_equivalence(rng):
    edges, _ = random_arboric(300, 4, rng)
    g = build_graph(300, edges)
    ranks = random_permutation_ranks(300, jax.random.PRNGKey(11))
    a = greedy_mis_parallel(g, ranks)
    b = greedy_mis_parallel(g, ranks, use_kernel=True)
    assert (np.asarray(a.status) == np.asarray(b.status)).all()


def test_batched_permutation_ranks_bit_identical():
    """The packer's fused rank batch must be row-bit-identical to per-key
    calls — the property the batch engine's bit-exactness rests on."""
    from repro.core import random_permutation_ranks_batch

    for n in (1, 2, 7, 33, 96):
        keys = [jax.random.fold_in(jax.random.PRNGKey(5), i)
                for i in range(4)]
        batch = np.asarray(random_permutation_ranks_batch(n, keys))
        assert batch.shape == (4, n)
        for i, key in enumerate(keys):
            solo = np.asarray(random_permutation_ranks(n, key))
            assert (batch[i] == solo).all(), (n, i)
