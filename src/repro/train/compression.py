"""Int8 error-feedback gradient compression for cross-pod data parallelism.

Standard recipe (1-bit Adam / PowerSGD lineage, int8 variant): before the
cross-pod gradient reduction, quantize each gradient leaf to int8 with a
per-leaf scale, and add back the quantization error on the *next* step
(error feedback keeps the scheme unbiased in the long run). ICI bytes for
the DP all-reduce drop 4× (fp32→int8); convergence impact is negligible at
these scales (the residual is carried, not dropped).

The dry-run lowers this inside train_step when ``compress_cross_pod=True``;
the roofline collective term records the reduced byte count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g32, err):
    target = g32 + err
    scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = target - deq
    return deq, new_err


def compress_decompress(grads, err_tree):
    """Apply int8 quantize→dequantize with error feedback per leaf.

    Returns (dequantized grads fp32-equivalent, new error tree). The
    quantized representation is what crosses the pod link; XLA sees the
    int8 round-trip and the all-reduce operates on the dequantized values —
    in a production deployment the reduction itself runs on int8 with a
    custom reducer; here the byte saving is modeled by the int8 cast being
    visible in the HLO (documented simplification).
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(err_tree)[0]
    outs = [_quantize(g.astype(jnp.float32), e) for g, e in
            zip(flat_g, flat_e)]
    deq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return deq, new_err


__all__ = ["compress_decompress"]
