"""Distributed PIVOT/greedy-MIS via ``shard_map`` — the MPC ⇒ mesh mapping.

MPC machine ⇔ mesh device. The padded COO edge array is partitioned
contiguously across devices (each machine holds ``O(m/M)`` edges — the MPC
input distribution); per-vertex state is replicated (it is the ``O(n)``
aggregate message stream the broadcast/convergecast trees of §2.1.5 carry).

One MPC round ⇔ one collective phase:

* each device segment-reduces its local edge slab into a length-(n+1)
  candidate vector  (local computation — free in MPC),
* ``jax.lax.pmin`` across the mesh combines candidates (the convergecast
  tree; on a TPU torus XLA lowers this to an S-ary reduction exactly like
  Goodrich et al.'s broadcast trees),
* the replicated status update is the broadcast.

The whole while-loop lives inside a single ``shard_map`` so the lowered
program is one SPMD module whose collective schedule is inspectable by the
roofline tooling (`repro.launch.roofline` counts these collectives).

Output is bit-identical to the single-device engine (tested), because the
round dynamics are deterministic given π.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map

from .graph import Graph
from .mis import IN_MIS, INF_RANK, UNDECIDED, assign_to_min_rank_mis_neighbor


def edge_shard_mesh(num_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over available devices for edge-parallel clustering."""
    devs = np.array(jax.devices() if num_devices is None
                    else jax.devices()[:num_devices])
    return Mesh(devs, axis_names=("shard",))


def pow2_device_mesh(num_devices: Optional[int] = None,
                     axis_name: str = "shard") -> Mesh:
    """1-D mesh over the largest power-of-two prefix of local devices.

    The batch engine's group axis is always padded to a power of two, so a
    data-parallel split of that axis only divides evenly across a
    power-of-two device count. ``ShardedExecutor`` builds its mesh here: on
    an 8-device host this is all 8, on a 6-device host the first 4.
    """
    devs = jax.devices()
    limit = len(devs) if num_devices is None else max(1, min(num_devices,
                                                             len(devs)))
    count = 1 << (limit.bit_length() - 1)
    return Mesh(np.array(devs[:count]), axis_names=(axis_name,))


def _pad_edges_for_mesh(g: Graph, num_shards: int) -> Graph:
    """Re-pad the COO arrays so their length divides the shard count."""
    e = g.num_directed
    target = ((e + num_shards - 1) // num_shards) * num_shards
    if target == e:
        return g
    pad = target - e
    src = jnp.concatenate([g.src, jnp.full((pad,), g.n, jnp.int32)])
    dst = jnp.concatenate([g.dst, jnp.full((pad,), g.n, jnp.int32)])
    eid = jnp.concatenate([g.eid, jnp.full((pad,), g.m, jnp.int32)])
    row = g.row_offsets.at[g.n + 1].set(target)
    return Graph(n=g.n, m=g.m, src=src, dst=dst, row_offsets=row,
                 deg=g.deg, eid=eid)


def _local_segment_min(src, dst, vals_at_dst, mask_at_dst, n):
    """Per-device partial: min over the local edge slab, length n+1."""
    dst_ok = dst < n
    dst_idx = jnp.minimum(dst, n - 1)
    vals = jnp.where(dst_ok & mask_at_dst[dst_idx], vals_at_dst[dst_idx],
                     INF_RANK)
    return jnp.full((n + 1,), INF_RANK, jnp.int32).at[
        jnp.minimum(src, n)
    ].min(vals)


@partial(jax.jit, static_argnames=("n", "mesh", "packed"))
def _dist_mis_program(src, dst, ranks, n: int, mesh: Mesh,
                      packed: bool = False):
    """SPMD greedy-MIS: src/dst sharded over 'shard', state replicated.

    ``packed``: the hit-detection collective carries an int8 flag vector
    (pmax) instead of a second int32 rank pmin — the winner set is already
    globally known after the first pmin (every shard recomputes it from the
    replicated state), so only *adjacency to a winner* must cross the
    network. 8 → 5 bytes/vertex/round (§Perf H3 beyond-paper step).
    """

    def spmd(src_l, dst_l, ranks_r):
        def nbr_min(mask):
            local = _local_segment_min(src_l, dst_l, ranks_r, mask, n)
            return jax.lax.pmin(local, "shard")[:n]  # MPC convergecast

        def nbr_any(mask):
            """int8 OR-convergecast: does v have a neighbour in ``mask``."""
            dst_ok = dst_l < n
            dst_idx = jnp.minimum(dst_l, n - 1)
            vals = (dst_ok & mask[dst_idx]).astype(jnp.int8)
            local = jnp.zeros((n + 1,), jnp.int8).at[
                jnp.minimum(src_l, n)
            ].max(vals)
            return jax.lax.pmax(local, "shard")[:n] > 0

        def body(state):
            status, rounds = state
            und = status == UNDECIDED
            nmin = nbr_min(und)
            winners = und & (ranks_r < nmin)
            if packed:
                hit = und & (~winners) & nbr_any(winners)
            else:
                wmin = nbr_min(winners)
                hit = und & (~winners) & (wmin < INF_RANK)
            status = jnp.where(winners, jnp.int32(1), status)
            status = jnp.where(hit, jnp.int32(2), status)
            return status, rounds + 1

        def cond(state):
            status, _ = state
            return jnp.any(status == UNDECIDED)

        status0 = jnp.zeros((n,), jnp.int32)
        status, rounds = jax.lax.while_loop(cond, body, (status0, jnp.int32(0)))

        # PIVOT capture pass (one more convergecast round).
        in_mis = status == 1
        local = _local_segment_min(src_l, dst_l, ranks_r, in_mis, n)
        wmin = jax.lax.pmin(local, "shard")[:n]
        return status, rounds, wmin

    # check_rep=False: the pinned jax has no replication rule for `while`
    # inside shard_map; every out spec is replicated by construction (pmin /
    # pmax collectives close each round).
    return _shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P("shard"), P("shard"), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )(src, dst, ranks)


def distributed_pivot(g: Graph, ranks, mesh: Optional[Mesh] = None,
                      packed: bool = False
                      ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Edge-parallel PIVOT. Returns (labels, in_mis, rounds).

    ``packed=True`` switches the hit-detection collective to the int8
    OR-convergecast (see ``_dist_mis_program``): 8 → 5 bytes/vertex/round on
    the wire, bit-identical output (tested against the unpacked engine).
    """
    mesh = mesh or edge_shard_mesh()
    nshards = mesh.devices.size
    gp = _pad_edges_for_mesh(g, nshards)
    n = g.n
    ranks = jnp.asarray(ranks, jnp.int32)
    status, rounds, wmin = _dist_mis_program(gp.src, gp.dst, ranks, n, mesh,
                                             packed=packed)
    in_mis = status == 1

    rank_to_v = jnp.zeros((n,), jnp.int32).at[ranks].set(
        jnp.arange(n, dtype=jnp.int32))
    own = jnp.arange(n, dtype=jnp.int32)
    pivot_v = rank_to_v[jnp.minimum(wmin, n - 1)]
    labels = jnp.where(in_mis, own,
                       jnp.where(wmin < INF_RANK, pivot_v, own))
    return np.asarray(labels), np.asarray(in_mis), int(rounds)


__all__ = ["edge_shard_mesh", "pow2_device_mesh", "distributed_pivot"]
