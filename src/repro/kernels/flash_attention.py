"""Pallas TPU kernel: blocked (flash) attention forward with GQA + causal.

Canonical TPU tiling: grid ``(batch, q_heads, nQ, nKV)`` with the innermost
KV dimension marked "arbitrary" (sequential) and the softmax running stats
``(m, l)`` and the output accumulator carried in VMEM scratch across KV
steps. Block shapes are MXU-aligned (q/k blocks multiples of 128 rows,
head_dim padded to a multiple of 128 by the wrapper). KV blocks strictly
above the causal diagonal are skipped with ``pl.when`` (they are still
fetched by the pipeline — the index map is static — but contribute no
FLOPs; the wrapper instead *clips* the KV grid per Q block when the whole
tail is masked).

GQA: KV head index = q_head // (H // KH), expressed in the k/v BlockSpec
index maps, so KV tiles are fetched once per group from HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               num_kv_blocks: int, row_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: the whole KV block is masked iff its first column exceeds the
    # last (offset) row of the Q block. row_offset = Sk - Sq aligns a short
    # query suffix against a longer KV prefix (decode/chunked-prefill).
    run = True
    if causal:
        run = (ik * block_k) <= (iq * block_q + block_q - 1 + row_offset)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (bq, bk)
        if causal:
            rows = row_offset + iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_ref[:, :1]                            # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)        # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                  # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret",
                     "row_offset"),
)
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True, row_offset: int = 0
                    ) -> jnp.ndarray:
    """Blocked attention forward.

    Shapes: q (B, H, Sq, D), k/v (B, KH, Sk, D) with H % KH == 0.
    Sq/Sk must divide by the block sizes (wrapper in ops.py pads).
    ``row_offset`` aligns a short causal query block against a longer KV
    prefix (row_offset = Sk_real − Sq_real).
    """
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        row_offset=row_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom l
        ],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
    )(q, k, v)


__all__ = ["flash_attention"]
