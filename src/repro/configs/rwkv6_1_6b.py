"""rwkv6-1.6b [ssm] "Finch": 24L, d=2048, attention-free, ff=7168,
vocab=65536, data-dependent per-channel decay. [arXiv:2404.05892;
unverified]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536, head_dim=64,
        rwkv=True, rwkv_head_dim=64, rwkv_decay_lora=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        rwkv=True, rwkv_head_dim=16, rwkv_decay_lora=8, vocab_round=64,
    )
