"""Small shared utilities used across core / serve / kernels.

Kept dependency-free (stdlib only) so every layer can import it without
cycles — ``core.batch`` packs device tensors with it and the serving layer
uses it for slot accounting.
"""

from __future__ import annotations


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(1, x) (``next_pow2(0) == 1``).

    The single source of truth for every power-of-two padding decision in
    the batch engine and the serving layer: bucket rows/width, batch-axis
    sub-batches, and the pad accounting derived from them. Keeping one
    helper means the packer and the schedulers can never round differently.
    """
    return 1 << max(0, int(x) - 1).bit_length()


class VirtualClock:
    """Deterministic engine clock for tests, simulators and benchmarks.

    Injected as ``ClusterBatcher(clock=...)`` (the engine clock is the only
    time source scheduling decisions see), so deadline/steal behaviour can
    be driven in virtual time and traces replay exactly. One definition for
    every call site — tests and benchmarks must not fork their own copies
    that could drift.
    """

    __slots__ = ("t",)

    def __init__(self, start: float = 0.0):
        self.t = start

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


__all__ = ["next_pow2", "VirtualClock"]
