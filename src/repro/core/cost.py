"""Disagreement cost, brute-force optimum, and the Lemma 25 transform.

Cost convention (paper §1.3.2): for a clustering C of the complete signed
graph whose positive edges are ``E⁺``,

  cost(C) = |{(u,v) ∈ E⁺ : C(u) != C(v)}|                (positive disagr.)
          + Σ_cluster [ (|C| choose 2) − intra_positive(C) ]  (negative disagr.)
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph


@partial(jax.jit, static_argnames=("n",))
def _cost_impl(src, dst, labels, n: int):
    valid = src < n  # mask COO padding
    same = (labels[jnp.minimum(src, n - 1)] == labels[jnp.minimum(dst, n - 1)]) & valid
    # COO holds both directions: each undirected edge counted twice.
    # int32 accumulators: simulation-scale instances (n < 2^15 pair counts
    # stay well inside int32; jax x64 is disabled in this deployment).
    intra_pos = jnp.sum(same.astype(jnp.int32)) // 2
    pos_total = jnp.sum(valid.astype(jnp.int32)) // 2
    pos_disagree = pos_total - intra_pos

    sizes = jnp.zeros((n,), jnp.int32).at[labels].add(1)
    intra_pairs = jnp.sum(sizes * (sizes - 1) // 2)
    neg_disagree = intra_pairs - intra_pos
    return pos_disagree + neg_disagree, pos_disagree, neg_disagree


def clustering_cost(g: Graph, labels) -> int:
    """Total disagreements of ``labels`` (any integer cluster ids in [0, n))."""
    total, _, _ = _cost_impl(g.src, g.dst, jnp.asarray(labels, jnp.int32), g.n)
    return int(total)


def clustering_cost_split(g: Graph, labels) -> Tuple[int, int]:
    _, pos, neg = _cost_impl(g.src, g.dst, jnp.asarray(labels, jnp.int32), g.n)
    return int(pos), int(neg)


def canonicalize(labels: np.ndarray) -> np.ndarray:
    """Relabel clusters as first-occurrence indices (for comparisons)."""
    labels = np.asarray(labels)
    _, inv = np.unique(labels, return_inverse=True)
    return inv.astype(np.int32)


def minmax_cost(g: Graph, labels) -> int:
    """Worst-vertex disagreement (min-max objective, arXiv 2502.12519).

    Per vertex v: its cut positive edges plus its missing intra-cluster
    positive edges; the clustering is scored by the *maximum* over
    vertices instead of the sum. Numpy host oracle over the full graph —
    the full-graph counterpart of the device cost pass in
    :mod:`repro.core.programs` (which scores the eligible-induced capped
    subgraph; the two agree exactly when the degree cap drops nothing).
    """
    from .programs import minmax_cost_host

    return minmax_cost_host(g.n, g.undirected_edges(), labels)


# ---------------------------------------------------------------------------
# Brute-force optimum (tiny n): enumerate set partitions via restricted
# growth strings (recursive).
# ---------------------------------------------------------------------------


def brute_force_opt(g: Graph, max_n: int = 10) -> Tuple[int, np.ndarray]:
    """Exact minimum-disagreement clustering by exhaustive enumeration."""
    n = g.n
    if n > max_n:
        raise ValueError(f"brute force limited to n <= {max_n}, got {n}")
    und = g.undirected_edges()
    adj = np.zeros((n, n), dtype=bool)
    for u, v in und:
        adj[u, v] = adj[v, u] = True

    best_cost, best = None, None
    # restricted growth strings via simple recursion
    a = np.zeros(n, dtype=np.int32)

    def rec(i: int, kmax: int):
        nonlocal best_cost, best
        if i == n:
            cost = 0
            for u in range(n):
                for v in range(u + 1, n):
                    same = a[u] == a[v]
                    if adj[u, v] and not same:
                        cost += 1
                    elif (not adj[u, v]) and same:
                        cost += 1
            if best_cost is None or cost < best_cost:
                best_cost, best = cost, a.copy()
            return
        for c in range(kmax + 1):
            a[i] = c
            rec(i + 1, max(kmax, c + 1))

    rec(0, 0)
    return int(best_cost), best


# ---------------------------------------------------------------------------
# Lemma 25: local-update transform. Repeatedly eject a vertex v* with
# d_C⁺(v*) ≤ 2λ−1 from any cluster of size ≥ 4λ−1; cost never increases.
# ---------------------------------------------------------------------------


def lemma25_transform(g: Graph, labels: np.ndarray, lam: int) -> np.ndarray:
    """Apply the Lemma 25 local updates until all clusters have ≤ 4λ−2 vertices.

    Returns new labels. Asserts the invariant the lemma proves: each ejection
    does not increase the number of disagreements.
    """
    n = g.n
    labels = canonicalize(np.asarray(labels).copy())
    und = g.undirected_edges()
    adj: list[set[int]] = [set() for _ in range(n)]
    for u, v in und:
        adj[u].add(v)
        adj[v].add(u)

    next_label = int(labels.max()) + 1 if n else 0
    changed = True
    while changed:
        changed = False
        # cluster membership map
        members: dict[int, list[int]] = {}
        for v in range(n):
            members.setdefault(int(labels[v]), []).append(v)
        for c, vs in members.items():
            if len(vs) <= 4 * lam - 2:
                continue
            cset = set(vs)
            # find v* with positive degree inside the cluster ≤ 2λ−1
            vstar = None
            for v in vs:
                if len(adj[v] & cset) <= 2 * lam - 1:
                    vstar = v
                    break
            assert vstar is not None, (
                "Lemma 25 guarantees a low-internal-degree vertex in any "
                f"cluster of size {len(vs)} > 4λ−2 (λ={lam})"
            )
            labels[vstar] = next_label
            next_label += 1
            changed = True
            break
    return canonicalize(labels)


__all__ = [
    "clustering_cost",
    "clustering_cost_split",
    "minmax_cost",
    "canonicalize",
    "brute_force_opt",
    "lemma25_transform",
]
