"""Arboricity estimation via degeneracy peeling.

The degeneracy ``d`` of a graph satisfies ``λ ≤ d ≤ 2λ − 1`` (Nash–Williams),
so it is a 2-approximation of arboricity usable in the Algorithm 4 degree
threshold — only the constant in ``O(λ/ε)`` moves.

Two implementations:
* :func:`degeneracy_sequential` — exact min-degree peeling (host oracle).
* :func:`degeneracy_parallel` — round-parallel doubling peeling: repeatedly
  strip all vertices of degree ≤ k, doubling k when the graph stops
  shrinking; returns an upper bound ≤ 2d in O(log²) rounds (standard MPC
  peeling; each strip round is one convergecast).
"""

from __future__ import annotations

import heapq
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph


def degeneracy_sequential(g: Graph) -> int:
    """Exact degeneracy via a min-degree peeling with a heap."""
    n = g.n
    if n == 0:
        return 0
    deg = np.asarray(g.deg).copy()
    dst = np.asarray(g.dst)
    row = np.asarray(g.row_offsets)
    removed = np.zeros(n, dtype=bool)
    heap = [(int(deg[v]), v) for v in range(n)]
    heapq.heapify(heap)
    degeneracy = 0
    seen = 0
    while heap and seen < n:
        d, v = heapq.heappop(heap)
        if removed[v] or d != deg[v]:
            continue
        removed[v] = True
        seen += 1
        degeneracy = max(degeneracy, d)
        for e in range(row[v], row[v + 1]):
            u = int(dst[e])
            if u < n and not removed[u]:
                deg[u] -= 1
                heapq.heappush(heap, (int(deg[u]), u))
    return int(degeneracy)


@partial(jax.jit, static_argnames=("max_iters",))
def _peel(g: Graph, max_iters: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Doubling peel: returns (k_bound, rounds). λ ≤ degeneracy ≤ k_bound."""
    n = g.n

    def live_deg(alive):
        dst_ok = g.dst < n
        dst_idx = jnp.minimum(g.dst, n - 1)
        contrib = (dst_ok & alive[dst_idx]).astype(jnp.int32)
        return jnp.zeros((n + 1,), jnp.int32).at[jnp.minimum(g.src, n)].add(
            contrib
        )[:n]

    def body(state):
        alive, k, rounds, _ = state
        deg = live_deg(alive)
        strip = alive & (deg <= k)
        new_alive = alive & ~strip
        stalled = ~jnp.any(strip)
        new_k = jnp.where(stalled, k * 2, k)
        return new_alive, new_k, rounds + 1, jnp.any(new_alive)

    def cond(state):
        _, _, rounds, more = state
        return more & (rounds < max_iters)

    alive0 = jnp.ones((n,), bool)
    _, k, rounds, _ = jax.lax.while_loop(
        cond, body, (alive0, jnp.int32(1), jnp.int32(0), jnp.bool_(n > 0))
    )
    return k, rounds


def degeneracy_parallel(g: Graph) -> Tuple[int, int]:
    """(upper bound on degeneracy, peel rounds used)."""
    k, rounds = _peel(g)
    return int(k), int(rounds)


def arboricity_bounds(g: Graph, exact: bool = True) -> Tuple[int, int]:
    """Return (lower, upper) bounds on arboricity λ.

    With ``exact`` degeneracy d: ceil((d+1)/2) ≤ λ ≤ d.
    """
    d = degeneracy_sequential(g) if exact else degeneracy_parallel(g)[0]
    lo = (d + 1 + 1) // 2
    return max(1, lo), max(1, d)


__all__ = [
    "degeneracy_sequential",
    "degeneracy_parallel",
    "arboricity_bounds",
]
