"""Lemma 25 structure, Corollary 32 clique algorithm, arboricity bounds."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    arboricity_bounds,
    build_graph,
    clique_clustering,
    clustering_cost,
    connected_components,
    degeneracy_parallel,
    degeneracy_sequential,
    lemma25_transform,
)
from repro.core.graph import (
    barbell,
    clique,
    disjoint_cliques,
    gnp,
    path,
    random_arboric,
    random_forest,
)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(6, 40), lam=st.integers(1, 4), seed=st.integers(0, 99))
def test_lemma25_transform_property(n, lam, seed):
    """From ANY clustering, the local updates reach ≤4λ−2 clusters without
    cost increase — the constructive content of Lemma 25."""
    rng = np.random.default_rng(seed)
    edges, _ = random_arboric(n, lam, rng)
    g = build_graph(n, edges)
    labels = rng.integers(0, max(1, n // 3), n).astype(np.int32)
    before = clustering_cost(g, labels)
    after_labels = lemma25_transform(g, labels, lam)
    after = clustering_cost(g, after_labels)
    assert after <= before
    assert np.bincount(after_labels).max() <= 4 * lam - 2


def test_lemma25_on_optimal_grows_nothing(rng):
    """Cor 27 special case: on forests the transform of the all-singleton
    clustering is free (already ≤ 2 = 4·1−2)."""
    e = random_forest(50, rng)
    g = build_graph(50, e)
    labels = np.arange(50, dtype=np.int32)
    out = lemma25_transform(g, labels, 1)
    assert clustering_cost(g, out) == clustering_cost(g, labels)


def test_clique_clustering_exact_on_cliques():
    n, e = disjoint_cliques([5, 3, 7, 2])
    g = build_graph(n, e)
    labels = np.asarray(clique_clustering(g))
    assert clustering_cost(g, labels) == 0


def test_clique_clustering_barbell_ratio():
    """Remark 33: barbell is the λ² tight case; algorithm must stay within
    O(λ²)·OPT (OPT = 1 disagreement)."""
    for lam in (3, 5, 8):
        n, e = barbell(lam)
        g = build_graph(n, e)
        labels = np.asarray(clique_clustering(g))
        cost = clustering_cost(g, labels)
        opt = 1
        assert cost <= 4 * lam * lam * opt  # O(λ²) with explicit constant
        # and it must not merge across the bridge
        assert labels[0] != labels[-1]


def test_clique_clustering_never_false_merges(rng):
    """Property: accepted groups are exactly clique components — on a path
    (no nontrivial cliques) everything is singleton."""
    g = build_graph(30, path(30))
    labels = np.asarray(clique_clustering(g))
    # path has K2 components only if isolated edges; a path of 30 has none
    # except... every adjacent pair has extra neighbours, so all singleton:
    assert (labels == np.arange(30)).all()
    # single edge → one 2-clique
    g2 = build_graph(2, np.array([[0, 1]]))
    l2 = np.asarray(clique_clustering(g2))
    assert l2[0] == l2[1]


def test_connected_components(rng):
    n, e = disjoint_cliques([4, 6, 3])
    g = build_graph(n, e)
    labels, iters = connected_components(
        g, np.ones(n, dtype=bool))
    labels = np.asarray(labels)
    assert len(np.unique(labels)) == 3
    assert int(iters) <= 8


@pytest.mark.parametrize("lam", [1, 2, 4])
def test_arboricity_bounds(lam, rng):
    edges, _ = random_arboric(100, lam, rng)
    g = build_graph(100, edges)
    lo, hi = arboricity_bounds(g)
    assert lo <= lam <= hi + 1  # degeneracy ≤ 2λ−1 ⇒ hi ≥ λ… allow slack
    assert hi <= 2 * lam  # union of λ forests has degeneracy ≤ 2λ−1


def test_degeneracy_parallel_upper_bounds_sequential(rng):
    edges, _ = random_arboric(150, 3, rng)
    g = build_graph(150, edges)
    d = degeneracy_sequential(g)
    k, rounds = degeneracy_parallel(g)
    assert k >= d
    assert k <= 4 * max(1, d)  # doubling peel ≤ 2× optimal, slack 4×
    assert rounds > 0


def test_clique_arboricity():
    g = build_graph(8, clique(8))
    d = degeneracy_sequential(g)
    assert d == 7  # K8 degeneracy
