"""Forest specialization (λ=1): Cor 27 / Lemma 29 / Cor 31."""

import jax
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    augmenting_matching_parallel,
    build_graph,
    brute_force_opt,
    clustering_cost,
    clustering_from_matching,
    correlation_cluster,
    matching_size,
    max_matching_forest,
    maximal_matching_parallel,
)
from repro.core.graph import path, random_forest


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 60), seed=st.integers(0, 99))
def test_maximal_matching_valid(n, seed):
    """Property: symmetric partner array; no free edge remains (maximal)."""
    rng = np.random.default_rng(seed)
    g = build_graph(n, random_forest(n, rng))
    partner, rounds = maximal_matching_parallel(g, jax.random.PRNGKey(seed))
    p = np.asarray(partner)
    for v in range(n):
        if p[v] >= 0:
            assert p[p[v]] == v
    und = g.undirected_edges()
    free = p < 0
    if len(und):
        assert not np.any(free[und[:, 0]] & free[und[:, 1]])
    assert int(rounds) <= n + 1


def test_exact_matching_is_optimum_clustering(rng):
    """Cor 27: cost(matching clustering) == brute-force OPT on tiny forests."""
    for n in (5, 7, 9):
        g = build_graph(n, random_forest(n, rng))
        opt, _ = brute_force_opt(g)
        partner = max_matching_forest(g)
        labels = clustering_from_matching(partner)
        assert clustering_cost(g, labels) == opt


def test_cost_formula(rng):
    """cost = m − |M| on forests."""
    g = build_graph(80, random_forest(80, rng))
    partner = max_matching_forest(g)
    labels = clustering_from_matching(partner)
    assert clustering_cost(g, labels) == g.m - matching_size(partner)


def test_lemma29_ratio(rng):
    """α-matching ⇒ α-approx clustering; maximal (α≤2) must satisfy it."""
    for seed in range(4):
        g = build_graph(120, random_forest(120, rng))
        m_star = matching_size(max_matching_forest(g))
        partner, _ = maximal_matching_parallel(g, jax.random.PRNGKey(seed))
        m = matching_size(partner)
        alpha = m_star / max(1, m)
        assert alpha <= 2.0 + 1e-9
        cost = clustering_cost(g, clustering_from_matching(np.asarray(partner)))
        opt = g.m - m_star
        assert cost <= alpha * max(opt, 1) + 1e-9 or cost <= opt + (m_star - m)


def test_augmentation_improves_toward_maximum(rng):
    g = build_graph(300, random_forest(300, rng))
    m_star = matching_size(max_matching_forest(g))
    p0, _ = maximal_matching_parallel(g, jax.random.PRNGKey(5))
    m0 = matching_size(p0)
    p1, _ = augmenting_matching_parallel(g, jax.random.PRNGKey(5), passes=6)
    m1 = matching_size(p1)
    assert m1 >= m0
    assert m1 >= 0.92 * m_star  # (1+ε)-regime after a few passes
    # flips preserved validity
    p = np.asarray(p1)
    for v in range(300):
        if p[v] >= 0:
            assert p[p[v]] == v


def test_path_worst_case():
    """Remark 30: P4 maximal matching can be half of maximum."""
    g = build_graph(4, path(4))
    m_star = matching_size(max_matching_forest(g))
    assert m_star == 2


def test_api_forest_methods(rng):
    g = build_graph(60, random_forest(60, rng))
    exact = correlation_cluster(g, method="forest_exact")
    approx = correlation_cluster(g, method="forest_approx",
                                 key=jax.random.PRNGKey(0))
    assert exact.cost <= approx.cost <= 2 * exact.cost + 1
