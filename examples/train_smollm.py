"""End-to-end driver: dedup → train a reduced smollm for a few hundred steps
with checkpointing (deliverable (b): train-kind end-to-end example).

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""

import argparse

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_smollm")
    args = ap.parse_args()
    out = run("smollm-135m", smoke=True, steps=args.steps,
              ckpt_dir=args.ckpt_dir, resume=False, fail_at=None,
              seq_len=128, global_batch=8, ckpt_every=50, dedup=True,
              log_every=10)
    losses = out["losses"]
    print(f"trained {len(losses)} steps: loss {losses[0]:.3f} → "
          f"{losses[-1]:.3f}")


if __name__ == "__main__":
    main()
