"""Continuous batching for clustering-as-a-service.

Same slot-based scheduling idiom as :class:`repro.serve.batching.
ContinuousBatcher` (admit into fixed-capacity slots, run the device program
over the whole batch, retire finished work), applied to graph queries
instead of token sequences: incoming graphs are **admitted** into the shape
bucket their padded ``(R, W)`` size maps to, a bucket **flushes** through
``correlation_cluster_batch`` the moment it fills ``max_batch`` slots (or on
``flush_all``), and flushed requests **retire** with their results attached.

Because the device program is jit-cached per bucket shape, a steady request
stream compiles O(#buckets) programs total no matter how many graphs flow
through — the clustering analogue of a shape-static decode batch. Empty
slots at flush time are padded with empty graphs (the standard accelerator
padding trade, tracked in :class:`ClusterStats.padded_slots`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import correlation_cluster_batch, plan_graph
from repro.core.api import ClusterResult
from repro.core.graph import Graph


@dataclasses.dataclass
class ClusterRequest:
    uid: int
    graph: Graph
    key: jax.Array
    lam: Optional[int] = None
    result: Optional[ClusterResult] = None
    done: bool = False


@dataclasses.dataclass
class ClusterStats:
    submitted: int = 0
    flushes: int = 0
    clustered: int = 0
    padded_slots: int = 0        # empty batch slots padded at flush time
    pad_vertex_waste: int = 0    # Σ (R − n) over clustered graphs
    buckets_seen: int = 0        # distinct (R, W) buckets ≈ compiled programs


class ClusterBatcher:
    """Buckets incoming graphs by padded shape and flushes full buckets."""

    def __init__(self, max_batch: int = 64, method: str = "pivot",
                 eps: float = 2.0, num_samples: int = 1,
                 use_kernel: bool = False):
        self.max_batch = max_batch
        self.method = method
        self.eps = eps
        self.num_samples = num_samples
        self.use_kernel = use_kernel
        self.buckets: Dict[Tuple[int, int], List[ClusterRequest]] = {}
        self._bucket_keys_seen: set = set()
        self.stats = ClusterStats()

    def submit(self, req: ClusterRequest) -> List[ClusterRequest]:
        """Admit a request; returns the retired batch if its bucket flushed."""
        plan = plan_graph(req.graph, method=self.method, eps=self.eps,
                          lam=req.lam)
        req.lam = plan.lam  # resolved once; the flush reuses it verbatim
        slot_list = self.buckets.setdefault(plan.bucket, [])
        slot_list.append(req)
        self.stats.submitted += 1
        self._bucket_keys_seen.add(plan.bucket)
        self.stats.buckets_seen = len(self._bucket_keys_seen)
        if len(slot_list) >= self.max_batch:
            return self._flush(plan.bucket)
        return []

    def _flush(self, bucket: Tuple[int, int]) -> List[ClusterRequest]:
        reqs = self.buckets.pop(bucket, [])
        if not reqs:
            return []
        results = correlation_cluster_batch(
            [r.graph for r in reqs],
            keys=[r.key for r in reqs],
            method=self.method,
            eps=self.eps,
            lams=[r.lam for r in reqs],
            num_samples=self.num_samples,
            use_kernel=self.use_kernel,
        )
        # The device batch carries num_samples entries per request, padded
        # to the next power of two (see core.batch._pack_bucket).
        n_entries = len(reqs) * max(1, self.num_samples)
        b_pad = 1 << max(0, (n_entries - 1).bit_length())
        self.stats.flushes += 1
        self.stats.padded_slots += b_pad - n_entries
        for req, res in zip(reqs, results):
            req.result = res
            req.done = True
            self.stats.clustered += 1
            self.stats.pad_vertex_waste += bucket[0] - req.graph.n
        return reqs

    def flush_all(self) -> List[ClusterRequest]:
        """Drain every bucket (end of stream / latency deadline)."""
        retired: List[ClusterRequest] = []
        for bucket in list(self.buckets):
            retired.extend(self._flush(bucket))
        return retired

    def pending(self) -> int:
        return sum(len(v) for v in self.buckets.values())


__all__ = ["ClusterRequest", "ClusterStats", "ClusterBatcher"]
