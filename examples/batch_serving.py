"""Clustering-as-a-service demo: streaming graphs through the engine API.

Simulates the north-star serving workload — a stream of small similarity
graphs (per-band near-dup buckets) arriving one at a time — under the
scheduling policies of the pluggable scheduler layer
(``repro.serve.scheduler``):

* **Full-bucket** (throughput mode): a bucket flushes only when it fills
  ``max_batch`` slots; stragglers wait for the end-of-stream drain.
* **Deadline** (latency mode): ``max_wait`` bounds how long any request
  can sit in a partial bucket; ``poll()`` flushes overdue buckets padded
  to the next power-of-two sub-batch.
* **Adaptive** (self-tuning pipelining): the deadline policy plus a
  dynamic in-flight admission window derived from observed flush latency
  — it replaces the hand-tuned ``max_in_flight`` knob. At the window,
  ``admit`` raises ``AdmissionRejected`` (here the demo just drains and
  retries — a real front-end would shed load).
* **Coalescing** (work-stealing): requests starving in a small shape
  bucket are promoted into a compatible larger bucket's flush, so no
  queue waits unboundedly behind a hot one.
* **Cost-aware coalescing** (priced work-stealing): every steal is priced
  by ``repro.serve.costmodel.FlushCostModel`` — pow2 pad inflation and
  promoted-row waste at the bucket's observed service time, plus any
  compile the inflated sub-batch would pay — and taken only when the wait
  it saves covers the bill. Its ``on_retire`` also feeds bucket-shape
  heat to the compiled-program LRU (touch/pin eviction hints).

The full-bucket/deadline drives also contrast the **async executor**
(pipelined mode): flushes are dispatched without blocking, so the engine
packs the next bucket while the previous one computes on device —
completed flushes are harvested on later ``admit``/``poll``/``flush``
calls.

Every result is bit-identical to running ``correlation_cluster`` on that
graph alone, under every policy and executor.

Run:  PYTHONPATH=src python examples/batch_serving.py
"""

import time

import jax
import numpy as np

from repro.core import build_graph
from repro.core.graph import random_arboric
from repro.serve.cluster_batcher import (
    AdmissionRejected,
    ClusterBatcher,
    ClusterRequest,
)


def make_stream(n_requests: int, seed: int = 42):
    rng = np.random.default_rng(seed)
    for uid in range(n_requests):
        n = int(rng.integers(8, 64))
        edges, _ = random_arboric(n, int(rng.integers(1, 4)), rng)
        yield ClusterRequest(uid=uid, graph=build_graph(n, edges),
                             key=jax.random.PRNGKey(uid))


def drive(batcher: ClusterBatcher, n_requests: int, label: str):
    print(f"\n--- {label} ---")
    t0 = time.perf_counter()
    waits, retired = [], 0

    def account(done):
        nonlocal retired
        now = batcher.clock()   # same clock base as req.admitted_at
        for r in done:
            retired += 1
            waits.append(now - r.admitted_at)
            if retired % 25 == 0:
                print(f"  uid={r.uid:3d} n={r.graph.n:3d} "
                      f"clusters={len(np.unique(r.result.labels)):3d} "
                      f"cost={r.result.cost:4d} "
                      f"bucket={r.result.info['bucket']}")

    for req in make_stream(n_requests):
        while True:
            try:
                account(batcher.admit(req))
                break
            except AdmissionRejected:
                # Backpressure: the executor is at max_in_flight. Harvest
                # whatever finished and retry (a front-end would 429 here).
                done = batcher.retire()
                account(done)
                if not done:
                    time.sleep(0.001)   # let the device catch up
        account(batcher.poll())
    account(batcher.flush())
    dt = time.perf_counter() - t0

    s = batcher.stats
    print(f"served {retired} queries in {dt:.2f}s "
          f"({retired / dt:.1f} graphs/s)  [policy={s.policy}]")
    print(f"flushes={s.flushes} (deadline={s.deadline_flushes}, "
          f"coalesced={s.coalesced_flushes})  "
          f"buckets_seen={s.buckets_seen}  padded_slots={s.padded_slots}  "
          f"pad_vertex_waste={s.pad_vertex_waste}")
    if s.stolen_requests:
        print(f"work-stealing: {s.stolen_requests} requests promoted into "
              "larger-bucket flushes")
    if s.rejected or s.in_flight_peak:
        print(f"backpressure: rejected={s.rejected}  "
              f"in_flight_peak={s.in_flight_peak}")
    if s.latency.total_flushes:
        print(f"flush latency: wall EWMA={s.latency.ewma_wall * 1e3:.1f}ms  "
              f"assemble EWMA={s.latency.ewma_assemble * 1e3:.1f}ms"
              + (f"  build EWMA={s.latency.ewma_build * 1e3:.2f}ms"
                 if s.latency.total_builds else ""))
    print(f"max in-engine wait: {max(waits):.3f}s")


def main():
    n_requests = 100
    print(f"streaming {n_requests} clustering queries (max_batch=16)...")
    drive(ClusterBatcher(max_batch=16, num_samples=2),
          n_requests, "full-bucket policy (throughput mode)")
    drive(ClusterBatcher(max_batch=16, num_samples=2, max_wait=0.05),
          n_requests, "deadline policy (max_wait=50ms, bounded tail)")
    # Pipelined serving: non-blocking flush dispatch + bounded in-flight
    # work. The same stream, same answers — packing just overlaps compute.
    drive(ClusterBatcher(max_batch=16, num_samples=2, max_wait=0.05,
                         executor="async", max_in_flight=4),
          n_requests, "async executor (pipelined flushes, max_in_flight=4)")
    # Self-tuning pipelining: the adaptive policy derives the in-flight
    # window from the flush-latency telemetry instead of the knob above.
    drive(ClusterBatcher(max_batch=16, num_samples=2, max_wait=0.05,
                         executor="async", policy="adaptive"),
          n_requests, "adaptive policy (latency-derived in-flight window)")
    # Work-stealing: requests stuck in a rare shape bucket ride a hot
    # bucket's flush at a promoted (R, W) shape — same answers, bounded
    # wait for the starved bucket.
    drive(ClusterBatcher(max_batch=16, num_samples=2, max_wait=0.05,
                         policy="coalesce"),
          n_requests, "coalescing policy (cross-bucket work-stealing)")
    # Priced work-stealing: same steals, but only when the wait saved
    # covers the pad/compile cost added; plus shape-heat eviction hints
    # to the compiled-program cache.
    cost_batcher = ClusterBatcher(max_batch=16, num_samples=2,
                                  max_wait=0.05, policy="cost")
    drive(cost_batcher, n_requests,
          "cost-aware coalescing (priced steals + eviction hints)")
    print(f"steal pricing: {cost_batcher.policy.cost_stats()}")


if __name__ == "__main__":
    main()
