"""Roofline tooling: HLO collective walker (trip counts, async starts,
participants) + analytic FLOPs sanity."""

import textwrap

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (
    Roofline,
    active_param_count,
    collective_stats,
    forward_flops,
    model_flops,
    step_flops,
)

HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
      %p = (s32[], f32[16,16]) parameter(0)
      %ar = f32[16,16]{1,0} all-reduce(%gte), channel_id=1, replica_groups=[4,8]<=[32], to_apply=%add
      ROOT %t = (s32[], f32[16,16]) tuple(%iv, %ar)
    }

    %cond (p2: (s32[], f32[16,16])) -> pred[] {
      %p2 = (s32[], f32[16,16]) parameter(0)
      ROOT %lt = pred[] compare(%iv2, %c), direction=LT
    }

    ENTRY %main (a: f32[16,16]) -> f32[16,16] {
      %a = f32[16,16]{1,0} parameter(0)
      %ag = f32[64,16]{1,0} all-gather(%a), channel_id=2, replica_groups=[8,4]<=[32], dimensions={0}
      %w = (s32[], f32[16,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      %cps = (f32[16,16], f32[16,16]) collective-permute-start(%a), channel_id=3, source_target_pairs={{0,1},{1,0}}
      %cpd = f32[16,16]{1,0} collective-permute-done(%cps)
      ROOT %out = f32[16,16]{1,0} add(%cpd, %a)
    }
""")


def test_collective_walker_trip_counts_and_async():
    cs = collective_stats(HLO, default_participants=32)
    # all-gather: 64*16*4 bytes × 4 participants = 16384
    assert cs.bytes_by_kind["all-gather"] == 64 * 16 * 4 * 4
    # all-reduce inside while ×10 trips, 8 participants
    assert cs.bytes_by_kind["all-reduce"] == 16 * 16 * 4 * 8 * 10
    assert cs.count_by_kind["all-reduce"] == 10
    # collective-permute-start counted once (max tuple element), done
    # skipped; participants = number of source_target_pairs (2 here)
    assert cs.bytes_by_kind["collective-permute"] == 16 * 16 * 4 * 2
    assert cs.count_by_kind["collective-permute"] == 1


def test_analytic_flops_scale_with_tokens():
    cfg = get_config("qwen3-8b")
    f1 = forward_flops(cfg, 1, 1024)
    f2 = forward_flops(cfg, 2, 1024)
    assert 1.9 < f2 / f1 < 2.1
    # ~2·N·D at short seq (attention negligible)
    n = cfg.param_count()
    assert 0.8 < f1 / (2 * n * 1024) < 1.3


def test_moe_active_params():
    cfg = get_config("olmoe-1b-7b")
    total = cfg.param_count()
    active = active_param_count(cfg)
    assert active < 0.35 * total  # 8/64 experts active (+dense parts)


def test_train_flops_is_3x_forward():
    cfg = get_config("granite-3-2b")
    shape = SHAPES["train_4k"]
    assert abs(step_flops(cfg, shape)
               / (3 * forward_flops(cfg, shape.global_batch,
                                    shape.seq_len)) - 1) < 1e-6


def test_decode_flops_excludes_encoder():
    cfg = get_config("whisper-base")
    dec = SHAPES["decode_32k"]
    pre = SHAPES["prefill_32k"]
    f_dec = step_flops(cfg, dec)
    f_pre = step_flops(cfg, pre)
    assert f_dec < 0.05 * f_pre  # one token vs 32k prompt + encoder


def test_roofline_terms_and_bottleneck():
    r = Roofline(chips=256, flops=1e18, bytes_hbm=1e12, coll_bytes=1e12,
                 hlo_flops_raw=1e16, hlo_bytes_raw=1e12, model_flops_=8e17)
    assert r.t_compute > r.t_memory
    assert r.bottleneck == "compute"
    assert 0.79 < r.useful_ratio < 0.81
    assert abs(r.roofline_fraction - 0.8) < 1e-6
