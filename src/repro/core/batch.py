"""Batched multi-graph PIVOT engine — shape-bucketed ELL clustering.

The per-graph engine (``correlation_cluster``) retraces and recompiles for
every new ``(n, m)`` shape, which is hopeless for serving millions of small
clustering queries (near-dup buckets, LSH bands, per-shard similarity
graphs). This module packs many small graphs into **shape buckets** and runs
the whole bucket through one fused device program:

Bucketing scheme
  Each graph is assigned a bucket key ``(R, W)`` where ``R`` is the vertex
  count rounded up to a power of two (min 8) and ``W`` is the max degree of
  the *eligible-induced* subgraph rounded up to a power of two (min 4). The
  Theorem 26 degree cap is what makes ``W`` small: clustered vertices have
  degree ≤ 12λ at ε=2, so ELL padding waste is bounded by the cap, exactly
  the property the paper's TPU adaptation exploits for single graphs. A
  bucket of ``B`` graphs is packed into

    ell      (B, R, W) int32  — per-graph ELL adjacency, pad entries = R
    ranks    (B, R+1)  int32  — per-graph permutation ranks, slot R = INF
    eligible (B, R+1)  bool   — degree-cap mask, slot R inactive

  and the batch axis is itself padded to a power of two with empty graphs,
  so the jit cache key is the bucket shape: **compile count is O(#buckets),
  not O(#graphs)**.

Round loop
  One ``lax.while_loop`` drives the *entire bucket*: every round does a
  batched neighbour-min (pure-jnp gather or the Pallas ``(batch, row_block)``
  grid kernel ``repro.kernels.neighbor_min.neighbor_min_ell_batch``), local
  minima join the MIS, their neighbours drop out, and per-graph ``done``
  masks (no undecided vertices left) freeze finished graphs while the rest
  keep iterating. The PIVOT capture pass (min-rank MIS neighbour) runs on
  device as one more batched gather before anything returns to the host.

Bit-exactness contract
  For the same per-graph PRNG key, ``correlation_cluster_batch`` returns
  labels and costs **bit-identical** to per-graph ``correlation_cluster``:
  ranks come from the same ``random_permutation_ranks(n_i, key_i)``, the
  round dynamics are the same deterministic integer min-propagation (gather
  over a complete eligible-induced neighbour list ≡ segment-min over the COO
  edge set), and the capture pass resolves the same min-rank pivots. The
  property suite in ``tests/test_batch.py`` enforces this across bucket
  boundaries (n = R−1, R, R+1) and both kernel paths.

Benchmark
  ``PYTHONPATH=src python benchmarks/batch_bench.py`` measures graphs/sec of
  the batch engine vs a per-graph loop and reports compile counts for both.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .arboricity import arboricity_bounds
from .degree_cap import degree_threshold
from .graph import Graph
from .mis import INF_RANK, random_permutation_ranks

UNDECIDED = 0
IN_MIS = 1
REMOVED = 2

MIN_ROWS = 8     # smallest R bucket
MIN_WIDTH = 4    # smallest W bucket


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


# ---------------------------------------------------------------------------
# Host-side packing.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphPlan:
    """Per-graph packing plan: bucket key + degree-cap metadata."""

    g: Graph
    n: int
    lam: Optional[int]          # resolved arboricity bound (None for raw)
    threshold: Optional[float]  # degree-cap threshold (None for raw)
    eligible: np.ndarray        # (n,) bool — vertices the inner PIVOT sees
    wreq: int                   # max eligible-induced degree
    R: int                      # row bucket (pow2)
    W: int                      # width bucket (pow2)

    @property
    def bucket(self) -> Tuple[int, int]:
        return (self.R, self.W)


def plan_graph(g: Graph, method: str = "pivot", eps: float = 2.0,
               lam: Optional[int] = None) -> GraphPlan:
    """Resolve the degree cap and the (R, W) shape bucket for one graph.

    Mirrors the per-graph api exactly: ``lam`` defaults to the degeneracy
    upper bound, eligibility is ``deg <= 8(1+ε)/ε·λ`` (Theorem 26), and for
    ``method='pivot_raw'`` every vertex is eligible.
    """
    n = g.n
    if method == "pivot":
        if lam is None:
            _, lam = arboricity_bounds(g, exact=n <= 200_000)
        threshold = degree_threshold(lam, eps)
        eligible = ~(np.asarray(g.deg) > threshold)
    elif method == "pivot_raw":
        lam, threshold = None, None
        eligible = np.ones(n, dtype=bool)
    else:
        raise ValueError(f"batch engine supports 'pivot'/'pivot_raw', "
                         f"got {method!r}")

    und = g.undirected_edges()
    if len(und):
        keep = eligible[und[:, 0]] & eligible[und[:, 1]]
        kept = und[keep]
        deg_ind = np.bincount(kept.ravel(), minlength=n) if len(kept) else \
            np.zeros(n, np.int64)
        wreq = int(deg_ind.max()) if len(kept) else 0
    else:
        wreq = 0

    return GraphPlan(
        g=g, n=n, lam=lam, threshold=threshold, eligible=eligible,
        wreq=wreq,
        R=max(MIN_ROWS, _next_pow2(max(1, n))),
        W=max(MIN_WIDTH, _next_pow2(max(1, wreq))),
    )


def _pack_bucket(plans: Sequence[GraphPlan], keys: Sequence[jax.Array]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack one bucket's graphs into (B_pad, R, W) ELL + state tensors."""
    R, W = plans[0].bucket
    b_pad = _next_pow2(len(plans))
    ell = np.full((b_pad, R, W), R, dtype=np.int32)
    ranks = np.full((b_pad, R + 1), np.iinfo(np.int32).max, dtype=np.int32)
    elig = np.zeros((b_pad, R + 1), dtype=bool)

    for i, (plan, key) in enumerate(zip(plans, keys)):
        n = plan.n
        und = plan.g.undirected_edges()
        if len(und):
            keep = plan.eligible[und[:, 0]] & plan.eligible[und[:, 1]]
            e = und[keep]
        else:
            e = np.zeros((0, 2), dtype=np.int64)
        if len(e):
            src = np.concatenate([e[:, 0], e[:, 1]])
            dst = np.concatenate([e[:, 1], e[:, 0]])
            order = np.argsort(src, kind="stable")
            src, dst = src[order], dst[order]
            deg = np.bincount(src, minlength=n)
            starts = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(deg, out=starts[1:])
            slot = np.arange(len(src)) - starts[src]
            ell[i, src, slot] = dst
        # Same per-graph permutation as the single-graph engine: ranks are a
        # function of (n, key) only, so bit-exactness holds per graph.
        ranks[i, :n] = np.asarray(random_permutation_ranks(n, key))
        elig[i, :n] = plan.eligible
    return ell, ranks, elig


# ---------------------------------------------------------------------------
# Device program: fused MIS round loop + PIVOT capture for a whole bucket.
# ---------------------------------------------------------------------------


def _gather_rows(table: jnp.ndarray, ell: jnp.ndarray) -> jnp.ndarray:
    """(B, R+1) per-graph state gathered through (B, R, W) neighbour ids."""
    return jax.vmap(lambda t, e: t[e])(table, ell)


@partial(jax.jit, static_argnames=("use_kernel",))
def _batch_pivot_program(ell, ranks_p, elig_p, use_kernel: bool = False):
    """Cluster every graph of one shape bucket in a single fused program.

    Args:
      ell: (B, R, W) int32 ELL adjacency, pad entries = R.
      ranks_p: (B, R+1) int32 ranks, slot R = INF.
      elig_p: (B, R+1) bool degree-cap eligibility, slot R False.
    Returns (labels (B, R), in_mis (B, R), rounds (B,)).
    """
    B, R, W = ell.shape
    ranks = ranks_p[:, :R]
    elig = elig_p[:, :R]
    # Rank gather is loop-invariant on the jnp path — hoisted out of the
    # while body; only the activity gather changes per round.
    nbr_ranks = None if use_kernel else _gather_rows(ranks_p, ell)

    def nbr_min(active: jnp.ndarray) -> jnp.ndarray:
        active_p = jnp.concatenate(
            [active, jnp.zeros((B, 1), active.dtype)], axis=1)
        if use_kernel:
            from repro.kernels import ops as _kops  # kernels stay optional

            return _kops.neighbor_min_ell_batch(ell, ranks_p, active_p)
        act = _gather_rows(active_p, ell)
        return jnp.min(jnp.where(act, nbr_ranks, INF_RANK), axis=2)

    def cond(carry):
        status, _ = carry
        return jnp.any(status == UNDECIDED)

    def body(carry):
        status, rounds = carry
        und = status == UNDECIDED            # UNDECIDED ⊆ eligible
        nmin = nbr_min(und)
        winners = und & (ranks < nmin)
        wmin = nbr_min(winners)
        hit = und & (~winners) & (wmin < INF_RANK)
        status = jnp.where(winners, IN_MIS, status)
        status = jnp.where(hit, REMOVED, status)
        # Per-graph done mask: finished graphs stop accumulating rounds.
        rounds = rounds + jnp.any(und, axis=1).astype(jnp.int32)
        return status, rounds

    status0 = jnp.where(elig, UNDECIDED, REMOVED).astype(jnp.int32)
    status, rounds = jax.lax.while_loop(
        cond, body, (status0, jnp.zeros((B,), jnp.int32)))

    # PIVOT capture pass: min-rank MIS neighbour, one batched convergecast.
    in_mis = status == IN_MIS
    wmin = nbr_min(in_mis)
    arange_r = jnp.arange(R, dtype=jnp.int32)
    rank_to_v = jax.vmap(
        lambda rk: jnp.zeros((R + 1,), jnp.int32).at[
            jnp.clip(rk, 0, R)].set(arange_r)
    )(ranks)
    piv = jnp.take_along_axis(rank_to_v, jnp.minimum(wmin, R), axis=1)
    own = jnp.broadcast_to(arange_r[None, :], (B, R))
    labels = jnp.where(in_mis, own,
                       jnp.where(wmin < INF_RANK, piv, own))
    labels = jnp.where(elig, labels, own)
    return labels, in_mis, rounds


def program_cache_size() -> int:
    """Number of compiled bucket programs (benchmark: O(#buckets))."""
    return int(_batch_pivot_program._cache_size())


# ---------------------------------------------------------------------------
# Host-side cost (numpy) — integer-exact, no per-shape recompiles.
# ---------------------------------------------------------------------------


def _cost_host(g: Graph, labels: np.ndarray) -> int:
    """Disagreement cost, same convention as ``core.cost.clustering_cost``.

    Pure numpy so a batch of 10k graphs doesn't pay 10k cost-kernel
    compiles; integer arithmetic keeps it bit-identical to the jit path.
    """
    und = g.undirected_edges()
    intra_pos = int((labels[und[:, 0]] == labels[und[:, 1]]).sum()) \
        if len(und) else 0
    pos_disagree = g.m - intra_pos
    sizes = np.bincount(labels, minlength=g.n)
    intra_pairs = int((sizes.astype(np.int64) * (sizes - 1) // 2).sum())
    return pos_disagree + (intra_pairs - intra_pos)


# ---------------------------------------------------------------------------
# Public entry point.
# ---------------------------------------------------------------------------


def correlation_cluster_batch(
    graphs: Sequence[Graph],
    keys: Optional[Sequence[jax.Array] | jax.Array] = None,
    method: str = "pivot",
    eps: float = 2.0,
    lams: Optional[Sequence[Optional[int]]] = None,
    num_samples: int = 1,
    use_kernel: bool = False,
) -> List["ClusterResult"]:
    """Cluster many graphs through the shape-bucketed batch engine.

    Args:
      graphs: the positive-edge graphs (``Graph`` instances).
      keys: per-graph PRNG keys (one key broadcast to all if a single key is
        given; defaults to ``PRNGKey(0)`` like the per-graph api).
      method: ``'pivot'`` (Theorem 26 degree cap + PIVOT, Corollary 28) or
        ``'pivot_raw'`` (no cap).
      lams: optional per-graph arboricity bounds (estimated when omitted).
      num_samples: best-of-k PIVOT — each graph is clustered under ``k``
        folded keys *within the same bucket* and the lowest-cost clustering
        wins, matching ``correlation_cluster(num_samples=k)`` bit-exactly.
      use_kernel: route neighbour-min through the batched Pallas kernel.

    Returns one :class:`repro.core.api.ClusterResult` per input graph with
    labels/costs bit-identical to per-graph ``correlation_cluster`` calls
    under the same keys.
    """
    from .api import ClusterResult, sample_keys  # deferred: api imports us

    graphs = list(graphs)
    n_graphs = len(graphs)
    if n_graphs == 0:
        return []
    if keys is None:
        keys = [jax.random.PRNGKey(0)] * n_graphs
    elif isinstance(keys, jax.Array) and keys.ndim <= 1:
        # One key (legacy uint32 (2,) or typed 0-d) broadcast to all graphs.
        keys = [keys] * n_graphs
    else:
        keys = list(keys)
    if len(keys) != n_graphs:
        raise ValueError(f"{len(keys)} keys for {n_graphs} graphs")
    if lams is None:
        lams = [None] * n_graphs

    plans = [plan_graph(g, method=method, eps=eps, lam=lam)
             for g, lam in zip(graphs, lams)]

    # Expand best-of-k samples as extra bucket entries (same shape bucket ⇒
    # same compiled program; the whole sweep rides the batch axis).
    entries: List[Tuple[int, int, GraphPlan, jax.Array]] = []
    for gi, (plan, key) in enumerate(zip(plans, keys)):
        for si, k in enumerate(sample_keys(key, num_samples)):
            entries.append((gi, si, plan, k))

    buckets: Dict[Tuple[int, int], List[int]] = {}
    for ei, (_, _, plan, _) in enumerate(entries):
        buckets.setdefault(plan.bucket, []).append(ei)

    labels_by_entry: Dict[int, np.ndarray] = {}
    rounds_by_entry: Dict[int, int] = {}
    for bucket_key, members in buckets.items():
        bplans = [entries[ei][2] for ei in members]
        bkeys = [entries[ei][3] for ei in members]
        ell, ranks, elig = _pack_bucket(bplans, bkeys)
        labels, _, rounds = _batch_pivot_program(
            jnp.asarray(ell), jnp.asarray(ranks), jnp.asarray(elig),
            use_kernel=use_kernel)
        labels = np.asarray(labels)
        rounds = np.asarray(rounds)
        for slot, ei in enumerate(members):
            labels_by_entry[ei] = labels[slot, : bplans[slot].n]
            rounds_by_entry[ei] = int(rounds[slot])

    # Best-of-k reduction per graph (first minimum wins, like the api loop).
    per_graph: Dict[int, List[Tuple[int, int]]] = {}
    for ei, (gi, si, _, _) in enumerate(entries):
        per_graph.setdefault(gi, []).append((si, ei))

    results: List[ClusterResult] = []
    for gi, (g, plan) in enumerate(zip(graphs, plans)):
        best = None
        for si, ei in sorted(per_graph[gi]):
            lab = labels_by_entry[ei].astype(np.int32)
            cost = _cost_host(g, lab)
            if best is None or cost < best[0]:
                best = (cost, lab, ei, si)
        cost, lab, ei, si = best
        info = {
            "bucket": plan.bucket,
            "depth": rounds_by_entry[ei],
            "engine": "batch",
        }
        if plan.threshold is not None:
            info.update(threshold=plan.threshold,
                        high_degree=int((~plan.eligible).sum()),
                        lambda_bound=plan.lam)
        if num_samples > 1:
            info.update(num_samples=num_samples, picked_sample=si)
        results.append(ClusterResult(labels=lab, cost=cost, method=method,
                                     info=info))
    return results


__all__ = [
    "GraphPlan",
    "plan_graph",
    "correlation_cluster_batch",
    "program_cache_size",
    "MIN_ROWS",
    "MIN_WIDTH",
]
