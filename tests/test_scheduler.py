"""Scheduling-policy layer: decisions, promotion, bit-exactness, leases.

The contracts under test (serve/scheduler.py, serve/cluster_batcher.py,
core/plan.py promote_plan, core/executor.py telemetry):

* policy unit behaviour — full-bucket/deadline/adaptive/coalescing
  ``select_flushes``/``on_admit`` decisions are pure functions of the
  queues, the injected engine clock and the telemetry (no wall-clock);
* shape promotion (``promote_plan``) validates its target, and coalesced
  flushes — requests running at a *promoted* ``(R, W)`` — stay
  bit-identical to per-graph ``correlation_cluster``;
* all four policies satisfy the bit-exactness contract under randomized
  arrival traces (hypothesis-style), while ``BucketBufferPool`` never
  hands out a staging buffer whose lease is outstanding;
* executor telemetry (wall/pack per flush) reaches ``ClusterStats`` and
  drives the adaptive admission window;
* ``serve_all`` retries rejected admissions, so backpressure policies can
  be driven by the reference outer loop.
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BucketBufferPool,
    build_graph,
    correlation_cluster,
    plan_graph,
    promote_plan,
)
from repro.core.executor import AsyncExecutor
from repro.core.graph import path, random_arboric
from repro.serve.cluster_batcher import (
    AdmissionRejected,
    ClusterBatcher,
    ClusterRequest,
)
from repro.serve.engine import serve_all
from repro.serve.scheduler import (
    AdaptivePolicy,
    CoalescingPolicy,
    DeadlinePolicy,
    FlushDecision,
    FlushTelemetry,
    FullBucketPolicy,
    SchedulerPolicy,
    make_policy,
)
from repro.util import VirtualClock


def _rand_graph(n, lam, seed):
    edges, _ = random_arboric(n, lam, np.random.default_rng(seed))
    return build_graph(n, edges)


def _assert_matches(g, key, res_batch, **kwargs):
    res_single = correlation_cluster(g, key=key, **kwargs)
    assert (res_batch.labels == res_single.labels).all()
    assert res_batch.cost == res_single.cost


@dataclasses.dataclass
class _Req:
    """Queue stand-in: policies only read ``admitted_at``."""

    admitted_at: float


def _queues(spec):
    """{bucket: [ages...]} → {bucket: [requests admitted at those times]}."""
    return {b: [_Req(admitted_at=t) for t in ts] for b, ts in spec.items()}


# ---------------------------------------------------------------------------
# Policy unit behaviour (pure decisions over queues + clock + telemetry).
# ---------------------------------------------------------------------------


def test_full_bucket_policy_flushes_only_full_queues():
    pol = FullBucketPolicy(max_batch=4)
    tele = FlushTelemetry()
    qs = _queues({(8, 4): [0.0, 0.1, 0.2], (16, 4): [0.0] * 4})
    out = pol.select_flushes(qs, now=10.0, telemetry=tele)
    assert out == [FlushDecision(bucket=(16, 4), count=4)]
    # Oversized queue drains in max_batch chunks within one call.
    qs = _queues({(8, 4): [0.0] * 9})
    out = pol.select_flushes(qs, now=0.0, telemetry=tele)
    assert [d.count for d in out] == [4, 4]


def test_deadline_policy_flags_overdue_partial_flushes():
    pol = DeadlinePolicy(max_batch=4, max_wait=1.0)
    tele = FlushTelemetry()
    qs = _queues({(8, 4): [0.0, 0.5], (16, 4): [4.5]})
    out = pol.select_flushes(qs, now=5.0, telemetry=tele)
    # (8, 4) is overdue and flushes its whole queue; (16, 4) aged only 0.5s.
    assert out == [FlushDecision(bucket=(8, 4), count=2, deadline=True)]
    assert pol.select_flushes(qs, now=0.9, telemetry=tele) == []


def test_adaptive_policy_window_tracks_latency_ratio():
    pol = AdaptivePolicy(max_batch=4, min_window=1, max_window=8)
    tele = FlushTelemetry(alpha=1.0)    # alpha=1: window = last sample
    assert pol.admission_window(tele) == 8      # cold: never throttle
    tele.record((8, 4), wall_s=0.100, pack_s=0.010)
    assert pol.admission_window(tele) == 8      # ceil(10) clamped to max
    tele.record((8, 4), wall_s=0.030, pack_s=0.010)
    assert pol.admission_window(tele) == 3      # device 3x the host
    tele.record((8, 4), wall_s=0.001, pack_s=0.010)
    assert pol.admission_window(tele) == 1      # host-bound: no pipelining
    # Queue-inclusive wall is normalized by the in-flight depth at submit:
    # 80ms of wall behind 7 other flushes is 10ms of service, not a signal
    # to deepen the window (the feedback loop the normalization breaks).
    tele.record((8, 4), wall_s=0.080, pack_s=0.010, depth=8)
    assert pol.admission_window(tele) == 1
    tele.in_flight = 1
    assert not pol.on_admit({}, now=0.0, telemetry=tele)
    tele.in_flight = 0
    assert pol.on_admit({}, now=0.0, telemetry=tele)


def test_static_backpressure_window_is_policy_driven():
    pol = FullBucketPolicy(max_batch=2, max_in_flight=2)
    tele = FlushTelemetry()
    tele.in_flight = 1
    assert pol.on_admit({}, now=0.0, telemetry=tele)
    tele.in_flight = 2
    assert not pol.on_admit({}, now=0.0, telemetry=tele)


def test_coalescing_policy_steals_compatible_starving_buckets():
    pol = CoalescingPolicy(max_batch=6, max_wait=2.0, steal_wait=1.0)
    tele = FlushTelemetry()
    qs = _queues({
        (16, 8): [0.0, 0.1],    # overdue at now=3 → deadline flush, room 4
        (8, 4): [1.5, 1.6],     # age ≥ steal_wait, < max_wait → stolen
        (8, 16): [1.5],         # W too large to fit (16, 8) → never stolen
        (32, 8): [1.5],         # R too large to fit (16, 8) → never stolen
    })
    (d,) = pol.select_flushes(qs, now=3.0, telemetry=tele)
    assert d.bucket == (16, 8) and d.count == 2 and d.deadline
    assert d.steal == (((8, 4), 2),)
    # Below the steal threshold nothing is stolen.
    (d,) = pol.select_flushes(qs, now=2.3, telemetry=tele)
    assert d.steal == ()


def test_full_flush_at_capacity_has_no_steal_room():
    pol = CoalescingPolicy(max_batch=4, steal_wait=0.0)
    qs = _queues({(16, 8): [0.0] * 4, (8, 4): [0.0]})
    (d,) = pol.select_flushes(qs, now=5.0, telemetry=FlushTelemetry())
    assert d.bucket == (16, 8) and d.count == 4 and d.steal == ()


def test_coalescing_steal_capacity_and_starvation_order():
    pol = CoalescingPolicy(max_batch=4, max_wait=10.0, steal_wait=0.0)
    qs = _queues({
        (32, 8): [0.0],             # overdue at now=11 → room for 3
        (8, 4): [9.0, 9.1],         # older queue → stolen first
        (16, 8): [9.5, 9.6],        # younger → only 1 of 2 fits
    })
    (d,) = pol.select_flushes(qs, now=11.0, telemetry=FlushTelemetry())
    assert d.bucket == (32, 8) and d.count == 1 and d.deadline
    assert d.steal == (((8, 4), 2), ((16, 8), 1))


def test_make_policy_resolution_and_validation():
    assert make_policy(None, max_batch=4).name == "full"
    assert make_policy(None, max_batch=4, max_wait=0.1).name == "deadline"
    assert make_policy("adaptive", max_batch=4,
                       max_in_flight=3).max_window == 3
    assert make_policy("coalesce", max_batch=4,
                       max_wait=1.0).steal_wait == 0.5
    pol = CoalescingPolicy(max_batch=2)
    assert pol.steal_wait == 0.0    # direct construction: steal when room
    assert make_policy(pol, max_batch=99) is pol
    # ... but the name route requires a deadline, or the policy would
    # silently degenerate to full-bucket (full flushes have no steal room).
    with pytest.raises(ValueError, match="coalesce.*max_wait|max_wait"):
        make_policy("coalesce", max_batch=4)
    for impl in (FullBucketPolicy(2), DeadlinePolicy(2, 0.1),
                 AdaptivePolicy(2), CoalescingPolicy(2)):
        assert isinstance(impl, SchedulerPolicy)
    with pytest.raises(ValueError, match="max_wait"):
        make_policy("deadline", max_batch=4)
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("turbo", max_batch=4)
    with pytest.raises(TypeError, match="policy"):
        make_policy(42, max_batch=4)
    with pytest.raises(ValueError, match="min_window"):
        AdaptivePolicy(4, min_window=0)
    with pytest.raises(ValueError, match="steal_wait"):
        CoalescingPolicy(4, steal_wait=-1.0)


# ---------------------------------------------------------------------------
# promote_plan: validation + bit-exact coalesced flushes (tentpole contract).
# ---------------------------------------------------------------------------


def test_promote_plan_validates_and_is_identity_at_native_shape():
    plan = plan_graph(build_graph(6, path(6)))          # (8, 4)
    assert promote_plan(plan, 8, 4) is plan
    bigger = promote_plan(plan, 32, 8)
    assert bigger.bucket == (32, 8)
    assert bigger.n == plan.n and bigger.wreq == plan.wreq
    assert plan.bucket == (8, 4)                        # original untouched
    with pytest.raises(ValueError, match="promote"):
        promote_plan(plan, 4, 4)
    with pytest.raises(ValueError, match="promote"):
        promote_plan(bigger, 32, 4)
    with pytest.raises(ValueError, match="largest supported"):
        promote_plan(plan, 1 << 20, 4)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
def test_coalesced_flush_promotes_and_stays_bit_exact(executor, use_kernel):
    """Hot bucket goes overdue below capacity; the younger starving cold
    request is stolen into its deadline flush at a promoted (R, W) shape,
    and every result matches the per-graph engine bit-exactly."""
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=8, policy="coalesce", max_wait=0.1,
                             clock=clock, executor=executor,
                             use_kernel=use_kernel, num_samples=2)
    hot = [build_graph(n, path(n)) for n in (17, 20, 24)]   # bucket (32, 4)
    for i, g in enumerate(hot):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
        clock.advance(0.01)
    cold = build_graph(6, path(6))                          # bucket (8, 4)
    batcher.admit(ClusterRequest(uid=9, graph=cold,
                                 key=jax.random.PRNGKey(9)))
    # Hot oldest is now 0.03s old, cold 0.0s. Advance so the hot bucket is
    # overdue (0.11 ≥ max_wait) while cold (0.08) is past steal_wait (0.05)
    # but under its own deadline — the exact starvation-steal window.
    clock.advance(0.08)
    retired = batcher.poll()
    retired += batcher.flush()
    done = {r.uid: r for r in retired}
    assert sorted(done) == [0, 1, 2, 9]
    assert batcher.stats.flushes == 1       # one coalesced flush served all
    assert batcher.stats.coalesced_flushes == 1
    assert batcher.stats.stolen_requests == 1
    for uid, g in [(0, hot[0]), (1, hot[1]), (2, hot[2]), (9, cold)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result,
                        num_samples=2)
    # Promotion is transparent to the caller: the result still reports the
    # request's native bucket.
    assert done[9].result.info["bucket"] == (8, 4)


def test_coalescing_full_flush_steals_when_room_remains():
    """A full-bucket flush below max_batch capacity... cannot exist — but a
    repeating hot stream with spare room shows steady-state stealing: the
    cold request rides the first hot deadline flush, never the drain."""
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, policy="coalesce", max_wait=0.05,
                             clock=clock)
    cold = build_graph(5, path(5))
    hot = [build_graph(n, path(n)) for n in (17, 18, 19)]
    # Cold arrives first and would starve behind the hot stream under the
    # full-bucket policy (its bucket never fills).
    batcher.admit(ClusterRequest(uid=100, graph=cold,
                                 key=jax.random.PRNGKey(100)))
    clock.advance(0.04)     # cold nearly overdue
    for i, g in enumerate(hot):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    clock.advance(0.06)     # everyone overdue → cold's own deadline fires
    retired = batcher.poll()
    done = {r.uid: r for r in retired}
    # Cold is overdue itself, so it flushes regardless of stealing — the
    # guarantee that coalescing never *worsens* the deadline contract.
    assert 100 in done
    assert batcher.pending() == 0
    _assert_matches(cold, jax.random.PRNGKey(100), done[100].result)
    for i, g in enumerate(hot):
        _assert_matches(g, jax.random.PRNGKey(i), done[i].result)


# ---------------------------------------------------------------------------
# Telemetry plumbing: executor → ClusterStats → adaptive window.
# ---------------------------------------------------------------------------


def test_flush_latency_telemetry_reaches_stats():
    batcher = ClusterBatcher(max_batch=2)
    g = build_graph(6, path(6))
    for i in range(4):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    batcher.flush()
    tele = batcher.stats.latency
    assert tele.total_flushes == batcher.stats.flushes == 2
    assert tele.ewma_wall is not None and tele.ewma_wall >= 0.0
    assert tele.ewma_pack is not None and tele.ewma_pack >= 0.0
    summary = tele.summary()
    assert list(summary) == ["8x4"]
    rec = summary["8x4"]
    assert rec["flushes"] == 2
    for field in ("wall_p50_ms", "wall_p99_ms", "pack_p50_ms",
                  "pack_p99_ms", "wall_ewma_ms"):
        assert rec[field] >= 0.0
    assert batcher.stats.policy == "full"


def test_adaptive_policy_serves_and_windows_from_real_telemetry():
    batcher = ClusterBatcher(max_batch=2, policy="adaptive",
                             executor="async")
    assert batcher.stats.policy == "adaptive"
    reqs = [ClusterRequest(uid=i, graph=_rand_graph(6 + (i % 3), 1, seed=i),
                           key=jax.random.PRNGKey(i)) for i in range(8)]
    retired = serve_all(batcher, reqs)
    assert sorted(r.uid for r in retired) == list(range(8))
    for r in retired:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)
    # Telemetry accumulated, and the window is now latency-derived.
    assert batcher.stats.latency.total_flushes >= 1
    window = batcher.policy.admission_window(batcher.stats.latency)
    assert 1 <= window <= batcher.policy.max_window


class _ReleasingExecutor(AsyncExecutor):
    """Stalls harvests for a fixed number of retire() calls, then releases
    — deterministic backpressure that eventually clears."""

    def __init__(self, stall_retires=2):
        super().__init__()
        self.stall_retires = stall_retires

    def retire(self):
        if self.stall_retires > 0:
            self.stall_retires -= 1
            return []
        return super().retire()


def test_serve_all_retries_rejected_admissions():
    """The reference driver must survive AdmissionRejected (harvest +
    retry) so backpressure/adaptive policies can be driven by it."""
    ex = _ReleasingExecutor(stall_retires=8)
    batcher = ClusterBatcher(max_batch=1, executor=ex, max_in_flight=1)
    g = build_graph(6, path(6))
    reqs = [ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i))
            for i in range(4)]
    retired = serve_all(batcher, reqs)
    assert sorted(r.uid for r in retired) == list(range(4))
    assert batcher.stats.rejected >= 1      # backpressure actually fired
    for r in retired:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)


# ---------------------------------------------------------------------------
# Determinism: scheduling decisions only ever see the injected clock.
# ---------------------------------------------------------------------------


def test_no_wall_clock_on_any_scheduling_path(monkeypatch):
    """With a virtual clock injected, admit/poll/oldest_wait/flush must
    never fall back to time.monotonic — freeze it to a poisoned callable
    and drive a full deadline + coalescing cycle."""
    import sys
    import time as _time

    real_monotonic = _time.monotonic

    def _guarded():
        # JAX internals legitimately use time.monotonic; only calls from
        # this repo's serving layer are a clock-injection violation.
        caller = sys._getframe(1).f_globals.get("__name__", "")
        if caller.startswith("repro.serve"):
            raise AssertionError(
                "bare time.monotonic() on a scheduling path")
        return real_monotonic()

    monkeypatch.setattr(_time, "monotonic", _guarded)
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, max_wait=0.5, policy="coalesce",
                             clock=clock)
    g_small, g_big = build_graph(6, path(6)), build_graph(20, path(20))
    batcher.admit(ClusterRequest(uid=0, graph=g_small,
                                 key=jax.random.PRNGKey(0)))
    clock.advance(0.3)
    batcher.admit(ClusterRequest(uid=1, graph=g_big,
                                 key=jax.random.PRNGKey(1)))
    assert batcher.oldest_wait() == pytest.approx(0.3)
    clock.advance(0.3)
    retired = batcher.poll()        # uid0 overdue → deadline flush
    assert 0 in {r.uid for r in retired}
    retired += batcher.flush()
    assert sorted(r.uid for r in retired) == [0, 1]
    # Default clock resolves to the real monotonic clock when not injected.
    monkeypatch.undo()
    assert ClusterBatcher(max_batch=2).clock is _time.monotonic


# ---------------------------------------------------------------------------
# Randomized arrival traces: lease invariant + bit-exactness per policy
# (hypothesis-style satellite; runs under the conftest stub too).
# ---------------------------------------------------------------------------


class _LeaseAuditPool(BucketBufferPool):
    """Asserts the lease invariant: acquire never hands out staging arrays
    whose lease is still outstanding."""

    def __init__(self):
        super().__init__()
        self.outstanding = set()

    def acquire(self, b, r, w):
        lease = super().acquire(b, r, w)
        ident = id(lease.arrays["ell"])
        assert ident not in self.outstanding, \
            "BucketBufferPool refilled a staging buffer still in flight"
        self.outstanding.add(ident)
        return lease

    def _release(self, lease):
        self.outstanding.discard(id(lease.arrays["ell"]))
        super()._release(lease)


@settings(max_examples=12, deadline=None)
@given(policy=st.sampled_from(["full", "deadline", "adaptive", "coalesce"]),
       seed=st.integers(min_value=0, max_value=10_000),
       gap_ms=st.floats(min_value=0.0, max_value=30.0),
       wait_ms=st.floats(min_value=1.0, max_value=60.0))
def test_random_traces_bit_exact_and_lease_safe(policy, seed, gap_ms,
                                                wait_ms):
    """Drive each policy over a random (n, arrival-gap, deadline) stream on
    a virtual clock: every result must match the per-graph engine and the
    pool must never refill an in-flight lease."""
    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    pool = _LeaseAuditPool()
    max_wait = wait_ms / 1e3 if policy != "full" else None
    batcher = ClusterBatcher(max_batch=4, policy=policy, max_wait=max_wait,
                             clock=clock, pool=pool, executor="async")
    n_reqs = int(rng.integers(6, 12))
    reqs = []
    retired = []
    for uid in range(n_reqs):
        clock.advance(gap_ms / 1e3 * float(rng.random()))
        n = int(rng.integers(5, 15))
        req = ClusterRequest(uid=uid,
                             graph=_rand_graph(n, 1, seed * 31 + uid),
                             key=jax.random.PRNGKey(uid))
        reqs.append(req)
        while True:
            try:
                retired += batcher.admit(req)
                break
            except AdmissionRejected:       # adaptive window can reject
                retired += batcher.retire()
        retired += batcher.poll()
    retired += batcher.flush()
    assert sorted(r.uid for r in retired) == list(range(n_reqs))
    assert pool.leased == 0 and not pool.outstanding
    for r in reqs:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)
