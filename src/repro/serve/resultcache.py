"""Content-addressed result cache for the clustering serving layer.

The north-star workload is millions of *small* clustering queries, and
repeat traffic is the norm there: the same dedup shard or query
neighbourhood arrives over and over with the same PRNG key. PIVOT is
deterministic per key — for a fixed ``(graph, key, method, num_samples,
eps)`` the engine returns bit-identical ``(labels, cost, picked)`` every
time — so a repeat request need not touch the device at all.

:class:`ResultCache` is a bounded in-memory LRU keyed by
:class:`repro.core.plan.GraphFingerprint` (the canonical content hash of
the planned request — ELL rows, eligibility, exact key bytes, method/k/ε).
The store/stats split mirrors the compiled-program LRU in
:mod:`repro.core.executor`: the cache owns an ``OrderedDict`` with a hard
capacity + byte bound and eviction accounting, while a live
:class:`ResultCacheStats` object is shared outward (``ClusterStats``
surfaces it) so counters are readable without poking cache internals.

Two invariants matter:

* **Only post-selection winners are stored.** The cached value is the
  argmin-of-k labels/cost/picked the engine would return from a cold
  flush, keyed on the *exact* PRNG key — never intermediate per-sample
  outputs, never results for a "close enough" key. That is what keeps a
  cache hit bit-exact with the cold path.
* **Hits are collision-checked.** The fingerprint's canonical payload is
  retained per entry and compared on every digest match; a mismatch is a
  counted collision treated as a miss, so a hash collision can never
  serve another graph's labels.

A cache instance may be shared between engines (e.g. a long-lived dedup
pipeline reusing one cache across corpora): entries are immutable after
insertion and ``get`` hands out arrays the caller's result path copies
(``result_for_plan`` re-slices with ``astype``), so sharing is safe in
the repo's single-threaded serving discipline.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.core.plan import GraphFingerprint

# Flat per-entry bookkeeping charge (dict slot, dataclass, ints) so the
# byte bound cannot be gamed by many tiny entries.
_ENTRY_OVERHEAD = 256


@dataclasses.dataclass
class ResultCacheStats:
    """Live counters for one :class:`ResultCache` (shared outward through
    ``ClusterStats.result_cache``; a cache shared between engines shares
    one stats object, so these are cache-lifetime, not engine-lifetime).
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    collisions: int = 0     # digest matched, canonical payload did not
    insertions: int = 0
    entries: int = 0        # gauge: resident entries
    bytes: int = 0          # gauge: resident labels + retained payloads

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class _Entry:
    payload: bytes          # canonical bytes, compared on every hit
    labels: np.ndarray      # (n,) int32 post-selection winner
    cost: int
    picked: int
    rounds: int
    nbytes: int


class ResultCache:
    """Bounded LRU of post-selection clustering results, content-addressed.

    ``capacity`` bounds resident entries and ``max_bytes`` bounds resident
    memory (labels + retained fingerprint payloads + flat overhead);
    exceeding either evicts least-recently-used entries. An entry larger
    than ``max_bytes`` on its own is admitted and immediately evicted —
    too big to cache, counted like any other eviction.
    """

    def __init__(self, capacity: int = 4096, max_bytes: int = 64 << 20):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self.stats = ResultCacheStats()
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, fp: GraphFingerprint
            ) -> Optional[Tuple[np.ndarray, int, int, int]]:
        """Look up ``(labels, cost, picked, rounds)``; None on miss.

        A digest match with a different canonical payload is a detected
        hash collision: counted, treated as a miss, and the resident
        entry keeps its slot (first writer wins).
        """
        entry = self._entries.get(fp.digest)
        if entry is None:
            self.stats.misses += 1
            return None
        if entry.payload != fp.payload:
            self.stats.collisions += 1
            self.stats.misses += 1
            return None
        self._entries.move_to_end(fp.digest)
        self.stats.hits += 1
        return entry.labels, entry.cost, entry.picked, entry.rounds

    def put(self, fp: GraphFingerprint, labels: np.ndarray, cost: int,
            picked: int, rounds: int) -> None:
        """Insert one post-selection winner (idempotent per fingerprint —
        re-inserting refreshes recency and keeps the resident entry)."""
        resident = self._entries.get(fp.digest)
        if resident is not None:
            # Same fingerprint ⇒ same result by the bit-exactness
            # contract; refresh recency, don't churn bytes.
            self._entries.move_to_end(fp.digest)
            return
        owned = np.array(labels, dtype=np.int32, copy=True)
        nbytes = owned.nbytes + len(fp.payload) + _ENTRY_OVERHEAD
        self._entries[fp.digest] = _Entry(
            payload=fp.payload, labels=owned, cost=int(cost),
            picked=int(picked), rounds=int(rounds), nbytes=nbytes)
        self.stats.insertions += 1
        self.stats.bytes += nbytes
        while self._entries and (len(self._entries) > self.capacity
                                 or self.stats.bytes > self.max_bytes):
            _, evicted = self._entries.popitem(last=False)
            self.stats.bytes -= evicted.nbytes
            self.stats.evictions += 1
        self.stats.entries = len(self._entries)

    def clear(self) -> None:
        """Drop every entry (counted as evictions)."""
        self.stats.evictions += len(self._entries)
        self._entries.clear()
        self.stats.entries = 0
        self.stats.bytes = 0

    def info(self) -> dict:
        """JSON-ready counters for benchmarks and serving stats."""
        return {
            "capacity": self.capacity,
            "max_bytes": self.max_bytes,
            "entries": len(self._entries),
            "bytes": self.stats.bytes,
            "hits": self.stats.hits,
            "misses": self.stats.misses,
            "hit_rate": self.stats.hit_rate,
            "evictions": self.stats.evictions,
            "collisions": self.stats.collisions,
            "insertions": self.stats.insertions,
        }


def make_result_cache(spec) -> Optional[ResultCache]:
    """Resolve a ``ClusterBatcher(result_cache=...)`` spec.

    ``True`` → a fresh default-sized cache; ``False``/``None`` → caching
    disabled; an ``int`` → a fresh cache with that entry capacity; a
    :class:`ResultCache` instance → shared as-is (cross-engine reuse).
    """
    if spec is True:
        return ResultCache()
    if spec is False or spec is None:
        return None
    if isinstance(spec, int):
        return ResultCache(capacity=spec)
    if isinstance(spec, ResultCache):
        return spec
    raise ValueError(
        f"result_cache must be a bool, int capacity, or ResultCache "
        f"instance, got {spec!r}")


__all__ = ["ResultCache", "ResultCacheStats", "make_result_cache"]
