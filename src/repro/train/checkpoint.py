"""Checkpointing: atomic step snapshots, restart, elastic resharding.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json     — step, data cursor, tree structure, shapes,
                            dtypes, content hashes, mesh shape at save time
        arrays.npz        — flat leaf arrays keyed by tree path

Design points for the 1000-node story (see train/fault.py):
* **Atomicity** — written to ``step_X.tmp`` then renamed; a crash mid-write
  never corrupts the latest valid checkpoint.
* **Integrity** — per-leaf SHA1 content hashes verified on load.
* **Elastic resharding** — arrays are saved *unsharded* (gathered); on
  restore, ``jax.device_put`` with the *new* mesh's NamedShardings lays
  them out for whatever topology the job restarted with (16×16 → 8×16
  scale-down is a test). At real scale this becomes a sharded array-store
  (tensorstore); the manifest/restore protocol is identical.
* **Data cursor** — the pipeline is a pure function of step (data/pipeline),
  so the manifest's ``step`` alone resumes the exact token order.
"""

from __future__ import annotations

import hashlib
import json
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(k) for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(directory: str | Path, step: int, state: Any,
                    extra: Optional[dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    keys, vals, _ = _flatten_with_paths(state)
    arrays = {}
    hashes = {}
    meta = {}
    for k, v in zip(keys, vals):
        arr = np.asarray(v)
        arrays[k] = arr
        hashes[k] = hashlib.sha1(arr.tobytes()).hexdigest()
        meta[k] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "leaves": meta,
        "hashes": hashes,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | Path, template: Any,
                       step: Optional[int] = None,
                       shardings: Any = None,
                       verify: bool = True) -> Tuple[Any, dict]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedShardings (same structure) for
    elastic re-layout onto the *current* mesh.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")

    keys, vals, treedef = _flatten_with_paths(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(keys))
    out = []
    for k, tmpl, shd in zip(keys, vals, shard_leaves):
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = data[k]
        if verify and manifest["hashes"].get(k) != hashlib.sha1(
                arr.tobytes()).hexdigest():
            raise IOError(f"checkpoint corruption detected at leaf {k}")
        if list(arr.shape) != list(np.shape(tmpl)):
            raise ValueError(
                f"leaf {k}: checkpoint shape {arr.shape} != template "
                f"{np.shape(tmpl)}")
        arr = arr.astype(np.asarray(tmpl).dtype if hasattr(tmpl, "dtype")
                         else arr.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest


def prune_checkpoints(directory: str | Path, keep: int = 3):
    directory = Path(directory)
    steps = sorted(p for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p)


__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "prune_checkpoints"]
