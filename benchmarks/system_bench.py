"""System-level benchmarks: kernels, dedup pipeline, distributed engine."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, float]


def _timeit(fn, reps=3, warmup=1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def bench_neighbor_min_kernel() -> List[Row]:
    """Pallas neighbor-min (interpret) vs XLA segment-min oracle.

    On CPU the interpret-mode kernel is NOT the perf target (TPU is); the
    derived column reports agreement (0.0 = bit-identical), the us column
    the oracle's wall time (the production CPU path).
    """
    from repro.core import build_graph, random_permutation_ranks
    from repro.core.graph import random_arboric
    from repro.core.mis import neighbor_min_ranks
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    for n in (1000, 10000):
        edges, _ = random_arboric(n, 4, rng)
        g = build_graph(n, edges)
        ranks = random_permutation_ranks(n, jax.random.PRNGKey(0))
        active = jnp.ones((n,), bool)
        us = _timeit(lambda: neighbor_min_ranks(g, ranks, active))
        kern = ops.neighbor_min(g, ranks, active)
        oracle = neighbor_min_ranks(g, ranks, active)
        diff = float(jnp.sum(jnp.abs(kern - oracle)))
        rows.append((f"neighbor_min_oracle_n{n}", us, diff))
    return rows


def bench_attention_impls() -> List[Row]:
    """Chunked-XLA flash vs naive attention (CPU wall time, small shape)."""
    from repro.models.attention import _chunked_attention, _naive_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, kh, g, hd = 1, 1024, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, kh, g, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    f_naive = jax.jit(lambda a, b, c: _naive_attention(a, b, c, True))
    f_chunk = jax.jit(lambda a, b, c: _chunked_attention(
        a, b, c, True, q_chunk=256, kv_chunk=256))
    us_n = _timeit(lambda: f_naive(q, k, v))
    us_c = _timeit(lambda: f_chunk(q, k, v))
    err = float(jnp.max(jnp.abs(f_naive(q, k, v) - f_chunk(q, k, v))))
    return [("attention_naive_1k", us_n, err),
            ("attention_chunked_1k", us_c, us_n / max(us_c, 1e-9))]


def bench_dedup_pipeline() -> List[Row]:
    """End-to-end dedup: MinHash → similarity graph → Alg 4 clustering."""
    from repro.data.dedup import dedup_corpus, dedup_quality
    from repro.data.synthetic import synthetic_corpus

    corpus = synthetic_corpus(n_docs=150, dup_fraction=0.4, mutate_p=0.05,
                              seed=0)
    t0 = time.perf_counter()
    res = dedup_corpus(corpus, threshold=0.45)
    us = (time.perf_counter() - t0) * 1e6
    q = dedup_quality(res, corpus)
    return [
        ("dedup_pairs_recall", us, q["pairs_recall"]),
        ("dedup_pairs_precision", us, q["pairs_precision"]),
        ("dedup_kept_fraction", us, q["kept_fraction"]),
    ]


def bench_distributed_engine() -> List[Row]:
    """Edge-sharded PIVOT: rounds + wall time on the available devices."""
    from repro.core import (build_graph, distributed_pivot,
                            random_permutation_ranks)
    from repro.core.graph import random_arboric

    rng = np.random.default_rng(1)
    edges, _ = random_arboric(5000, 4, rng)
    g = build_graph(5000, edges)
    ranks = random_permutation_ranks(5000, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    labels, in_mis, rounds = distributed_pivot(g, ranks)
    us = (time.perf_counter() - t0) * 1e6
    return [("distributed_pivot_rounds_n5000", us, float(rounds))]


def bench_train_step_smoke() -> List[Row]:
    """One optimizer step wall time on the reduced qwen3 config (CPU)."""
    from repro.configs import get_smoke
    from repro.models import RunConfig, build_model
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import StepConfig, init_train_state, make_train_step

    cfg = get_smoke("qwen3-8b")
    m = build_model(cfg, rc=RunConfig(attn_impl="naive", loss_chunk=16),
                    param_dtype=jnp.float32)
    oc = OptConfig()
    state = init_train_state(m, jax.random.PRNGKey(0), oc, StepConfig())
    step = jax.jit(make_train_step(m, oc, StepConfig()))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    state, metrics = step(state, batch)  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        state, metrics = step(state, batch)
    jax.block_until_ready(metrics["loss"])
    us = (time.perf_counter() - t0) / 3 * 1e6
    return [("train_step_smoke_qwen3", us, float(metrics["loss"]))]


ALL = [
    bench_neighbor_min_kernel,
    bench_attention_impls,
    bench_dedup_pipeline,
    bench_distributed_engine,
    bench_train_step_smoke,
]
