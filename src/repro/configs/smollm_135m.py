"""smollm-135m [dense]: 30L, d=576, 9H (GQA kv=3), ff=1536, vocab=49152.
Llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
        d_ff=1536, vocab_size=49152, head_dim=64, tie_embeddings=True,
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke", family="dense",
        num_layers=2, d_model=48, num_heads=3, num_kv_heads=1,
        d_ff=96, vocab_size=512, head_dim=16, tie_embeddings=True,
        vocab_round=64,
    )
