"""Warmup-time kernel autotuner: cache persistence/invalidation, sweep
mechanics, program-key plumbing, bit-exactness for every tuned shape, and
the learned compile/service costs it feeds the serving cost model."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_graph, correlation_cluster,
                        correlation_cluster_batch)
from repro.core import executor as exec_mod
from repro.core.graph import random_arboric
from repro.core.plan import plan_graph
from repro.kernels import autotune as at
from repro.serve.cluster_batcher import ClusterBatcher, ClusterRequest
from repro.serve.costmodel import FlushCostModel
from repro.serve.engine import serve_all
from repro.serve.scheduler import FlushTelemetry


@pytest.fixture(autouse=True)
def fresh_cache(monkeypatch):
    """Every test runs against its own in-memory tuning cache: tuned
    winners are process-global state that would otherwise leak program-key
    resolution between tests."""
    monkeypatch.delenv("REPRO_TUNING_CACHE", raising=False)
    prev = at.set_tuning_cache(at.TuningCache(path=None))
    yield
    at.set_tuning_cache(prev)


def _graphs(n_graphs=4, lo=8, hi=30, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(lo, hi))
        edges, _ = random_arboric(n, 2, rng)
        out.append(build_graph(n, edges))
    return out


def _seed_all_buckets(graphs, block_rows, k=1):
    """Force ``block_rows`` as the cached winner for every bucket/tier a
    run of ``graphs`` can hit — the hook the bit-exactness sweep uses to
    route each candidate through the real resolution path."""
    cache = at.tuning_cache()
    buckets = {plan_graph(g).bucket for g in graphs}
    for (r, w) in buckets:
        tier = 1
        while tier <= at.MAX_BATCH_TIER:
            for kern in at.KERNELS:
                cache.put(kern, r, w, tier, min(block_rows, r))
            tier *= 2
    return buckets


# --- cache mechanics -------------------------------------------------------


def test_batch_tier_and_candidates():
    assert at.batch_tier(1) == 1
    assert at.batch_tier(5) == 8
    assert at.batch_tier(64) == 64
    assert at.batch_tier(10 ** 9) == at.MAX_BATCH_TIER
    # Clamped to R, deduplicated, default always present.
    assert at.candidate_blocks(512) == (64, 128, 256, 512)
    assert at.candidate_blocks(128) == (64, 128)
    assert at.candidate_blocks(32) == (32,)
    assert at.candidate_blocks(100, candidates=(48, 512)) == (48, 100)


def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tuning.json")
    cache = at.TuningCache(path=path)
    cache.put("neighbor_min", 64, 8, 4, 32,
              meta={"speedup_vs_default": 1.5})
    cache.save()
    loaded = at.TuningCache(path=path)
    assert loaded.get("neighbor_min", 64, 8, 4) == 32
    assert loaded.hits == 1
    assert loaded.get("neighbor_min", 64, 8, 8) is None   # other tier
    assert loaded.misses == 1
    blob = json.loads(open(path).read())
    assert blob["version"] == 1
    (key, entry), = blob["entries"].items()
    assert key == f"{jax.default_backend()}/neighbor_min/64x8/b4"
    assert entry["jax_version"] == jax.__version__


def test_cache_stale_entries_ignored(tmp_path):
    """The invalidation rule: entries from another backend or jax version
    are counted stale and treated as misses — ignored, never trusted."""
    path = str(tmp_path / "tuning.json")
    backend = jax.default_backend()
    blob = {"version": 1, "entries": {
        f"{backend}/neighbor_min/64x8/b4": {
            "block_rows": 32, "backend": backend,
            "jax_version": "0.0.0-stale"},
        f"tpu-v9/label_agree/64x8/b4": {
            "block_rows": 64, "backend": "tpu-v9",
            "jax_version": jax.__version__},
    }}
    with open(path, "w") as f:
        json.dump(blob, f)
    cache = at.TuningCache(path=path)
    assert cache.get("neighbor_min", 64, 8, 4) is None
    assert cache.stale == 1 and cache.misses == 1
    # The wrong-backend entry is simply not found under this backend's key.
    assert cache.get("label_agree", 64, 8, 4) is None
    assert cache.misses == 2


def test_cache_corrupt_file_ignored(tmp_path):
    path = str(tmp_path / "tuning.json")
    with open(path, "w") as f:
        f.write("{not json")
    cache = at.TuningCache(path=path)        # must not raise
    assert cache.get("neighbor_min", 8, 4, 1) is None


def test_cache_env_var_path(tmp_path, monkeypatch):
    path = str(tmp_path / "env-tuning.json")
    cache = at.TuningCache(path=path)
    cache.put("label_agree", 32, 4, 2, 16)
    cache.save()
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)
    env_cache = at.TuningCache()
    assert env_cache.path == path
    assert env_cache.get("label_agree", 32, 4, 2) == 16


def test_resolve_block_rows_untuned_is_none():
    assert at.resolve_block_rows((8, 64, 8)) is None
    at.tuning_cache().put("neighbor_min", 64, 8, 8, 128)
    # Partial tuning: the untuned kernel falls back to the clamped default.
    assert at.resolve_block_rows((8, 64, 8)) == (128, 64)


# --- sweep mechanics -------------------------------------------------------


def _packed_bucket(graphs, g_pad=None, k=1):
    from repro.core.api import sample_keys
    from repro.core.plan import pack_bucket

    plans = [plan_graph(g) for g in graphs]
    keys = [sample_keys(jax.random.PRNGKey(i), k)
            for i in range(len(plans))]
    return pack_bucket(plans, keys, k=k, g_pad=g_pad)


def test_sweep_records_winner_and_cache():
    graphs = _graphs(2, lo=20, hi=21, seed=3)     # one bucket
    ell, ranks, elig, _m, _pad = _packed_bucket(graphs, g_pad=2)
    cache = at.tuning_cache()
    records = at.sweep_bucket(ell, ranks, elig, candidates=(8, 16),
                              repeats=1)
    assert {r["kernel"] for r in records} == set(at.KERNELS)
    b, r, w = (int(s) for s in ell.shape)
    tier = at.batch_tier(b)
    for rec in records:
        assert rec["winner"] in rec["candidates"]
        assert rec["winner_ms"] <= rec["default_ms"] + 1e-9
        assert rec["speedup_vs_default"] >= 1.0 - 1e-9
        assert cache.get(rec["kernel"], r, w, tier) == rec["winner"]
    assert cache.sweeps == 2
    assert len(cache.sweep_log) == 2
    info = at.tuning_info()
    assert info["sweeps"] == 2 and len(info["sweep_log"]) == 2


def test_warmup_autotune_caches_and_reuses(tmp_path):
    """The CI autotune smoke: a 2-candidate sweep on one small bucket must
    cache a winner, and a second warmup against the populated cache file
    must perform zero sweep timings (hit counters prove it)."""
    path = str(tmp_path / "tuning.json")
    graphs = _graphs(3, lo=10, hi=24, seed=1)
    at.set_tuning_cache(at.TuningCache(path=path))
    eng = ClusterBatcher(max_batch=2, use_kernel=True)
    eng.warmup(graphs, autotune=True, candidates=(16, 32), repeats=1)
    first = at.tuning_cache()
    assert first.sweeps > 0
    assert os.path.exists(path)
    assert eng.stats.tuning is not None
    assert eng.stats.tuning["sweeps"] == first.sweeps
    assert len(eng.stats.tuning["sweep_log"]) == first.sweeps

    # "Second process": a fresh cache object loaded from the same file.
    at.set_tuning_cache(at.TuningCache(path=path))
    second = at.tuning_cache()
    eng2 = ClusterBatcher(max_batch=2, use_kernel=True)
    eng2.warmup(graphs, autotune=True, candidates=(16, 32), repeats=1)
    assert second.sweeps == 0, "populated cache must skip all sweeps"
    assert second.hits > 0, "reuse must be visible in the hit counters"
    assert second.stale == 0


def test_program_key_carries_block_shape():
    """Distinct block pairs are distinct compiled programs (re-tuning can
    never mutate a compiled one), with identical outputs; the jnp path
    ignores block shape entirely."""
    ell = jnp.full((2, 16, 4), 16, jnp.int32)
    ranks = jnp.full((2, 17), np.iinfo(np.int32).max, jnp.int32)
    elig = jnp.zeros((2, 17), bool)
    m = jnp.zeros((2,), jnp.int32)
    args = (ell, ranks, elig, m)
    before = exec_mod.program_cache_size()
    outs = [exec_mod.run_bucket_program(*args, k=2, use_kernel=True,
                                        block_rows=br)
            for br in [(8, 8), (16, 16), None]]
    assert exec_mod.program_cache_size() - before == 3
    for got in outs[1:]:
        for a, b in zip(outs[0], got):
            assert (np.asarray(a) == np.asarray(b)).all()
    # The probe resolves block shape identically to the run.
    assert exec_mod.program_cache_contains((2, 16, 4), 2, use_kernel=True,
                                           block_rows=(8, 8))
    assert not exec_mod.program_cache_contains((2, 16, 4), 2,
                                               use_kernel=True,
                                               block_rows=(4, 4))
    # use_kernel=False: block shape is normalized out of the key.
    before = exec_mod.program_cache_size()
    exec_mod.run_bucket_program(*args, k=2, block_rows=(8, 8))
    exec_mod.run_bucket_program(*args, k=2)
    assert exec_mod.program_cache_size() - before <= 1


def test_tuned_cache_winner_drives_run_and_probe():
    """An untuned run and a tuned run of the same bucket are different
    programs, and the cost model's probe tracks the tuning cache."""
    ell = jnp.full((2, 24, 4), 24, jnp.int32)
    ranks = jnp.full((2, 25), np.iinfo(np.int32).max, jnp.int32)
    elig = jnp.zeros((2, 25), bool)
    m = jnp.zeros((2,), jnp.int32)
    args = (ell, ranks, elig, m)
    exec_mod.run_bucket_program(*args, k=1, use_kernel=True)
    assert exec_mod.program_cache_contains((2, 24, 4), 1, use_kernel=True)
    for kern in at.KERNELS:
        at.tuning_cache().put(kern, 24, 4, at.batch_tier(2), 8)
    # The tuned program is not resident yet; default resolution now points
    # at the tuned key.
    assert not exec_mod.program_cache_contains((2, 24, 4), 1,
                                               use_kernel=True)
    before = exec_mod.program_cache_size()
    exec_mod.run_bucket_program(*args, k=1, use_kernel=True)
    assert exec_mod.program_cache_size() - before == 1
    assert exec_mod.program_cache_contains((2, 24, 4), 1, use_kernel=True)


# --- bit-exactness: every candidate and the cached winner ------------------


@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
@pytest.mark.parametrize("block_rows", [32, 64, 256])
def test_bit_exact_for_every_tuned_candidate(executor, block_rows):
    """The acceptance contract: for every swept candidate, batch results
    on the kernel path under tuned block shapes are bit-identical to the
    per-graph engine, across all three executors."""
    graphs = _graphs(5, lo=8, hi=40, seed=7)
    keys = [jax.random.PRNGKey(i) for i in range(len(graphs))]
    _seed_all_buckets(graphs, block_rows)
    results = correlation_cluster_batch(graphs, keys=keys, use_kernel=True,
                                        executor=executor)
    for g, key, got in zip(graphs, keys, results):
        ref = correlation_cluster(g, key=key)
        assert (got.labels == ref.labels).all()
        assert got.cost == ref.cost


def test_bit_exact_jnp_path_with_tuned_cache():
    """Tuned winners must not perturb the jnp (use_kernel=False) path."""
    graphs = _graphs(4, seed=9)
    keys = [jax.random.PRNGKey(i) for i in range(len(graphs))]
    _seed_all_buckets(graphs, 32)
    results = correlation_cluster_batch(graphs, keys=keys, use_kernel=False)
    for g, key, got in zip(graphs, keys, results):
        ref = correlation_cluster(g, key=key)
        assert (got.labels == ref.labels).all() and got.cost == ref.cost


def test_bit_exact_served_after_autotune_warmup():
    """Cached-winner path end to end: warmup(autotune=True) then serve on
    the kernel path — results match the per-graph engine."""
    graphs = _graphs(4, seed=11)
    eng = ClusterBatcher(max_batch=4, use_kernel=True)
    eng.warmup(graphs, autotune=True, candidates=(16, 64), repeats=1)
    reqs = [ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i))
            for i, g in enumerate(graphs)]
    done = {r.uid: r for r in serve_all(eng, reqs)}
    for i, g in enumerate(graphs):
        ref = correlation_cluster(g, key=jax.random.PRNGKey(i))
        assert (done[i].result.labels == ref.labels).all()
        assert done[i].result.cost == ref.cost


# --- learned compile walls + cost-model integration ------------------------


def test_compile_wall_stamped_and_surfaced():
    """A program-cache miss stamps its compile wall on the handle and into
    program_cache_info; hits stamp None."""
    ex = exec_mod.SyncExecutor()
    ell = np.full((3, 48, 4), 48, dtype=np.int32)
    ranks = np.full((3, 49), np.iinfo(np.int32).max, dtype=np.int32)
    elig = np.zeros((3, 49), dtype=bool)
    m = np.zeros((3,), dtype=np.int32)
    h1 = ex.submit(ell, ranks, elig, m, k=3)
    assert h1.compile_seconds is not None and h1.compile_seconds > 0
    h2 = ex.submit(ell, ranks, elig, m, k=3)
    assert h2.compile_seconds is None
    info = exec_mod.program_cache_info()
    assert "48x4" in info["compile_wall_ewma_ms"]
    assert info["compile_wall_ewma_ms"]["48x4"] > 0


def test_batcher_feeds_compile_walls_into_telemetry():
    """Harvest threads the executor's compile stamps into FlushTelemetry:
    per-shape compile stream + summary fields."""
    g = _graphs(1, lo=12, hi=13, seed=21)[0]
    eng = ClusterBatcher(max_batch=1, num_samples=3)
    done = eng.admit(ClusterRequest(uid=0, graph=g,
                                    key=jax.random.PRNGKey(0)))
    done += eng.flush()
    assert done and done[0].result is not None
    tele = eng.stats.latency
    bucket = plan_graph(g).queue_key     # telemetry keys are (method, R, W)
    assert tele.bucket_ewma_compile(bucket) is not None
    assert tele.ewma_compile is not None
    rec = tele.summary()[f"{bucket[0]}:{bucket[1]}x{bucket[2]}"]
    assert rec["compiles_total"] >= 1
    assert rec["compile_wall_ewma_ms"] > 0
    # Compile-free wall is maintained and below the raw (compile-heavy)
    # first wall.
    assert tele.bucket_ewma_wall_xc(bucket) is not None
    assert tele.bucket_ewma_wall_xc(bucket) <= tele.bucket_ewma_wall(bucket)


def test_cost_model_learned_compile_charge():
    """compile_charge prefers the observed per-shape compile EWMA, then
    the global compile EWMA, then the static prior — and still returns 0
    for resident programs."""
    bucket = (16384, 2048)          # never compiled anywhere in the suite
    model = FlushCostModel(compile_cost_s=0.1)
    model.bind_engine(num_samples=1)
    tele = FlushTelemetry(alpha=1.0)
    assert model.compile_charge(bucket, 4, tele) == pytest.approx(0.1)
    tele.record_compile((8, 4), 0.7)        # other shape: global fallback
    assert model.compile_charge(bucket, 4, tele) == pytest.approx(0.7)
    tele.record_compile(bucket, 0.4)        # this shape: learned
    assert model.compile_charge(bucket, 4, tele) == pytest.approx(0.4)
    assert model.compile_charge(bucket, 4, None) == pytest.approx(0.1)


def test_price_steal_uses_learned_compile_and_own_flush_credit():
    bucket = (16384, 2048)
    src = (8, 4)
    model = FlushCostModel(compile_cost_s=0.1)
    model.bind_engine(num_samples=1)
    tele = FlushTelemetry(alpha=1.0)
    tele.record(bucket, wall_s=0.08)
    # Steal 8→16 groups inflates the batch: learned compile charged.
    tele.record_compile(bucket, 0.4)
    cost = model.price_steal(bucket, 8, [(src, 0.01)], 0.1, tele)
    assert cost.compile_cost_s == pytest.approx(0.4)
    # Cold source: no own-flush credit (never the floor/global fallback).
    assert cost.own_flush_credit_s == 0.0
    assert cost.benefit_s == pytest.approx(0.1 - 0.01)
    # Observed source flush: its compile-free wall is credited once per
    # distinct source bucket.
    tele.record(src, wall_s=0.05)
    cost = model.price_steal(bucket, 8, [(src, 0.01), (src, 0.02)], 0.1,
                             tele)
    assert cost.own_flush_credit_s == pytest.approx(0.05)
    assert cost.benefit_s == pytest.approx((0.1 - 0.01) + (0.1 - 0.02)
                                           + 0.05)
    # The credit excludes compile walls: a compile-inflated flush of the
    # source must not inflate the credit.
    tele2 = FlushTelemetry(alpha=1.0)
    tele2.record(bucket, wall_s=0.08)
    tele2.record(src, wall_s=0.5, compile_s=0.48)
    cost2 = model.price_steal(bucket, 8, [(src, 0.01)], 0.1, tele2)
    assert cost2.own_flush_credit_s == pytest.approx(0.02)
