"""Model assembly for all ten architectures: init / forward / loss /
prefill / decode.

Layer stacks are *scanned* (params stacked with a leading layer dim) so the
lowered HLO is one block body + a loop — essential for compile time at 100
layers on the dry-run host. Heterogeneous patterns are expressed as grouped
scans:

  dense/moe     — scan over L uniform blocks
  zamba2        — scan over (L/k) groups: inner scan over k Mamba2 layers,
                  then the *shared* attention block (weights broadcast,
                  per-application KV cache)
  vlm           — scan over groups of (cross_attn_every−1 self layers +
                  1 gated cross-attn layer)
  whisper       — encoder scan (bidir) + decoder scan (self + cross + mlp)
  rwkv6         — scan over (time-mix + channel-mix) blocks

Caches are pytrees stacked the same way as the stacks that consume them.
The loss avoids materializing (B, S, V) logits by scanning vocab projection
+ softmax-xent over sequence chunks (padded vocab columns are masked).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .attention import attention, decode_attention, init_attention
from .common import (
    KeyGen,
    Pm,
    constrain,
    dense_init,
    is_pm,
    rms_norm,
    split_params,
)
from .mlp import init_mlp, init_moe, mlp, moe
from .sharding import ShardingPlan


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Per-run execution knobs (static)."""
    attn_impl: str = "chunked"       # chunked | pallas | naive
    moe_impl: str = "sort"           # sort | einsum
    moe_capacity: float = 1.25       # capacity factor (tokens may drop)
    moe_token_chunk: int = 8192      # dispatch chunk (bounds (T·k,d) buffers)
    remat: bool = False
    loss_chunk: int = 512
    rwkv_impl: str = "chunked"
    ssd_chunk: int = 64
    mesh: object = None              # required by moe_impl='ep_local'



# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_init(init_one, n: int, kg: KeyGen):
    """Init n layers and stack each leaf along a new leading axis, prepending
    None to its PartitionSpec."""
    trees = [init_one(KeyGen(kg())) for _ in range(n)]

    def merge(*leaves):
        specs = leaves[0].spec
        arr = jnp.stack([l.value for l in leaves])
        from jax.sharding import PartitionSpec as P
        return Pm(arr, P(None, *tuple(specs)))

    return jax.tree.map(merge, *trees, is_leaf=is_pm)


def _norm_init(cfg, plan, dtype):
    return Pm(jnp.ones((cfg.d_model,), dtype), plan.P(None))


def _dense_block_init(cfg: ModelConfig, plan, dtype):
    def one(kg):
        p = {
            "ln1": _norm_init(cfg, plan, dtype),
            "attn": init_attention(cfg, kg, dtype, plan),
            "ln2": _norm_init(cfg, plan, dtype),
        }
        if cfg.num_experts:
            p["moe"] = init_moe(cfg, kg, dtype, plan)
        else:
            p["mlp"] = init_mlp(cfg, kg, dtype, plan)
        return p
    return one


def init_model(cfg: ModelConfig, key: jax.Array,
               plan: Optional[ShardingPlan] = None,
               dtype=jnp.float32):
    """Returns a Pm tree (array + spec per leaf)."""
    plan = plan or ShardingPlan.null()
    kg = KeyGen(key)
    v, d = cfg.padded_vocab, cfg.d_model
    params: Dict[str, Any] = {
        "embed": Pm(dense_init(kg(), (v, d), dtype, in_axis_size=d),
                    plan.P("vocab", "embed")),
        "ln_f": _norm_init(cfg, plan, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Pm(dense_init(kg(), (d, v), dtype),
                               plan.P("embed", "vocab"))

    if cfg.family in ("dense", "moe"):
        params["blocks"] = _stack_init(
            _dense_block_init(cfg, plan, dtype), cfg.num_layers, kg)

    elif cfg.family == "vlm":
        k = cfg.cross_attn_every
        n_groups = cfg.num_layers // k
        n_self = k - 1

        def self_group(kg2):
            return _stack_init(_dense_block_init(cfg, plan, dtype), n_self, kg2)

        def cross_layer(kg2):
            return {
                "ln1": _norm_init(cfg, plan, dtype),
                "xattn": init_attention(cfg, kg2, dtype, plan, cross=True),
                "ln2": _norm_init(cfg, plan, dtype),
                "mlp": init_mlp(cfg, kg2, dtype, plan),
            }

        params["self_groups"] = _stack_init(self_group, n_groups, kg)
        params["cross_layers"] = _stack_init(cross_layer, n_groups, kg)

    elif cfg.family == "hybrid":
        k = cfg.attn_every
        n_groups = cfg.num_layers // k

        def mamba_group(kg2):
            def one(kg3):
                return {
                    "ln": _norm_init(cfg, plan, dtype),
                    "mamba": ssm_mod.init_mamba(cfg, kg3, dtype, plan),
                }
            return _stack_init(one, k, kg2)

        params["mamba_groups"] = _stack_init(mamba_group, n_groups, kg)
        params["shared_attn"] = {
            "ln1": _norm_init(cfg, plan, dtype),
            "attn": init_attention(cfg, KeyGen(kg()), dtype, plan),
            "ln2": _norm_init(cfg, plan, dtype),
            "mlp": init_mlp(cfg, KeyGen(kg()), dtype, plan),
        }

    elif cfg.family == "ssm":
        def one(kg2):
            return {
                "ln1": _norm_init(cfg, plan, dtype),
                "tm": rwkv_mod.init_rwkv_time_mix(cfg, kg2, dtype, plan),
                "ln2": _norm_init(cfg, plan, dtype),
                "cm": rwkv_mod.init_rwkv_channel_mix(cfg, kg2, dtype, plan),
            }
        params["blocks"] = _stack_init(one, cfg.num_layers, kg)

    elif cfg.family == "encdec":
        def enc_one(kg2):
            return {
                "ln1": _norm_init(cfg, plan, dtype),
                "attn": init_attention(cfg, kg2, dtype, plan),
                "ln2": _norm_init(cfg, plan, dtype),
                "mlp": init_mlp(cfg, kg2, dtype, plan),
            }

        def dec_one(kg2):
            return {
                "ln1": _norm_init(cfg, plan, dtype),
                "attn": init_attention(cfg, kg2, dtype, plan),
                "ln_x": _norm_init(cfg, plan, dtype),
                "xattn": init_attention(cfg, kg2, dtype, plan, cross=True),
                "ln2": _norm_init(cfg, plan, dtype),
                "mlp": init_mlp(cfg, kg2, dtype, plan),
            }

        params["encoder"] = _stack_init(enc_one, cfg.encoder_layers, kg)
        params["enc_ln_f"] = _norm_init(cfg, plan, dtype)
        params["blocks"] = _stack_init(dec_one, cfg.num_layers, kg)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return params


# ---------------------------------------------------------------------------
# Blocks (apply)
# ---------------------------------------------------------------------------


def _dense_block(p, cfg, plan, rc: RunConfig, x, positions, causal=True):
    h = attention(p["attn"], cfg, plan, rms_norm(x, p["ln1"], cfg.norm_eps),
                  positions, causal=causal, impl=rc.attn_impl).out
    x = x + h
    z = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        x = x + moe(p["moe"], z, cfg, impl=rc.moe_impl,
                    capacity_factor=rc.moe_capacity,
                    token_chunk=rc.moe_token_chunk, plan=plan, mesh=rc.mesh)
    else:
        x = x + mlp(p["mlp"], z)
    return constrain(x, plan, "batch", None, None)


def _cross_block(p, cfg, plan, rc, x, kv_src):
    h = attention(p["xattn"], cfg, plan, rms_norm(x, p["ln1"], cfg.norm_eps),
                  None, kv_x=kv_src, causal=False, impl=rc.attn_impl).out
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return constrain(x, plan, "batch", None, None)


def _rwkv_block(p, cfg, plan, rc, x, tm_prev, cm_prev, state):
    z = rms_norm(x, p["ln1"], cfg.norm_eps)
    o, tm_carry, state = rwkv_mod.rwkv_time_mix(
        p["tm"], cfg, z, tm_prev, state, impl=rc.rwkv_impl)
    x = x + o
    z = rms_norm(x, p["ln2"], cfg.norm_eps)
    o, cm_carry = rwkv_mod.rwkv_channel_mix(p["cm"], cfg, z, cm_prev)
    x = x + o
    return constrain(x, plan, "batch", None, None), tm_carry, cm_carry, state


def _maybe_remat(fn, rc: RunConfig):
    return jax.checkpoint(fn) if rc.remat else fn


# ---------------------------------------------------------------------------
# Forward (training / encoder-style full sequence)
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, plan, rc: RunConfig, batch):
    """Full-sequence forward to final hidden states (B, S, D)."""
    plan = plan or ShardingPlan.null()
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, plan, "batch", None, None)
    positions = jnp.arange(s, dtype=jnp.int32)

    if cfg.family in ("dense", "moe"):
        body = _maybe_remat(
            lambda x_, p: _dense_block(p, cfg, plan, rc, x_, positions), rc)
        x = _scan_stack(params["blocks"], x, body)

    elif cfg.family == "vlm":
        img = batch["image_embeds"].astype(x.dtype)

        def group(x_, p):
            def self_body(x2, p2):
                return _dense_block(p2, cfg, plan, rc, x2, positions)
            x_ = _scan_stack(p["self"], x_, _maybe_remat(self_body, rc))
            return _maybe_remat(
                lambda x3, p3: _cross_block(p3, cfg, plan, rc, x3, img), rc
            )(x_, p["cross"])

        stacked = {"self": params["self_groups"], "cross": params["cross_layers"]}
        x = _scan_stack(stacked, x, group)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(x_, p):
            def mamba_body(x2, p2):
                z = rms_norm(x2, p2["ln"], cfg.norm_eps)
                o, _ = ssm_mod.mamba_block(p2["mamba"], cfg, z,
                                           chunk=rc.ssd_chunk)
                return constrain(x2 + o, plan, "batch", None, None)
            x_ = _scan_stack(p, x_, _maybe_remat(mamba_body, rc))
            return _maybe_remat(
                lambda x3, p3: _dense_block(p3, cfg, plan, rc, x3, positions),
                rc)(x_, shared)

        x = _scan_stack(params["mamba_groups"], x, group)

    elif cfg.family == "ssm":
        h, n = rwkv_mod.rwkv_dims(cfg)
        zero_prev = jnp.zeros((b, 1, cfg.d_model), x.dtype)
        zero_state = jnp.zeros((b, h, n, n), jnp.float32)

        def body(x_, p):
            out, _, _, _ = _rwkv_block(p, cfg, plan, rc, x_,
                                       zero_prev, zero_prev, zero_state)
            return out

        x = _scan_stack(params["blocks"], x, _maybe_remat(body, rc))

    elif cfg.family == "encdec":
        enc = encode(params, cfg, plan, rc, batch)

        def body(x_, p):
            h = attention(p["attn"], cfg, plan,
                          rms_norm(x_, p["ln1"], cfg.norm_eps),
                          positions, causal=True, impl=rc.attn_impl).out
            x_ = x_ + h
            h = attention(p["xattn"], cfg, plan,
                          rms_norm(x_, p["ln_x"], cfg.norm_eps),
                          None, kv_x=enc, causal=False,
                          impl=rc.attn_impl).out
            x_ = x_ + h
            x_ = x_ + mlp(p["mlp"], rms_norm(x_, p["ln2"], cfg.norm_eps))
            return constrain(x_, plan, "batch", None, None)

        x = _scan_stack(params["blocks"], x, _maybe_remat(body, rc))
    else:
        raise ValueError(cfg.family)

    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def encode(params, cfg: ModelConfig, plan, rc: RunConfig, batch):
    """Whisper encoder over stub frame embeddings (B, enc_seq, D)."""
    x = batch["audio_embeds"]
    x = constrain(x, plan, "batch", None, None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x_, p):
        h = attention(p["attn"], cfg, plan,
                      rms_norm(x_, p["ln1"], cfg.norm_eps),
                      positions, causal=False, impl=rc.attn_impl).out
        x_ = x_ + h
        x_ = x_ + mlp(p["mlp"], rms_norm(x_, p["ln2"], cfg.norm_eps))
        return constrain(x_, plan, "batch", None, None)

    x = _scan_stack(params["encoder"], x, _maybe_remat(body, rc))
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def _scan_stack(stacked_params, x, body):
    """x' = body(x, layer_params) over the leading stacked axis."""
    def f(carry, p):
        return body(carry, p), None
    x, _ = jax.lax.scan(f, x, stacked_params)
    return x


# ---------------------------------------------------------------------------
# Loss (chunked vocab projection)
# ---------------------------------------------------------------------------


def lm_head(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_cross_entropy(hidden, head, labels, vocab_size: int,
                          chunk: int = 512):
    """Mean next-token CE without materializing (B, S, V) logits.

    hidden (B,S,D); head (D,Vpad); labels (B,S) with -1 = ignore.
    """
    b, s, d = hidden.shape
    vpad = head.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hq = hidden.reshape(b, nc, chunk, d)
    lq = labels.reshape(b, nc, chunk)

    @jax.checkpoint
    def step(acc, idx):
        h = hq[:, idx]                                   # (B, c, D)
        l = lq[:, idx]
        logits = jax.lax.dot_general(
            h.astype(jnp.float32), head.astype(jnp.float32),
            (((2,), (0,)), ((), ())))                    # (B, c, Vpad)
        if vpad > vocab_size:
            col = jnp.arange(vpad)
            logits = jnp.where(col[None, None, :] < vocab_size, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        loss_sum = jnp.sum((lse - ll) * valid)
        cnt = jnp.sum(valid)
        return (acc[0] + loss_sum, acc[1] + cnt), None

    (loss_sum, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0), jnp.float32(0)), jnp.arange(nc))
    return loss_sum / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, plan, rc: RunConfig, batch):
    hidden = forward(params, cfg, plan, rc, batch)
    return chunked_cross_entropy(hidden, lm_head(params, cfg),
                                 batch["labels"], cfg.vocab_size,
                                 chunk=rc.loss_chunk)


__all__ = [
    "RunConfig", "init_model", "forward", "encode", "loss_fn",
    "chunked_cross_entropy", "lm_head", "split_params",
]
