"""Scheduling-policy layer: decisions, promotion, bit-exactness, leases.

The contracts under test (serve/scheduler.py, serve/cluster_batcher.py,
core/plan.py promote_plan, core/executor.py telemetry):

* policy unit behaviour — full-bucket/deadline/adaptive/coalescing
  ``select_flushes``/``on_admit`` decisions are pure functions of the
  queues, the injected engine clock and the telemetry (no wall-clock);
* shape promotion (``promote_plan``) validates its target, and coalesced
  flushes — requests running at a *promoted* ``(R, W)`` — stay
  bit-identical to per-graph ``correlation_cluster``;
* all four policies satisfy the bit-exactness contract under randomized
  arrival traces (hypothesis-style), while ``BucketBufferPool`` never
  hands out a staging buffer whose lease is outstanding;
* executor telemetry (wall/pack per flush) reaches ``ClusterStats`` and
  drives the adaptive admission window;
* ``serve_all`` retries rejected admissions, so backpressure policies can
  be driven by the reference outer loop.
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BucketBufferPool,
    build_graph,
    correlation_cluster,
    estimate_pack_stats,
    plan_graph,
    promote_plan,
)
from repro.core.executor import AsyncExecutor
from repro.core.graph import path, random_arboric
from repro.serve.cluster_batcher import (
    AdmissionRejected,
    ClusterBatcher,
    ClusterRequest,
)
from repro.serve.engine import serve_all
from repro.serve.costmodel import FlushCostModel, ShapeHeat
from repro.serve.scheduler import (
    AdaptivePolicy,
    CoalescingPolicy,
    CostAwareCoalescingPolicy,
    DeadlinePolicy,
    FlushDecision,
    FlushTelemetry,
    FullBucketPolicy,
    SchedulerPolicy,
    make_policy,
)
from repro.util import VirtualClock


def _rand_graph(n, lam, seed):
    edges, _ = random_arboric(n, lam, np.random.default_rng(seed))
    return build_graph(n, edges)


@pytest.fixture(autouse=True)
def _unpin_program_cache():
    """Cost-policy heat tracking pins bucket shapes in the *global*
    program cache; never let pins leak between tests."""
    yield
    from repro.core.executor import program_cache_info, program_cache_unpin

    for bucket in program_cache_info()["pinned"]:
        while program_cache_unpin(tuple(bucket)):   # drain all refs
            pass


def _assert_matches(g, key, res_batch, **kwargs):
    res_single = correlation_cluster(g, key=key, **kwargs)
    assert (res_batch.labels == res_single.labels).all()
    assert res_batch.cost == res_single.cost


@dataclasses.dataclass
class _Req:
    """Queue stand-in: policies only read ``admitted_at``."""

    admitted_at: float


def _queues(spec):
    """{bucket: [ages...]} → {bucket: [requests admitted at those times]}."""
    return {b: [_Req(admitted_at=t) for t in ts] for b, ts in spec.items()}


# ---------------------------------------------------------------------------
# Policy unit behaviour (pure decisions over queues + clock + telemetry).
# ---------------------------------------------------------------------------


def test_full_bucket_policy_flushes_only_full_queues():
    pol = FullBucketPolicy(max_batch=4)
    tele = FlushTelemetry()
    qs = _queues({(8, 4): [0.0, 0.1, 0.2], (16, 4): [0.0] * 4})
    out = pol.select_flushes(qs, now=10.0, telemetry=tele)
    assert out == [FlushDecision(bucket=(16, 4), count=4)]
    # Oversized queue drains in max_batch chunks within one call.
    qs = _queues({(8, 4): [0.0] * 9})
    out = pol.select_flushes(qs, now=0.0, telemetry=tele)
    assert [d.count for d in out] == [4, 4]


def test_deadline_policy_flags_overdue_partial_flushes():
    pol = DeadlinePolicy(max_batch=4, max_wait=1.0)
    tele = FlushTelemetry()
    qs = _queues({(8, 4): [0.0, 0.5], (16, 4): [4.5]})
    out = pol.select_flushes(qs, now=5.0, telemetry=tele)
    # (8, 4) is overdue and flushes its whole queue; (16, 4) aged only 0.5s.
    assert out == [FlushDecision(bucket=(8, 4), count=2, deadline=True)]
    assert pol.select_flushes(qs, now=0.9, telemetry=tele) == []


def test_adaptive_policy_window_tracks_latency_ratio():
    pol = AdaptivePolicy(max_batch=4, min_window=1, max_window=8)
    tele = FlushTelemetry(alpha=1.0)    # alpha=1: window = last sample
    assert pol.admission_window(tele) == 8      # cold: never throttle
    tele.record((8, 4), wall_s=0.100, assemble_s=0.010)
    assert pol.admission_window(tele) == 8      # ceil(10) clamped to max
    tele.record((8, 4), wall_s=0.030, assemble_s=0.010)
    assert pol.admission_window(tele) == 3      # device 3x the host
    tele.record((8, 4), wall_s=0.001, assemble_s=0.010)
    assert pol.admission_window(tele) == 1      # host-bound: no pipelining
    # Queue-inclusive wall is normalized by the in-flight depth at submit:
    # 80ms of wall behind 7 other flushes is 10ms of service, not a signal
    # to deepen the window (the feedback loop the normalization breaks).
    tele.record((8, 4), wall_s=0.080, assemble_s=0.010, depth=8)
    assert pol.admission_window(tele) == 1
    tele.in_flight = 1
    assert not pol.on_admit({}, now=0.0, telemetry=tele)
    tele.in_flight = 0
    assert pol.on_admit({}, now=0.0, telemetry=tele)


def test_static_backpressure_window_is_policy_driven():
    pol = FullBucketPolicy(max_batch=2, max_in_flight=2)
    tele = FlushTelemetry()
    tele.in_flight = 1
    assert pol.on_admit({}, now=0.0, telemetry=tele)
    tele.in_flight = 2
    assert not pol.on_admit({}, now=0.0, telemetry=tele)


def test_coalescing_policy_steals_compatible_starving_buckets():
    pol = CoalescingPolicy(max_batch=6, max_wait=2.0, steal_wait=1.0)
    tele = FlushTelemetry()
    qs = _queues({
        (16, 8): [0.0, 0.1],    # overdue at now=3 → deadline flush, room 4
        (8, 4): [1.5, 1.6],     # age ≥ steal_wait, < max_wait → stolen
        (8, 16): [1.5],         # W too large to fit (16, 8) → never stolen
        (32, 8): [1.5],         # R too large to fit (16, 8) → never stolen
    })
    (d,) = pol.select_flushes(qs, now=3.0, telemetry=tele)
    assert d.bucket == (16, 8) and d.count == 2 and d.deadline
    assert d.steal == (((8, 4), 2),)
    # Below the steal threshold nothing is stolen.
    (d,) = pol.select_flushes(qs, now=2.3, telemetry=tele)
    assert d.steal == ()


def test_full_flush_at_capacity_has_no_steal_room():
    pol = CoalescingPolicy(max_batch=4, steal_wait=0.0)
    qs = _queues({(16, 8): [0.0] * 4, (8, 4): [0.0]})
    (d,) = pol.select_flushes(qs, now=5.0, telemetry=FlushTelemetry())
    assert d.bucket == (16, 8) and d.count == 4 and d.steal == ()


def test_coalescing_steal_capacity_and_starvation_order():
    pol = CoalescingPolicy(max_batch=4, max_wait=10.0, steal_wait=0.0)
    qs = _queues({
        (32, 8): [0.0],             # overdue at now=11 → room for 3
        (8, 4): [9.0, 9.1],         # older queue → stolen first
        (16, 8): [9.5, 9.6],        # younger → only 1 of 2 fits
    })
    (d,) = pol.select_flushes(qs, now=11.0, telemetry=FlushTelemetry())
    assert d.bucket == (32, 8) and d.count == 1 and d.deadline
    assert d.steal == (((8, 4), 2), ((16, 8), 1))


@pytest.mark.parametrize("policy_cls", [CoalescingPolicy,
                                        CostAwareCoalescingPolicy])
def test_coalescing_policies_never_steal_cross_method(policy_cls):
    """Two methods sharing one ``(R, W)`` shape: an overdue ``'pivot'``
    flush may steal only from ``'pivot'`` queues. The ``'precluster'``
    queue is *older* and its shape fits, so a method-blind starvation
    order would promote it first — both built-in coalescing policies must
    skip it (its own deadline still bounds its wait)."""
    pol = policy_cls(max_batch=6, max_wait=2.0, steal_wait=1.0)
    qs = _queues({
        ("pivot", 16, 8): [0.0, 0.1],     # overdue at now=3 → room for 4
        ("pivot", 8, 4): [1.5, 1.6],      # same method → stealable
        ("precluster", 8, 4): [1.3],      # oldest, shape fits: wrong method
        ("precluster", 16, 8): [1.5],     # the flush's own shape, too
    })
    decisions = pol.select_flushes(qs, now=3.0, telemetry=FlushTelemetry())
    (d,) = [d for d in decisions if d.bucket == ("pivot", 16, 8)]
    assert d.deadline and d.count == 2
    assert d.steal == ((("pivot", 8, 4), 2),)
    for other in decisions:
        for src, _ in other.steal:
            assert src[:-2] == other.bucket[:-2], (
                f"{pol.name} proposed a cross-method steal {src} -> "
                f"{other.bucket}")


def test_batcher_refuses_hand_built_cross_method_decision():
    """A custom policy that does propose a cross-method steal is refused
    by ``_execute`` with a clear ValueError, and the popped requests are
    requeued — nothing is lost, and a subsequent clean flush still serves
    both requests bit-exactly under their own methods."""
    g = _rand_graph(12, 1, seed=7)
    eng = ClusterBatcher(max_batch=4)          # full-bucket: never auto-flush
    eng.admit(ClusterRequest(uid=0, graph=g, key=jax.random.PRNGKey(0)))
    eng.admit(ClusterRequest(uid=1, graph=g, key=jax.random.PRNGKey(1),
                             method="precluster"))
    pivot_key = next(b for b in eng.buckets if b[0] == "pivot")
    pre_key = next(b for b in eng.buckets if b[0] == "precluster")
    assert pivot_key[1:] == pre_key[1:]        # same (R, W), distinct queues
    bad = FlushDecision(bucket=pivot_key, count=1,
                        steal=((pre_key, 1),))
    with pytest.raises(ValueError, match="cross-method"):
        eng._execute(bad)
    # Both requests were requeued into their own queues...
    assert len(eng.buckets[pivot_key]) == 1
    assert len(eng.buckets[pre_key]) == 1
    # ...and a clean drain serves each under its own method, bit-exactly.
    done = {r.uid: r for r in eng.flush_all()}
    assert done[0].result.method == "pivot"
    assert done[1].result.method == "precluster"
    _assert_matches(g, jax.random.PRNGKey(0), done[0].result)
    _assert_matches(g, jax.random.PRNGKey(1), done[1].result,
                    method="precluster")
    eng.close()


@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
def test_mixed_method_trace_cost_policy_bit_exact(executor):
    """The PR 10 acceptance smoke: one engine, both registered methods in
    one trace, cost policy active. Every result must be bit-identical to
    the per-graph engine of its own method, and the flush telemetry must
    show both methods flushing through their own queues."""
    methods = ("pivot", "precluster")
    reqs = [(uid, _rand_graph(6 + 3 * (uid % 5), 1 + uid % 2, seed=uid))
            for uid in range(12)]
    eng = ClusterBatcher(max_batch=4, max_wait=0.005, policy="cost",
                         executor=executor)
    done = {}
    for uid, g in reqs:
        for r in eng.admit(ClusterRequest(uid=uid, graph=g,
                                          key=jax.random.PRNGKey(uid),
                                          method=methods[uid % 2])):
            done[r.uid] = r
    for r in eng.flush_all():
        done[r.uid] = r
    assert len(done) == len(reqs)
    for uid, g in reqs:
        m = methods[uid % 2]
        assert done[uid].result.method == m
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result,
                        method=m)
    flushed_methods = {key.split(":", 1)[0]
                       for key in eng.stats.latency.summary()}
    assert set(methods) <= flushed_methods
    eng.close()


def test_make_policy_resolution_and_validation():
    assert make_policy(None, max_batch=4).name == "full"
    assert make_policy(None, max_batch=4, max_wait=0.1).name == "deadline"
    assert make_policy("adaptive", max_batch=4,
                       max_in_flight=3).max_window == 3
    assert make_policy("coalesce", max_batch=4,
                       max_wait=1.0).steal_wait == 0.5
    assert make_policy("cost", max_batch=4, max_wait=1.0).name == "cost"
    pol = CoalescingPolicy(max_batch=2)
    assert pol.steal_wait == 0.0    # direct construction: steal when room
    assert make_policy(pol, max_batch=99) is pol
    # ... but the name route requires a deadline, or the policy would
    # silently degenerate to full-bucket (full flushes have no steal room).
    with pytest.raises(ValueError, match="coalesce.*max_wait|max_wait"):
        make_policy("coalesce", max_batch=4)
    with pytest.raises(ValueError, match="max_wait"):
        make_policy("cost", max_batch=4)
    for impl in (FullBucketPolicy(2), DeadlinePolicy(2, 0.1),
                 AdaptivePolicy(2), CoalescingPolicy(2),
                 CostAwareCoalescingPolicy(2)):
        assert isinstance(impl, SchedulerPolicy)
    with pytest.raises(ValueError, match="max_wait"):
        make_policy("deadline", max_batch=4)
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("turbo", max_batch=4)
    with pytest.raises(TypeError, match="policy"):
        make_policy(42, max_batch=4)
    with pytest.raises(ValueError, match="min_window"):
        AdaptivePolicy(4, min_window=0)
    with pytest.raises(ValueError, match="steal_wait"):
        CoalescingPolicy(4, steal_wait=-1.0)


def test_make_policy_rejects_knobs_conflicting_with_instance():
    """A policy instance carries its own max_wait/max_in_flight; silently
    ignoring the engine-level knobs (the old behaviour) hid real
    misconfigurations — ClusterBatcher(policy=AdaptivePolicy(...),
    max_wait=0.05) got no deadline and no error."""
    pol = AdaptivePolicy(4, max_wait=0.2)
    with pytest.raises(ValueError, match="max_wait"):
        make_policy(pol, max_batch=4, max_wait=0.05)
    with pytest.raises(ValueError, match="max_in_flight"):
        make_policy(DeadlinePolicy(4, 0.1), max_batch=4, max_in_flight=2)
    with pytest.raises(ValueError, match="max_wait and max_in_flight"):
        make_policy(pol, max_batch=4, max_wait=0.05, max_in_flight=2)
    # Clean pass-through: knobs on the instance itself are fine.
    assert make_policy(pol, max_batch=4) is pol
    # The batcher-level surface: conflict raises, instance-only works and
    # the instance's own deadline actually drives the engine.
    with pytest.raises(ValueError, match="max_wait"):
        ClusterBatcher(max_batch=4, policy=AdaptivePolicy(4, max_wait=0.2),
                       max_wait=0.05)
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, clock=clock,
                             policy=DeadlinePolicy(4, max_wait=0.1))
    batcher.admit(ClusterRequest(uid=0, graph=build_graph(6, path(6)),
                                 key=jax.random.PRNGKey(0)))
    clock.advance(0.2)
    assert {r.uid for r in batcher.poll()} == {0}   # the deadline fired


# ---------------------------------------------------------------------------
# promote_plan: validation + bit-exact coalesced flushes (tentpole contract).
# ---------------------------------------------------------------------------


def test_promote_plan_validates_and_is_identity_at_native_shape():
    plan = plan_graph(build_graph(6, path(6)))          # (8, 4)
    assert promote_plan(plan, 8, 4) is plan
    bigger = promote_plan(plan, 32, 8)
    assert bigger.bucket == (32, 8)
    assert bigger.n == plan.n and bigger.wreq == plan.wreq
    assert plan.bucket == (8, 4)                        # original untouched
    with pytest.raises(ValueError, match="promote"):
        promote_plan(plan, 4, 4)
    with pytest.raises(ValueError, match="promote"):
        promote_plan(bigger, 32, 4)
    with pytest.raises(ValueError, match="largest supported"):
        promote_plan(plan, 1 << 20, 4)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("policy", ["coalesce", "cost"])
@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
def test_coalesced_flush_promotes_and_stays_bit_exact(executor, policy,
                                                      use_kernel):
    """Hot bucket goes overdue below capacity; the younger starving cold
    request is stolen into its deadline flush at a promoted (R, W) shape,
    and every result matches the per-graph engine bit-exactly. The cost
    policy takes the same steal here (cold telemetry → it degrades to
    age-only coalescing), so both stealing policies run the promoted
    path under every executor and kernel."""
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=8, policy=policy, max_wait=0.1,
                             clock=clock, executor=executor,
                             use_kernel=use_kernel, num_samples=2)
    hot = [build_graph(n, path(n)) for n in (17, 20, 24)]   # bucket (32, 4)
    for i, g in enumerate(hot):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
        clock.advance(0.01)
    cold = build_graph(6, path(6))                          # bucket (8, 4)
    batcher.admit(ClusterRequest(uid=9, graph=cold,
                                 key=jax.random.PRNGKey(9)))
    # Hot oldest is now 0.03s old, cold 0.0s. Advance so the hot bucket is
    # overdue (0.11 ≥ max_wait) while cold (0.08) is past steal_wait (0.05)
    # but under its own deadline — the exact starvation-steal window.
    clock.advance(0.08)
    retired = batcher.poll()
    retired += batcher.flush()
    done = {r.uid: r for r in retired}
    assert sorted(done) == [0, 1, 2, 9]
    assert batcher.stats.flushes == 1       # one coalesced flush served all
    assert batcher.stats.coalesced_flushes == 1
    assert batcher.stats.stolen_requests == 1
    for uid, g in [(0, hot[0]), (1, hot[1]), (2, hot[2]), (9, cold)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result,
                        num_samples=2)
    # Promotion is transparent to the caller: the result still reports the
    # request's native bucket.
    assert done[9].result.info["bucket"] == (8, 4)


def test_coalescing_full_flush_steals_when_room_remains():
    """A full-bucket flush below max_batch capacity... cannot exist — but a
    repeating hot stream with spare room shows steady-state stealing: the
    cold request rides the first hot deadline flush, never the drain."""
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, policy="coalesce", max_wait=0.05,
                             clock=clock)
    cold = build_graph(5, path(5))
    hot = [build_graph(n, path(n)) for n in (17, 18, 19)]
    # Cold arrives first and would starve behind the hot stream under the
    # full-bucket policy (its bucket never fills).
    batcher.admit(ClusterRequest(uid=100, graph=cold,
                                 key=jax.random.PRNGKey(100)))
    clock.advance(0.04)     # cold nearly overdue
    for i, g in enumerate(hot):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    clock.advance(0.06)     # everyone overdue → cold's own deadline fires
    retired = batcher.poll()
    done = {r.uid: r for r in retired}
    # Cold is overdue itself, so it flushes regardless of stealing — the
    # guarantee that coalescing never *worsens* the deadline contract.
    assert 100 in done
    assert batcher.pending() == 0
    _assert_matches(cold, jax.random.PRNGKey(100), done[100].result)
    for i, g in enumerate(hot):
        _assert_matches(g, jax.random.PRNGKey(i), done[i].result)


# ---------------------------------------------------------------------------
# Cost model: pricing arithmetic, abstention, cost-aware steal decisions.
# ---------------------------------------------------------------------------


def _warm_telemetry(bucket=(32, 4), wall_s=0.08, assemble_s=0.001):
    tele = FlushTelemetry(alpha=1.0)    # alpha=1: EWMA = last sample
    tele.record(bucket, wall_s=wall_s, assemble_s=assemble_s)
    return tele


def test_cost_model_abstains_cold_and_prices_warm():
    model = FlushCostModel()
    cold = FlushTelemetry()
    # Cold telemetry, no floor: the model abstains — callers degrade to
    # plain age-only coalescing.
    cost = model.price_steal((32, 4), 8, [((8, 4), 0.01)], 0.1, cold)
    assert not cost.priced and cost.accepts()
    # With a floor the same cold engine *can* price (a pessimistic prior).
    floored = FlushCostModel(service_floor_s=0.05)
    cost = floored.price_steal((32, 4), 8, [((8, 4), 0.01)], 0.1, cold)
    assert cost.priced
    # Warm pricing at a pow2 boundary: count 8 + 1 steal doubles g_pad, so
    # the marginal pad entries are (16 − 8) − 1 = 7, priced at the per-entry
    # service time 80ms/8 — far above the 10ms of slack the steal saves.
    tele = _warm_telemetry(wall_s=0.08)
    cost = model.price_steal((32, 4), 8, [((8, 4), 0.09)], 0.1, tele)
    assert cost.pad_entries_added == 7
    assert cost.vertex_waste_added == 32 - 8
    assert cost.benefit_s == pytest.approx(0.1 - 0.09)
    assert cost.pad_cost_s > 0.06       # ≥ 7 · 10ms of pad alone
    assert not cost.accepts()
    # Riding existing padding is (nearly) free: count 5 + 3 steals stays at
    # g_pad 8 — no pad entries added, only the promoted-row fraction.
    cost = model.price_steal((32, 4), 5, [((8, 4), 0.02)] * 3, 0.1, tele)
    assert cost.pad_entries_added == -3
    assert cost.pad_cost_s == pytest.approx(
        3 * (32 - 8) / 32 * 0.08 / 8)
    assert cost.accepts()               # 3 × 80ms slack ≫ 22.5ms


def test_cost_model_hurdle_and_validation():
    tele = _warm_telemetry(wall_s=0.08)
    # benefit 60ms vs cost ≈ 22.5ms: accepted at hurdle 1, rejected at 10.
    free = [((8, 4), 0.04)] * 3
    assert FlushCostModel().price_steal((32, 4), 5, free, 0.1,
                                        tele).accepts(1.0)
    assert not FlushCostModel().price_steal((32, 4), 5, free, 0.1,
                                            tele).accepts(10.0)
    with pytest.raises(ValueError, match="hurdle"):
        FlushCostModel(hurdle=0.0)
    with pytest.raises(ValueError, match=">= 0"):
        FlushCostModel(compile_cost_s=-1.0)
    with pytest.raises(ValueError):
        ShapeHeat(window=0)
    with pytest.raises(ValueError):
        ShapeHeat(min_heat=0)


def test_cost_model_compile_charge_uses_cache_probe():
    from repro.core.executor import run_bucket_program

    import numpy as _np

    model = FlushCostModel(compile_cost_s=0.5, service_floor_s=0.01)
    model.bind_engine(num_samples=1, use_kernel=False, donate=False)
    tele = _warm_telemetry(bucket=(8, 4), wall_s=0.001)
    # Shape (2, 8, 4) not compiled with this exact signature → charged.
    probe = model.price_steal((8, 4), 1, [((8, 4), 0.05)], 0.1, tele)
    if probe.compile_cost_s == 0.0:
        # Another test may have compiled it; force a fresh shape instead.
        pytest.skip("shape already resident — probe covered elsewhere")
    assert probe.compile_cost_s == 0.5
    # Compile it for real; the charge disappears.
    ell = _np.full((2, 8, 4), 8, dtype=_np.int32)
    ranks = _np.full((2, 9), _np.iinfo(_np.int32).max, dtype=_np.int32)
    elig = _np.zeros((2, 9), dtype=bool)
    m = _np.zeros((2,), dtype=_np.int32)
    run_bucket_program(ell, ranks, elig, m, k=1)
    after = model.price_steal((8, 4), 1, [((8, 4), 0.05)], 0.1, tele)
    assert after.compile_cost_s == 0.0


def test_cost_aware_policy_rejects_boundary_steal_and_trims_to_free_room():
    """Unit decisions: at a pow2 boundary the steal is dropped entirely;
    below it the free prefix is kept and the inflating tail rejected."""
    tele = _warm_telemetry(wall_s=0.08)
    # Boundary: 8 native hot requests overdue, one starving cold — the
    # age-only parent steals it, the cost policy refuses (7 pad entries
    # at ~10ms each vs 10ms slack).
    pol = CostAwareCoalescingPolicy(16, max_wait=0.1, steal_wait=0.01)
    qs = _queues({(32, 4): [0.0] * 8, (8, 4): [0.02]})
    (d,) = pol.select_flushes(qs, now=0.11, telemetry=tele)
    assert d.bucket == (32, 4) and d.count == 8 and d.steal == ()
    assert pol.steals_rejected == 1 and pol.steals_accepted == 0
    assert pol.pad_entries_avoided == 7
    # Same queues, cold telemetry: degrades to the parent's age-only steal.
    pol2 = CostAwareCoalescingPolicy(16, max_wait=0.1, steal_wait=0.01)
    (d2,) = pol2.select_flushes(qs, now=0.11, telemetry=FlushTelemetry())
    assert d2.steal == (((8, 4), 1),)
    assert pol2.steals_accepted == 1 and pol2.steals_rejected == 0
    # Trim: 6 native (g_pad 8 → 2 free slots) + 4 starving cold. Taking
    # all 4 inflates to g_pad 16; the free 2 ride existing padding.
    pol3 = CostAwareCoalescingPolicy(16, max_wait=0.1, steal_wait=0.01)
    qs3 = _queues({(32, 4): [0.0] * 6, (8, 4): [0.02, 0.02, 0.03, 0.03]})
    (d3,) = pol3.select_flushes(qs3, now=0.11, telemetry=tele)
    assert d3.count == 6 and d3.steal == (((8, 4), 2),)
    assert pol3.steals_accepted == 2 and pol3.steals_rejected == 2


def test_trimmed_steal_reanchors_later_decisions_at_queue_front():
    """Cross-decision pricing: when an earlier decision's steal is
    rejected, a later decision stealing from the same queue must be
    priced against the queue *front* entries execution will actually pop
    (the oldest, with the least deadline slack) — not the younger offsets
    the parent planned assuming the first steal happened. Here the
    re-anchored benefit (0.05s of slack) falls below the promoted-row
    cost (~0.066s at the 0.3s service floor) while the stale offsets'
    benefit (0.09s) would have cleared it — so the steal must be
    rejected."""
    pol = CostAwareCoalescingPolicy(
        10, max_wait=0.1, steal_wait=0.01,
        cost_model=FlushCostModel(service_floor_s=0.3))
    qs = _queues({
        (32, 4): [0.0] * 8,             # boundary: stealing into it inflates
        (64, 4): [0.005] * 6,           # g_pad 8: two free steal slots
        (8, 4): [0.03, 0.04, 0.05, 0.06],
    })
    d_a, d_b = pol.select_flushes(qs, now=0.11, telemetry=FlushTelemetry())
    # First decision's steal rejected on the pow2 inflation...
    assert d_a.bucket == (32, 4) and d_a.steal == ()
    # ...and the second decision's steal — re-anchored at the queue front
    # — is priced too expensive as well (stale offsets would accept it).
    assert d_b.bucket == (64, 4)
    assert d_b.steal == ()
    assert pol.steals_rejected == 4 and pol.steals_accepted == 0


def test_shape_heat_release_does_not_strip_other_trackers():
    """Pins are refcounted process-globally: one engine's teardown must
    not strip a shape another live engine still pins."""
    from repro.core.executor import program_cache_info

    heat_a = ShapeHeat(window=8, max_pinned=1, min_heat=1)
    heat_b = ShapeHeat(window=8, max_pinned=1, min_heat=1)
    heat_a.on_retire((8, 4))
    heat_b.on_retire((8, 4))
    try:
        assert (8, 4) in program_cache_info()["pinned"]
        heat_a.release()
        # B's pin survives A's teardown.
        assert (8, 4) in program_cache_info()["pinned"]
    finally:
        heat_b.release()
        heat_a.release()
    assert (8, 4) not in program_cache_info()["pinned"]


def test_shape_heat_pins_hot_bucket_and_releases_cold():
    pins, unpins, touches = [], [], []
    heat = ShapeHeat(window=8, max_pinned=1, min_heat=3,
                     pin=pins.append, unpin=unpins.append,
                     touch=touches.append)
    hot, cold = (8, 4), (32, 4)
    for _ in range(3):
        heat.on_retire(hot)
    assert pins == [hot] and heat.pinned == {hot}
    assert touches == [hot] * 3
    # A different shape taking over the window displaces the pin.
    for _ in range(8):
        heat.on_retire(cold)
    assert hot in unpins and heat.pinned == {cold}
    heat.release()
    assert heat.pinned == set() and cold in unpins


def test_cost_policy_pins_hot_shape_through_batcher_retires():
    """End-to-end heat: serving a hot shape through the cost policy pins
    it in the real program cache; teardown unpins."""
    from repro.core.executor import program_cache_info, program_cache_unpin

    batcher = ClusterBatcher(max_batch=1, policy="cost", max_wait=0.05)
    g = build_graph(6, path(6))
    try:
        for i in range(4):
            batcher.admit(ClusterRequest(uid=i, graph=g,
                                         key=jax.random.PRNGKey(i)))
            batcher.flush()
        assert (8, 4) in batcher.policy.heat.pinned
        assert (8, 4) in program_cache_info()["pinned"]
    finally:
        batcher.close()         # engine teardown releases the global pins
    assert (8, 4) not in program_cache_info()["pinned"]
    batcher.close()             # idempotent


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("executor", ["sync", "async", "sharded"])
def test_cost_rejected_steal_stays_bit_exact(executor, use_kernel):
    """The acceptance-criteria path: a steal *rejected* on cost. The cold
    request must still retire (its own deadline) and every result must
    match the per-graph engine bit-exactly — pricing can only ever decide
    whether a steal happens, never what a flush computes."""
    clock = VirtualClock()
    model = FlushCostModel(service_floor_s=10.0)    # poison: reject all
    pol = CostAwareCoalescingPolicy(8, max_wait=0.1, steal_wait=0.05,
                                    cost_model=model)
    batcher = ClusterBatcher(max_batch=8, policy=pol, clock=clock,
                             executor=executor, use_kernel=use_kernel,
                             num_samples=2)
    hot = [build_graph(n, path(n)) for n in (17, 20, 24)]   # bucket (32, 4)
    for i, g in enumerate(hot):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
        clock.advance(0.01)
    cold = build_graph(6, path(6))                          # bucket (8, 4)
    batcher.admit(ClusterRequest(uid=9, graph=cold,
                                 key=jax.random.PRNGKey(9)))
    clock.advance(0.08)
    retired = batcher.poll()        # hot deadline flush; steal refused
    assert pol.steals_rejected >= 1
    assert batcher.stats.stolen_requests == 0
    assert 9 not in {r.uid for r in retired}
    clock.advance(0.05)             # cold crosses its own deadline
    retired += batcher.poll()
    retired += batcher.flush()
    done = {r.uid: r for r in retired}
    assert sorted(done) == [0, 1, 2, 9]
    assert batcher.stats.coalesced_flushes == 0
    for uid, g in [(0, hot[0]), (1, hot[1]), (2, hot[2]), (9, cold)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result,
                        num_samples=2)


# ---------------------------------------------------------------------------
# Steal-induced pad accounting (satellite): serving stats must equal the
# promoted pack's own numbers — the quantity the cost model prices.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("executor", ["sync", "async"])
def test_steal_pad_accounting_matches_promoted_pack(executor):
    clock = VirtualClock()
    k = 2
    batcher = ClusterBatcher(max_batch=8, policy="coalesce", max_wait=0.1,
                             clock=clock, executor=executor, num_samples=k)
    hot = [build_graph(n, path(n)) for n in (17, 20, 24)]   # bucket (32, 4)
    cold = [build_graph(5, path(5)), build_graph(6, path(6))]  # (8, 4)
    for i, g in enumerate(hot):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
        clock.advance(0.01)
    for j, g in enumerate(cold):
        batcher.admit(ClusterRequest(uid=10 + j, graph=g,
                                     key=jax.random.PRNGKey(10 + j)))
    clock.advance(0.08)
    batcher.poll()                  # one coalesced flush: 3 hot + 2 stolen
    assert batcher.stats.flushes == 1
    assert batcher.stats.stolen_requests == 2
    # Independent ground truth: the promoted pack priced by the pure
    # PackStats formula — 5 graphs at (32, 4), g_pad = 8.
    expected = estimate_pack_stats(
        [promote_plan(plan_graph(g), 32, 4) for g in hot + cold], k=k)
    assert expected.padded_entries == (8 - 5) * k
    assert expected.pad_vertex_waste == sum(
        32 - g.n for g in hot + cold)
    assert batcher.stats.padded_slots == expected.padded_entries
    assert batcher.stats.pad_vertex_waste == expected.pad_vertex_waste
    retired = batcher.flush()
    for r in retired:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result,
                        num_samples=k)


# ---------------------------------------------------------------------------
# Harvest-error deferral (satellite): one failed earlier flush must not
# drop the rest of a tick's decisions.
# ---------------------------------------------------------------------------


class _ExplodingOutput:
    """Device-output stand-in: reports ready, then fails the fetch."""

    def is_ready(self):
        return True

    def __array__(self, *args, **kwargs):
        raise RuntimeError("device fetch exploded")


class _MidTickFailureExecutor(AsyncExecutor):
    """Poisons one flush's outputs so its fetch fails, and withholds the
    handle from ``retire()`` until armed + one extra call — landing the
    failure exactly in ``_execute``'s trailing harvest, mid-tick, between
    two policy decisions."""

    def __init__(self):
        super().__init__()
        self.poison_next = False
        self.released = False
        self._skip = 0
        self._held = None

    def _post_submit(self, handle):
        if self.poison_next:
            handle._outputs = (_ExplodingOutput(),) * 4
            self._held = handle
            self.poison_next = False

    def arm(self):
        """Deliver the poisoned handle on the *second* retire() from now
        (skipping a tick's initial harvest)."""
        self.released = True
        self._skip = 1

    def retire(self):
        out = super().retire()
        if self._held is not None and self._held in out:
            if not self.released or self._skip > 0:
                if self.released:
                    self._skip -= 1
                out.remove(self._held)
                self._pending.append(self._held)
        return out


def test_harvest_error_does_not_drop_remaining_decisions():
    """Regression: a harvest error from a previous flush surfaced between
    two FlushDecisions used to abort the tick — the second (due!) deadline
    flush was silently skipped past its budget. Now every decision
    executes, the error is re-raised afterwards, and the failed flush's
    requests are requeued and succeed on retry."""
    ex = _MidTickFailureExecutor()
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=2, max_wait=0.05, clock=clock,
                             executor=ex)
    g_a = build_graph(6, path(6))           # bucket (8, 4)
    g_b = build_graph(20, path(20))         # bucket (32, 4)
    ex.poison_next = True                   # the first flush will fail
    batcher.admit(ClusterRequest(uid=0, graph=g_a,
                                 key=jax.random.PRNGKey(0)))
    batcher.admit(ClusterRequest(uid=1, graph=g_a,
                                 key=jax.random.PRNGKey(1)))   # full → flush
    assert batcher.stats.flushes == 1
    # Two more buckets go due together.
    batcher.admit(ClusterRequest(uid=2, graph=g_a,
                                 key=jax.random.PRNGKey(2)))
    batcher.admit(ClusterRequest(uid=3, graph=g_b,
                                 key=jax.random.PRNGKey(3)))
    clock.advance(0.1)
    ex.arm()
    with pytest.raises(RuntimeError, match="exploded"):
        batcher.poll()
    # BOTH due deadline flushes were dispatched before the error surfaced
    # (the old behaviour stopped at 2: the first deadline flush's trailing
    # harvest raised and dropped the second decision).
    assert batcher.stats.flushes == 3
    # The failed flush's requests are back in their native bucket, oldest
    # first; nothing was lost.
    assert [r.uid for r in batcher.buckets.get(("pivot", 8, 4), [])] == [0, 1]
    retired = batcher.flush()               # failing-then-succeeding retry
    done = {r.uid: r for r in retired}
    assert sorted(done) == [0, 1, 2, 3]
    for uid, g in [(0, g_a), (1, g_a), (2, g_a), (3, g_b)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result)


class _FailOnceSubmitExecutor(AsyncExecutor):
    """Raises on the first submit of one bucket shape (a dispatch-time
    failure, e.g. device OOM), then behaves normally."""

    def __init__(self, fail_bucket):
        super().__init__()
        self.fail_bucket = fail_bucket
        self.failed = False

    def submit(self, ell, *args, **kwargs):
        shape = np.shape(ell)
        if (shape[1], shape[2]) == self.fail_bucket and not self.failed:
            self.failed = True
            raise RuntimeError("submit boom")
        return super().submit(ell, *args, **kwargs)


def test_flush_drains_remaining_buckets_past_dispatch_error():
    """flush()'s deferral covers dispatch failures too: one bucket's
    pack/submit blowing up must not strand the other queued buckets
    undispatched or skip the blocking harvest."""
    ex = _FailOnceSubmitExecutor(fail_bucket=(8, 4))
    batcher = ClusterBatcher(max_batch=4, executor=ex)
    g_a, g_b = build_graph(6, path(6)), build_graph(20, path(20))
    batcher.admit(ClusterRequest(uid=0, graph=g_a,
                                 key=jax.random.PRNGKey(0)))
    batcher.admit(ClusterRequest(uid=1, graph=g_b,
                                 key=jax.random.PRNGKey(1)))
    with pytest.raises(RuntimeError, match="submit boom"):
        batcher.flush()
    assert batcher.stats.flushes == 1               # (32,4) still drained
    assert [r.uid for r in batcher.buckets.get(("pivot", 8, 4), [])] == [0]
    done = {r.uid: r for r in batcher.flush()}      # retry succeeds
    assert sorted(done) == [0, 1]
    for uid, g in [(0, g_a), (1, g_b)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result)


def test_poll_dispatch_error_does_not_drop_remaining_decisions():
    """The policy tick contains dispatch failures like flush() does: one
    decision's pack/submit blowing up must not skip the tick's other due
    deadline flushes past their budget."""
    ex = _FailOnceSubmitExecutor(fail_bucket=(8, 4))
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, max_wait=0.05, clock=clock,
                             executor=ex)
    g_a, g_b = build_graph(6, path(6)), build_graph(20, path(20))
    batcher.admit(ClusterRequest(uid=0, graph=g_a,
                                 key=jax.random.PRNGKey(0)))
    batcher.admit(ClusterRequest(uid=1, graph=g_b,
                                 key=jax.random.PRNGKey(1)))
    clock.advance(0.1)                      # both buckets due
    with pytest.raises(RuntimeError, match="submit boom"):
        batcher.poll()
    assert batcher.stats.flushes == 1       # the second decision ran
    assert [r.uid for r in batcher.buckets.get(("pivot", 8, 4), [])] == [0]
    done = {r.uid: r for r in batcher.flush()}
    assert sorted(done) == [0, 1]
    for uid, g in [(0, g_a), (1, g_b)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result)


def test_poll_leading_harvest_error_does_not_drop_decisions():
    """The tick's *leading* harvest joins the deferral discipline too: an
    error surfacing there (failed flush already ready when poll starts)
    must not stop the due deadline flushes from dispatching."""
    ex = _MidTickFailureExecutor()
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=2, max_wait=0.05, clock=clock,
                             executor=ex)
    g_a, g_b = build_graph(6, path(6)), build_graph(20, path(20))
    ex.poison_next = True
    batcher.admit(ClusterRequest(uid=0, graph=g_a,
                                 key=jax.random.PRNGKey(0)))
    batcher.admit(ClusterRequest(uid=1, graph=g_a,
                                 key=jax.random.PRNGKey(1)))   # poisoned
    batcher.admit(ClusterRequest(uid=2, graph=g_b,
                                 key=jax.random.PRNGKey(2)))
    clock.advance(0.1)                      # uid2 due
    ex.released = True                      # poison lands at poll's start
    with pytest.raises(RuntimeError, match="exploded"):
        batcher.poll()
    # The tick still dispatched everything due: uid2's deadline flush AND
    # the requeued uid0/uid1 (their bucket refilled by the requeue, so it
    # re-flushed in the same tick) — 1 poisoned + 2 live flushes.
    assert batcher.stats.flushes == 3
    done = {r.uid: r for r in batcher.flush()}
    assert sorted(done) == [0, 1, 2]


def test_flush_drains_remaining_buckets_past_harvest_error():
    """Same deferral discipline at end-of-stream: flush() must dispatch
    every queued bucket even when an earlier flush's harvest fails
    mid-drain (the old behaviour stranded the later buckets undispatched)."""
    ex = _MidTickFailureExecutor()
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=2, clock=clock, executor=ex)
    g_a = build_graph(6, path(6))           # bucket (8, 4)
    g_b = build_graph(20, path(20))         # bucket (32, 4)
    g_c = build_graph(40, path(40))         # bucket (64, 4)
    ex.poison_next = True
    batcher.admit(ClusterRequest(uid=0, graph=g_a,
                                 key=jax.random.PRNGKey(0)))
    batcher.admit(ClusterRequest(uid=1, graph=g_a,
                                 key=jax.random.PRNGKey(1)))   # poisoned
    batcher.admit(ClusterRequest(uid=2, graph=g_b,
                                 key=jax.random.PRNGKey(2)))
    batcher.admit(ClusterRequest(uid=3, graph=g_c,
                                 key=jax.random.PRNGKey(3)))
    ex.released = True                      # deliver on the next retire
    with pytest.raises(RuntimeError, match="exploded"):
        batcher.flush()
    # The poison surfaced inside the first bucket's trailing harvest, yet
    # the second queued bucket was still dispatched: 1 poisoned + 2 drains.
    assert batcher.stats.flushes == 3
    done = {r.uid: r for r in batcher.flush()}
    assert sorted(done) == [0, 1, 2, 3]
    for uid, g in [(0, g_a), (1, g_a), (2, g_b), (3, g_c)]:
        _assert_matches(g, jax.random.PRNGKey(uid), done[uid].result)


# ---------------------------------------------------------------------------
# Telemetry plumbing: executor → ClusterStats → adaptive window.
# ---------------------------------------------------------------------------


def test_flush_latency_telemetry_reaches_stats():
    batcher = ClusterBatcher(max_batch=2)
    g = build_graph(6, path(6))
    for i in range(4):
        batcher.admit(ClusterRequest(uid=i, graph=g,
                                     key=jax.random.PRNGKey(i)))
    batcher.flush()
    tele = batcher.stats.latency
    assert tele.total_flushes == batcher.stats.flushes == 2
    assert tele.ewma_wall is not None and tele.ewma_wall >= 0.0
    assert tele.ewma_assemble is not None and tele.ewma_assemble >= 0.0
    # Deprecated pre-split alias must keep answering with the new stream.
    assert tele.ewma_pack == tele.ewma_assemble
    # Default engine prebuilds rows at admission: one build per request,
    # in its own telemetry stream, off every flush's wall.
    assert tele.total_builds == 4
    assert tele.ewma_build is not None and tele.ewma_build >= 0.0
    summary = tele.summary()
    assert list(summary) == ["pivot:8x4"]     # keys are method-qualified
    rec = summary["pivot:8x4"]
    assert rec["flushes_total"] == 2
    assert rec["window_samples"] == 2
    for field in ("wall_p50_ms", "wall_p99_ms", "assemble_p50_ms",
                  "assemble_p99_ms", "wall_ewma_ms", "build_p50_ms",
                  "build_p99_ms"):
        assert rec[field] >= 0.0
    assert rec["builds_total"] == 4
    assert batcher.stats.policy == "full"


def test_telemetry_summary_separates_lifetime_from_window_counts():
    """Past the retention window, lifetime flush counts and the sample
    count percentiles are computed over must diverge — and the summary
    must say so explicitly (the old single 'flushes' field silently mixed
    a lifetime count with windowed percentiles)."""
    tele = FlushTelemetry(window=4)
    for i in range(10):
        tele.record((8, 4), wall_s=0.001 * (i + 1), assemble_s=0.0005)
    rec = tele.summary()["8x4"]
    assert rec["flushes_total"] == 10
    assert rec["window_samples"] == 4
    # Percentiles really are windowed: all retained walls are the last 4.
    assert rec["wall_p50_ms"] >= 0.001 * 7 * 1e3 - 1e-9


def test_adaptive_policy_serves_and_windows_from_real_telemetry():
    batcher = ClusterBatcher(max_batch=2, policy="adaptive",
                             executor="async")
    assert batcher.stats.policy == "adaptive"
    reqs = [ClusterRequest(uid=i, graph=_rand_graph(6 + (i % 3), 1, seed=i),
                           key=jax.random.PRNGKey(i)) for i in range(8)]
    retired = serve_all(batcher, reqs)
    assert sorted(r.uid for r in retired) == list(range(8))
    for r in retired:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)
    # Telemetry accumulated, and the window is now latency-derived.
    assert batcher.stats.latency.total_flushes >= 1
    window = batcher.policy.admission_window(batcher.stats.latency)
    assert 1 <= window <= batcher.policy.max_window


class _ReleasingExecutor(AsyncExecutor):
    """Stalls harvests for a fixed number of retire() calls, then releases
    — deterministic backpressure that eventually clears."""

    def __init__(self, stall_retires=2):
        super().__init__()
        self.stall_retires = stall_retires

    def retire(self):
        if self.stall_retires > 0:
            self.stall_retires -= 1
            return []
        return super().retire()


def test_serve_all_retries_rejected_admissions():
    """The reference driver must survive AdmissionRejected (harvest +
    retry) so backpressure/adaptive policies can be driven by it."""
    ex = _ReleasingExecutor(stall_retires=8)
    batcher = ClusterBatcher(max_batch=1, executor=ex, max_in_flight=1)
    g = build_graph(6, path(6))
    reqs = [ClusterRequest(uid=i, graph=g, key=jax.random.PRNGKey(i))
            for i in range(4)]
    retired = serve_all(batcher, reqs)
    assert sorted(r.uid for r in retired) == list(range(4))
    assert batcher.stats.rejected >= 1      # backpressure actually fired
    for r in retired:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)


# ---------------------------------------------------------------------------
# Determinism: scheduling decisions only ever see the injected clock.
# ---------------------------------------------------------------------------


def test_no_wall_clock_on_any_scheduling_path(monkeypatch):
    """With a virtual clock injected, admit/poll/oldest_wait/flush must
    never fall back to time.monotonic — freeze it to a poisoned callable
    and drive a full deadline + coalescing cycle."""
    import sys
    import time as _time

    real_monotonic = _time.monotonic

    def _guarded():
        # JAX internals legitimately use time.monotonic; only calls from
        # this repo's serving layer are a clock-injection violation.
        caller = sys._getframe(1).f_globals.get("__name__", "")
        if caller.startswith("repro.serve"):
            raise AssertionError(
                "bare time.monotonic() on a scheduling path")
        return real_monotonic()

    monkeypatch.setattr(_time, "monotonic", _guarded)
    clock = VirtualClock()
    batcher = ClusterBatcher(max_batch=4, max_wait=0.5, policy="coalesce",
                             clock=clock)
    g_small, g_big = build_graph(6, path(6)), build_graph(20, path(20))
    batcher.admit(ClusterRequest(uid=0, graph=g_small,
                                 key=jax.random.PRNGKey(0)))
    clock.advance(0.3)
    batcher.admit(ClusterRequest(uid=1, graph=g_big,
                                 key=jax.random.PRNGKey(1)))
    assert batcher.oldest_wait() == pytest.approx(0.3)
    clock.advance(0.3)
    retired = batcher.poll()        # uid0 overdue → deadline flush
    assert 0 in {r.uid for r in retired}
    retired += batcher.flush()
    assert sorted(r.uid for r in retired) == [0, 1]
    # Default clock resolves to the real monotonic clock when not injected.
    monkeypatch.undo()
    assert ClusterBatcher(max_batch=2).clock is _time.monotonic


# ---------------------------------------------------------------------------
# Randomized arrival traces: lease invariant + bit-exactness per policy
# (hypothesis-style satellite; runs under the conftest stub too).
# ---------------------------------------------------------------------------


class _LeaseAuditPool(BucketBufferPool):
    """Asserts the lease invariant: acquire never hands out staging arrays
    whose lease is still outstanding."""

    def __init__(self):
        super().__init__()
        self.outstanding = set()

    def acquire(self, b, r, w):
        lease = super().acquire(b, r, w)
        ident = id(lease.arrays["ell"])
        assert ident not in self.outstanding, \
            "BucketBufferPool refilled a staging buffer still in flight"
        self.outstanding.add(ident)
        return lease

    def _release(self, lease):
        self.outstanding.discard(id(lease.arrays["ell"]))
        super()._release(lease)


@settings(max_examples=12, deadline=None)
@given(policy=st.sampled_from(["full", "deadline", "adaptive", "coalesce",
                               "cost"]),
       seed=st.integers(min_value=0, max_value=10_000),
       gap_ms=st.floats(min_value=0.0, max_value=30.0),
       wait_ms=st.floats(min_value=1.0, max_value=60.0))
def test_random_traces_bit_exact_and_lease_safe(policy, seed, gap_ms,
                                                wait_ms):
    """Drive each policy over a random (n, arrival-gap, deadline) stream on
    a virtual clock: every result must match the per-graph engine and the
    pool must never refill an in-flight lease."""
    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    pool = _LeaseAuditPool()
    max_wait = wait_ms / 1e3 if policy != "full" else None
    batcher = ClusterBatcher(max_batch=4, policy=policy, max_wait=max_wait,
                             clock=clock, pool=pool, executor="async")
    n_reqs = int(rng.integers(6, 12))
    reqs = []
    retired = []
    for uid in range(n_reqs):
        clock.advance(gap_ms / 1e3 * float(rng.random()))
        n = int(rng.integers(5, 15))
        req = ClusterRequest(uid=uid,
                             graph=_rand_graph(n, 1, seed * 31 + uid),
                             key=jax.random.PRNGKey(uid))
        reqs.append(req)
        while True:
            try:
                retired += batcher.admit(req)
                break
            except AdmissionRejected:       # adaptive window can reject
                retired += batcher.retire()
        retired += batcher.poll()
    retired += batcher.flush()
    assert sorted(r.uid for r in retired) == list(range(n_reqs))
    assert pool.leased == 0 and not pool.outstanding
    for r in reqs:
        _assert_matches(r.graph, jax.random.PRNGKey(r.uid), r.result)
