"""Pure-jnp oracles for every Pallas kernel (no Pallas imports).

These are the correctness references the kernel tests sweep against
(`tests/test_kernels.py` asserts allclose across shapes/dtypes) and the
fallbacks used on platforms without the kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INF_I32 = jnp.int32(2**31 - 1)


def neighbor_min_ref(ell: jnp.ndarray, ranks: jnp.ndarray,
                     active: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.neighbor_min: min over active neighbours per row.

    ell: (n, W) neighbour ids, pad entries point at the last slot of
    ranks/active (which must be INF/inactive).
    """
    vals = jnp.take(ranks, ell, axis=0, fill_value=2**31 - 1)
    act = jnp.take(active.astype(jnp.bool_), ell, axis=0, fill_value=False)
    return jnp.min(jnp.where(act, vals, INF_I32), axis=1)


def label_agree_ref(ell: jnp.ndarray, labels_p: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.label_agree_ell_batch on one graph slice.

    ell: (n, W) neighbour ids, pad entries = n; labels_p: (n+1,) labels
    with slot n = -1 sentinel (never equal to a real label). Returns the
    per-vertex count of ELL neighbours sharing the vertex's label.
    """
    nbr = jnp.take(labels_p, ell, axis=0, fill_value=-1)
    own = labels_p[: ell.shape[0]]
    return jnp.sum((nbr == own[:, None]).astype(jnp.int32), axis=1)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, scale: float | None = None
                  ) -> jnp.ndarray:
    """Naive attention oracle (f32 math). q (B,H,Sq,D), k/v (B,KH,Sk,D)."""
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


__all__ = ["neighbor_min_ref", "label_agree_ref", "attention_ref",
           "INF_I32"]
