"""Benchmarks mirroring the paper's claims (one function per claim).

The paper has no numeric tables — its results are theorems. Each benchmark
measures the empirical quantity the theorem bounds, on instances where the
bound is checkable, and reports ``name,us_per_call,derived`` rows (derived =
the measured ratio/round-count the claim is about).
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np

from repro.core import (
    algorithm1,
    brute_force_opt,
    build_graph,
    clique_clustering,
    clustering_cost,
    degree_capped_pivot,
    dependency_depth,
    greedy_mis_parallel,
    lemma25_transform,
    matching_size,
    max_matching_forest,
    maximal_matching_parallel,
    augmenting_matching_parallel,
    clustering_from_matching,
    pivot,
    random_permutation_ranks,
)
from repro.core.graph import barbell, gnp, random_arboric, random_forest
from repro.core.phases import algorithm2_phase
from repro.core.mis import MISState
import jax.numpy as jnp

Row = Tuple[str, float, float]


def _timed(fn: Callable, reps: int = 1) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_pivot_3approx() -> List[Row]:
    """Cor 28: E[cost(PIVOT ∘ degree-cap)] ≤ 3·OPT (brute-forceable n)."""
    rng = np.random.default_rng(0)
    rows = []
    ratios = []
    us = 0.0
    for trial in range(4):
        n = 8
        g = build_graph(n, gnp(n, 0.45, rng))
        opt, _ = brute_force_opt(g)
        costs = []
        for s in range(50):
            dt, res = _timed(lambda s=s: pivot(
                g, jax.random.PRNGKey(trial * 100 + s)))
            us += dt
            costs.append(clustering_cost(g, res.labels))
        ratios.append(np.mean(costs) / max(opt, 1))
    rows.append(("pivot_mean_cost_over_opt", us / 200, float(np.mean(ratios))))
    return rows


def bench_degree_cap() -> List[Row]:
    """Thm 26: capped PIVOT stays within max{1+ε,3}·OPT; high-deg fraction."""
    rng = np.random.default_rng(1)
    rows = []
    for lam in (1, 2):
        n = 9
        edges, _ = random_arboric(n, lam, rng)
        g = build_graph(n, edges)
        opt, _ = brute_force_opt(g)
        costs, us = [], 0.0
        for s in range(40):
            dt, res = _timed(lambda s=s: degree_capped_pivot(
                g, lam=lam, key=jax.random.PRNGKey(s), eps=2.0))
            us += dt
            costs.append(clustering_cost(g, res.labels))
        rows.append((f"thm26_ratio_lam{lam}", us / 40,
                     float(np.mean(costs) / max(opt, 1))))
    return rows


def bench_mis_rounds_scaling() -> List[Row]:
    """Thm 5/24: dependency depth grows ~log n; Algorithm 1 MPC rounds."""
    rng = np.random.default_rng(2)
    rows = []
    for n in (256, 1024, 4096):
        edges, _ = random_arboric(n, 3, rng)
        g = build_graph(n, edges)
        depths, us = [], 0.0
        for s in range(3):
            ranks = random_permutation_ranks(n, jax.random.PRNGKey(s))
            dt, d = _timed(lambda: dependency_depth(g, ranks))
            us += dt
            depths.append(d)
        rows.append((f"greedy_mis_depth_n{n}", us / 3, float(np.mean(depths))))
    # Algorithm 1 charged rounds, both models
    edges, _ = random_arboric(2048, 3, rng)
    g = build_graph(2048, edges)
    for sub in ("alg2", "alg3"):
        dt, out = _timed(lambda: algorithm1(
            g, key=jax.random.PRNGKey(0), subroutine=sub,
            measure_components=(sub == "alg2")))
        _, _, ledger = out
        rows.append((f"algorithm1_{sub}_mpc_rounds", dt, ledger.total_rounds))
        if sub == "alg2":
            rows.append((f"algorithm1_{sub}_max_component", dt,
                         float(ledger.summary()["max_component"])))
    return rows


def bench_lemma22() -> List[Row]:
    """Lemma 22: max degree after prefix t is ≤ c·n log n / t."""
    from repro.core import remaining_max_degree_after_prefix
    rng = np.random.default_rng(3)
    n = 4096
    edges, _ = random_arboric(n, 4, rng)
    g = build_graph(n, edges)
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(1))
    rows = []
    for t in (n // 16, n // 4, n // 2):
        dt, d = _timed(lambda t=t: remaining_max_degree_after_prefix(
            g, ranks, t))
        bound = n * np.log(n) / t
        rows.append((f"lemma22_t{t}_deg_over_bound", dt, d / bound))
    return rows


def bench_lemma25() -> List[Row]:
    """Lemma 25: transform reaches ≤4λ−2 clusters at no cost increase."""
    rng = np.random.default_rng(4)
    rows = []
    for lam in (1, 2, 4):
        n = 60
        edges, _ = random_arboric(n, lam, rng)
        g = build_graph(n, edges)
        labels = rng.integers(0, 5, n).astype(np.int32)
        before = clustering_cost(g, labels)
        dt, lab2 = _timed(lambda: lemma25_transform(g, labels, lam))
        after = clustering_cost(g, lab2)
        maxc = int(np.bincount(lab2).max())
        assert maxc <= 4 * lam - 2 and after <= before
        rows.append((f"lemma25_lam{lam}_cost_delta", dt,
                     float(after - before)))
    return rows


def bench_forest() -> List[Row]:
    """Cor 27/31 + Lemma 29: matching-based clustering on forests."""
    rng = np.random.default_rng(5)
    n = 1000
    g = build_graph(n, random_forest(n, rng))
    m_star = matching_size(max_matching_forest(g))
    opt_cost = g.m - m_star
    rows = []
    dt, out = _timed(lambda: maximal_matching_parallel(
        g, jax.random.PRNGKey(0)))
    partner, rounds = out
    m = matching_size(partner)
    cost = clustering_cost(g, clustering_from_matching(np.asarray(partner)))
    rows.append(("forest_maximal_rounds", dt, float(rounds)))
    rows.append(("forest_maximal_cost_over_opt", dt, cost / max(opt_cost, 1)))
    dt, out = _timed(lambda: augmenting_matching_parallel(
        g, jax.random.PRNGKey(0), passes=6))
    partner2, _ = out
    cost2 = clustering_cost(g, clustering_from_matching(partner2))
    rows.append(("forest_augmented_cost_over_opt", dt,
                 cost2 / max(opt_cost, 1)))
    return rows


def bench_cliques_lambda2() -> List[Row]:
    """Cor 32 + Rmk 33: λ²-algorithm; barbell attains Θ(λ²)."""
    rows = []
    for lam in (4, 8, 16):
        n, e = barbell(lam)
        g = build_graph(n, e)
        dt, labels = _timed(lambda: np.asarray(clique_clustering(g)))
        cost = clustering_cost(g, labels)
        rows.append((f"cor32_barbell_lam{lam}_cost_over_opt", dt,
                     float(cost)))  # OPT = 1
    return rows


def bench_shattering_lemma18() -> List[Row]:
    """Lemma 18: chunk-graph components stay O(log n) in Algorithm 2."""
    rng = np.random.default_rng(6)
    n = 4096
    edges, _ = random_arboric(n, 3, rng)
    g = build_graph(n, edges)
    ranks = random_permutation_ranks(n, jax.random.PRNGKey(2))
    state = MISState(status=jnp.zeros((n,), jnp.int32), rounds=jnp.int32(0))
    dt, out = _timed(lambda: algorithm2_phase(
        g, ranks, state, 0, n, max(1, g.max_degree()),
        measure_components=True))
    _, _, _, max_comp, chunks = out
    rows = [("lemma18_max_component_over_logn", dt,
             max_comp / np.log(n)),
            ("lemma18_chunks", dt, float(chunks))]
    return rows


ALL = [
    bench_pivot_3approx,
    bench_degree_cap,
    bench_mis_rounds_scaling,
    bench_lemma22,
    bench_lemma25,
    bench_forest,
    bench_cliques_lambda2,
    bench_shattering_lemma18,
]
