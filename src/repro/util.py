"""Small shared utilities used across core / serve / kernels.

Kept dependency-free (stdlib only) so every layer can import it without
cycles — ``core.batch`` packs device tensors with it and the serving layer
uses it for slot accounting.
"""

from __future__ import annotations


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(1, x) (``next_pow2(0) == 1``).

    The single source of truth for every power-of-two padding decision in
    the batch engine and the serving layer: bucket rows/width, batch-axis
    sub-batches, and the pad accounting derived from them. Keeping one
    helper means the packer and the schedulers can never round differently.
    """
    return 1 << max(0, int(x) - 1).bit_length()


__all__ = ["next_pow2"]
