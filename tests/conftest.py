"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single-device CPU; only launch/dryrun.py forces 512 host devices (in its own
process).

Also installs a minimal ``hypothesis`` stand-in when the real package is not
importable, so the tier-1 suite collects and runs in a clean environment.
The stub covers exactly the API surface the suite uses (``given``,
``settings``, ``strategies.integers``, ``strategies.floats`` and a couple of
neighbours) with deterministic seeded sampling: each ``@given`` test runs
``max_examples`` times over examples drawn from a per-test RNG seeded by the
test's qualified name, so runs are reproducible across processes. Install
``requirements-dev.txt`` to get the real shrinking/coverage behaviour.

And a per-test **watchdog timeout**: a hung device program (e.g. a
``lax.while_loop`` whose cond never flips) executes in C++ and never returns
to Python, so a SIGALRM-style in-process timeout can't fire — the suite
would stall until the CI job limit. When the real ``pytest-timeout`` plugin
is installed (``requirements-dev.txt``) it handles this via its ``thread``
method; otherwise a minimal stand-in below honours the same ``timeout`` ini
key: a watchdog thread dumps all stacks (``faulthandler``) and hard-exits
the process so the failure is visible in seconds, not hours.
"""

import functools
import random as _random
import sys
import types

import numpy as np
import pytest

try:
    import pytest_timeout  # noqa: F401
    _HAS_TIMEOUT_PLUGIN = True
except ImportError:  # pragma: no cover - depends on environment
    _HAS_TIMEOUT_PLUGIN = False


def _install_hypothesis_stub():
    class _Strategy:
        """A draw function wrapper mimicking a hypothesis SearchStrategy."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd):
            return self._draw(rnd)

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[r.randrange(len(elements))])

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                max_examples = getattr(wrapper, "_stub_max_examples", 20)
                # Seeding by qualname (str seeds hash via SHA-512 in CPython)
                # keeps the example stream stable across runs and workers.
                rnd = _random.Random(fn.__qualname__)
                for _ in range(max_examples):
                    drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                    try:
                        fn(*args, **{**kwargs, **drawn})
                    except Exception as exc:
                        raise AssertionError(
                            f"falsifying example (hypothesis stub): "
                            f"{fn.__name__}({drawn})"
                        ) from exc

            # pytest must not resolve the wrapped params as fixtures: drop
            # the __wrapped__ back-reference so inspect sees (*args, **kw).
            del wrapper.__wrapped__
            wrapper.is_hypothesis_test = True
            return wrapper

        return deco

    def settings(max_examples=20, deadline=None, **_):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [("integers", integers), ("floats", floats),
                      ("booleans", booleans), ("sampled_from", sampled_from)]:
        setattr(st_mod, name, obj)
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    _install_hypothesis_stub()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    if not _HAS_TIMEOUT_PLUGIN:
        # Register the same ini keys pytest-timeout owns, so pytest.ini
        # parses cleanly with or without the plugin installed.
        parser.addini("timeout", "per-test watchdog timeout in seconds "
                                 "(pytest-timeout stand-in)", default="0")
        parser.addini("timeout_method", "accepted for pytest-timeout "
                                        "compatibility; the stand-in always "
                                        "uses a watchdog thread",
                      default="thread")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_protocol(item, nextitem):
    if _HAS_TIMEOUT_PLUGIN:
        yield
        return
    try:
        timeout = float(item.config.getini("timeout") or 0)
    except (TypeError, ValueError):
        timeout = 0.0
    if timeout <= 0:
        yield
        return
    import faulthandler
    import os
    import threading

    done = threading.Event()

    def watchdog():
        if not done.wait(timeout):
            sys.stderr.write(
                f"\n+++ watchdog: {item.nodeid} exceeded {timeout:.0f}s "
                "(hung device program?) — dumping stacks, aborting run +++\n")
            faulthandler.dump_traceback()
            sys.stderr.flush()
            os._exit(71)

    thread = threading.Thread(target=watchdog, daemon=True,
                              name=f"watchdog:{item.name}")
    thread.start()
    try:
        yield
    finally:
        done.set()
        thread.join(timeout=1.0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
