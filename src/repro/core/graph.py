"""Graph containers and generators for correlation clustering.

A complete signed graph is represented by its *positive* edge set only
(negative edges are implicit — the complement), matching the paper's
input-size convention ``N = |E⁺|`` (§1.1).

All algorithm-facing state lives in padded, fixed-shape arrays so that every
MPC round lowers to static dense kernels on TPU:

* COO: ``src``/``dst`` of length ``2m_pad`` (both directions of every
  undirected edge), sorted by ``src`` and padded with the sentinel vertex
  ``n`` so segment reductions have a spill row.
* CSR: ``row_offsets`` of length ``n + 2`` over the sorted COO.

Generators are host-side numpy (they run once per job); the returned
``Graph`` is a pytree of ``jnp`` arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT = jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Positive-edge graph of a complete signed instance.

    Attributes:
      n: number of vertices (static).
      m: number of undirected positive edges (static).
      src, dst: directed COO arrays, length ``2 * m_pad``, sorted by src;
        padding entries have ``src == dst == n``.
      row_offsets: CSR offsets, length ``n + 2`` (row ``n`` is the pad row).
      deg: positive degree per vertex, length ``n``.
    """

    n: int
    m: int
    src: jnp.ndarray
    dst: jnp.ndarray
    row_offsets: jnp.ndarray
    deg: jnp.ndarray
    eid: jnp.ndarray  # undirected edge id per directed slot (pad = m)

    # -- pytree plumbing (n, m static) ------------------------------------
    def tree_flatten(self):
        return (
            (self.src, self.dst, self.row_offsets, self.deg, self.eid),
            (self.n, self.m),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        n, m = aux
        src, dst, row_offsets, deg, eid = children
        return cls(n=n, m=m, src=src, dst=dst, row_offsets=row_offsets,
                   deg=deg, eid=eid)

    # -- conveniences ------------------------------------------------------
    @property
    def num_directed(self) -> int:
        return int(self.src.shape[0])

    def undirected_edges(self) -> np.ndarray:
        """Return the (m, 2) undirected edge list with u < v (host numpy)."""
        s = np.asarray(self.src)
        d = np.asarray(self.dst)
        keep = (s < d) & (s < self.n)
        return np.stack([s[keep], d[keep]], axis=1)

    def max_degree(self) -> int:
        return int(np.asarray(self.deg).max()) if self.n else 0


def build_graph(n: int, edges: np.ndarray, pad_to: Optional[int] = None) -> Graph:
    """Build a :class:`Graph` from an (m, 2) undirected edge array.

    Self loops and duplicate edges are removed. ``pad_to`` (directed count)
    fixes the array length for shape-stable jit across instances.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size:
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keep = lo != hi
        lo, hi = lo[keep], hi[keep]
        und = np.unique(lo * np.int64(n) + hi)
        lo, hi = und // n, und % n
    else:
        lo = hi = np.zeros((0,), dtype=np.int64)
    m = int(lo.shape[0])

    s = np.concatenate([lo, hi])
    d = np.concatenate([hi, lo])
    e = np.concatenate([np.arange(m), np.arange(m)])
    order = np.argsort(s, kind="stable")
    s, d, e = s[order], d[order], e[order]

    npad = 2 * m if pad_to is None else int(pad_to)
    if npad < 2 * m:
        raise ValueError(f"pad_to={npad} < 2m={2 * m}")
    s_pad = np.full((npad,), n, dtype=np.int32)
    d_pad = np.full((npad,), n, dtype=np.int32)
    e_pad = np.full((npad,), m, dtype=np.int32)
    s_pad[: 2 * m] = s
    d_pad[: 2 * m] = d
    e_pad[: 2 * m] = e

    deg = np.bincount(s, minlength=n).astype(np.int32) if m else np.zeros(n, np.int32)
    row = np.zeros((n + 2,), dtype=np.int32)
    row[1 : n + 1] = np.cumsum(deg)
    row[n + 1] = npad  # pad row swallows the sentinel tail

    return Graph(
        n=n,
        m=m,
        src=jnp.asarray(s_pad, INT),
        dst=jnp.asarray(d_pad, INT),
        row_offsets=jnp.asarray(row, INT),
        deg=jnp.asarray(deg, INT),
        eid=jnp.asarray(e_pad, INT),
    )


# ---------------------------------------------------------------------------
# Generators (host-side). Every generator returns (n, edges ndarray, lam)
# where lam is a *known upper bound* on the arboricity by construction.
# ---------------------------------------------------------------------------


def random_forest(n: int, rng: np.random.Generator, p_keep: float = 1.0) -> np.ndarray:
    """Uniform random recursive forest: vertex i attaches to a random j < i."""
    if n <= 1:
        return np.zeros((0, 2), dtype=np.int64)
    parents = np.array([rng.integers(0, i) for i in range(1, n)], dtype=np.int64)
    edges = np.stack([np.arange(1, n, dtype=np.int64), parents], axis=1)
    if p_keep < 1.0:
        edges = edges[rng.random(len(edges)) < p_keep]
    return edges


def random_arboric(n: int, lam: int, rng: np.random.Generator,
                   p_keep: float = 1.0) -> Tuple[np.ndarray, int]:
    """Union of ``lam`` independent random forests ⇒ arboricity ≤ lam."""
    chunks = []
    for _ in range(lam):
        perm = rng.permutation(n)
        f = random_forest(n, rng, p_keep=p_keep)
        if len(f):
            chunks.append(perm[f])
    edges = np.concatenate(chunks, axis=0) if chunks else np.zeros((0, 2), np.int64)
    return edges, lam


def gnp(n: int, p: float, rng: np.random.Generator) -> np.ndarray:
    """Erdős–Rényi G(n, p) positive edges (small n only)."""
    iu = np.triu_indices(n, k=1)
    mask = rng.random(len(iu[0])) < p
    return np.stack([iu[0][mask], iu[1][mask]], axis=1).astype(np.int64)


def clique(n: int, offset: int = 0) -> np.ndarray:
    iu = np.triu_indices(n, k=1)
    return (np.stack([iu[0], iu[1]], axis=1) + offset).astype(np.int64)


def barbell(lam: int) -> Tuple[int, np.ndarray]:
    """Two K_lam cliques joined by one edge (Remark 33 tightness instance)."""
    e1 = clique(lam, 0)
    e2 = clique(lam, lam)
    bridge = np.array([[lam - 1, lam]], dtype=np.int64)
    return 2 * lam, np.concatenate([e1, e2, bridge], axis=0)


def star(n: int) -> np.ndarray:
    """Star graph: arboricity 1, max degree n-1 (degree-cap stress case)."""
    return np.stack(
        [np.zeros(n - 1, dtype=np.int64), np.arange(1, n, dtype=np.int64)], axis=1
    )


def path(n: int) -> np.ndarray:
    return np.stack(
        [np.arange(n - 1, dtype=np.int64), np.arange(1, n, dtype=np.int64)], axis=1
    )


def disjoint_cliques(sizes, gap: int = 0) -> Tuple[int, np.ndarray]:
    edges, off = [], 0
    for s in sizes:
        if s >= 2:
            edges.append(clique(s, off))
        off += s + gap
    e = np.concatenate(edges, axis=0) if edges else np.zeros((0, 2), np.int64)
    return off, e


def scale_free(n: int, attach: int, rng: np.random.Generator) -> Tuple[np.ndarray, int]:
    """Barabási–Albert preferential attachment: arboricity ≤ attach.

    Vectorized: the endpoint pool is a flat int array (each edge endpoint
    appears once — sampling it uniformly IS degree-proportional sampling);
    duplicates within one vertex's picks are dropped, keeping ≤ attach new
    edges per vertex (arboricity bound preserved).
    """
    pool = np.empty(2 * attach * n, dtype=np.int64)
    pool[:attach] = np.arange(attach)
    pool_len = attach
    edges = np.empty((attach * n, 2), dtype=np.int64)
    m = 0
    for v in range(attach, n):
        idx = rng.integers(0, pool_len, attach)
        picks = np.unique(pool[idx])
        k = len(picks)
        edges[m:m + k, 0] = v
        edges[m:m + k, 1] = picks
        m += k
        pool[pool_len:pool_len + k] = picks
        pool[pool_len + k:pool_len + 2 * k] = v
        pool_len += 2 * k
    return edges[:m], attach


__all__ = [
    "Graph",
    "build_graph",
    "random_forest",
    "random_arboric",
    "gnp",
    "clique",
    "barbell",
    "star",
    "path",
    "disjoint_cliques",
    "scale_free",
]
